#!/usr/bin/env bash
# Arm the CI bench gate: promote measured BENCH_micro/BENCH_ablation
# reports from a green CI run's `bench-reports` artifact to committed
# root baselines (see docs/BENCHMARKS.md, "Refreshing a baseline").
#
# Usage:
#   gh run download <RUN_ID> --name bench-reports --dir /tmp/bench-reports
#   scripts/arm_bench_gate.sh /tmp/bench-reports
#
# The script:
#   * copies BENCH_micro.json and BENCH_ablation.json to the repo root;
#   * drops the `kernel_xla_mix` entry from the micro baseline (only
#     emitted when PJRT artifacts are built, so gating it would fail
#     every standard runner);
#   * forces `"provisional": false` so the gate compares for real;
#   * leaves the diff staged for you to review and commit.
set -euo pipefail

if [ $# -ne 1 ] || [ ! -d "$1" ]; then
    echo "usage: $0 <downloaded-bench-reports-dir>" >&2
    exit 2
fi
src=$1
root=$(cd "$(dirname "$0")/.." && pwd)

for name in BENCH_micro.json BENCH_ablation.json; do
    if [ ! -f "$src/$name" ]; then
        echo "arm_bench_gate: $src/$name not found — is this a bench-reports artifact?" >&2
        exit 1
    fi
done

python3 - "$src" "$root" <<'EOF'
import json, sys
src, root = sys.argv[1], sys.argv[2]
for name, drop in (("BENCH_micro.json", {"kernel_xla_mix"}), ("BENCH_ablation.json", set())):
    with open(f"{src}/{name}") as f:
        doc = json.load(f)
    doc["provisional"] = False
    before = len(doc["entries"])
    doc["entries"] = [e for e in doc["entries"] if e["name"] not in drop]
    with open(f"{root}/{name}", "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"armed {name}: {len(doc['entries'])} entries"
          + (f" (dropped {before - len(doc['entries'])})" if before != len(doc["entries"]) else ""))
EOF

cd "$root"
git add BENCH_micro.json BENCH_ablation.json
git --no-pager diff --cached --stat
echo "arm_bench_gate: staged. Review with 'git diff --cached', then commit."
