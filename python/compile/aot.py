"""AOT bridge: lower the L2 graphs to HLO *text* artifacts.

HLO text — not ``serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids, which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once at build time (``make artifacts``); the rust binary is then
self-contained. Usage::

    cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import EXPORTS


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for the rust
    side's ``to_tuple1`` unwrap)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(out_dir: str) -> list:
    os.makedirs(out_dir, exist_ok=True)
    written = []
    for name, (fn, shapes) in EXPORTS.items():
        specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        written.append((name, path, len(text)))
    # Manifest: lets the rust loader sanity-check shapes without parsing HLO.
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        for name, (fn, shapes) in EXPORTS.items():
            dims = ";".join(",".join(str(d) for d in s) for s in shapes)
            f.write(f"{name} = {dims}\n")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    for name, path, size in export_all(args.out):
        print(f"wrote {name}: {size} chars -> {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
