"""L2: the JAX compute graph for `ComputeObject` operations.

Composes the L1 Pallas kernels into the two entry points the rust runtime
executes (one AOT artifact each):

  * ``mix_op(states, params)``   — the UPDATE operation's state transition;
  * ``digest_op(states)``        — the READ operation's digest.

The mixing matrix W is an explicit *runtime input*, not a baked constant:
the XLA HLO **text** printer elides large literals as ``constant({...})``
and the text parser reads those back as zeros, so constants above a few
elements cannot ride through the text interchange format. The rust runtime
materializes W once at startup (same formula as `w_matrix`) and passes it
on every call.
"""

import jax.numpy as jnp  # noqa: F401  (kept for callers)

from .kernels import mix as kernels
from .kernels.ref import DEFAULT_DIM, DEFAULT_ROUNDS


def mix_op(states: jnp.ndarray, params: jnp.ndarray, w: jnp.ndarray) -> tuple:
    """UPDATE: R rounds of tanh(state @ W + params). (B,D),(B,D),(D,D) → (B,D)."""
    return (kernels.mix(states, params, w, rounds=DEFAULT_ROUNDS),)


def digest_op(states: jnp.ndarray) -> tuple:
    """READ: per-row sum of squares. (B,D) → (B,)."""
    return (kernels.digest(states),)


#: The artifact set `aot.py` exports and rust's `runtime::XlaBackend`
#: loads: name → (function, input shapes). B=1 is the per-object call used
#: on the request path; B=8 exercises the batch tiling in tests.
D2 = (DEFAULT_DIM, DEFAULT_DIM)
EXPORTS = {
    "mix": (mix_op, [(1, DEFAULT_DIM), (1, DEFAULT_DIM), D2]),
    "digest": (digest_op, [(1, DEFAULT_DIM)]),
    "mix_b8": (mix_op, [(8, DEFAULT_DIM), (8, DEFAULT_DIM), D2]),
}
