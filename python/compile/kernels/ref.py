"""Pure-jnp oracle for the Pallas kernels (L1 correctness reference).

The rust `SpinBackend` (rust/src/object/compute.rs) implements the same
computation in scalar rust; `mix_ref`/`digest_ref` here are the canonical
specification both are validated against.
"""

import jax.numpy as jnp
import numpy as np

DEFAULT_DIM = 64
DEFAULT_ROUNDS = 4


def w_matrix(dim: int = DEFAULT_DIM) -> np.ndarray:
    """Deterministic mixing matrix: W[i, j] = sin(i * dim + j) / dim.

    Matches rust's SpinBackend exactly (modulo f32 rounding of sin).
    """
    idx = np.arange(dim * dim, dtype=np.float32)
    return (np.sin(idx) / dim).reshape(dim, dim).astype(np.float32)


def mix_ref(states: jnp.ndarray, params: jnp.ndarray, w: jnp.ndarray,
            rounds: int = DEFAULT_ROUNDS) -> jnp.ndarray:
    """R rounds of `state' = tanh(state @ W + params)` over a (B, D) batch."""
    s = states
    for _ in range(rounds):
        s = jnp.tanh(s @ w + params)
    return s


def digest_ref(states: jnp.ndarray) -> jnp.ndarray:
    """Per-row sum of squares — the read-only digest (B,)."""
    return jnp.sum(states * states, axis=-1)
