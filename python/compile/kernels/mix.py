"""L1 Pallas kernels: the state-mix update and the read digest.

The CF model's point is delegating complex computation to the object's
home node (paper §1, §2.5); `ComputeObject`'s `mix` (update) and `digest`
(read) operations are that computation. Both kernels are written in Pallas
and lowered with ``interpret=True`` — real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see DESIGN.md
§Hardware-Adaptation).

TPU structure (documented, estimated in DESIGN.md §7):
  * grid over the batch dimension; each grid step works on a
    (BLOCK_B, D) tile of states/params resident in VMEM;
  * the D×D mixing matrix W uses a constant index_map so it stays
    resident in VMEM across grid steps (16 KiB at D=64, f32);
  * the per-round ``s @ w`` matmul is MXU-shaped (D a multiple of 8);
    accumulation in f32;
  * ROUNDS is unrolled at trace time — no scan carries, so Mosaic can
    double-buffer the HBM→VMEM state streams.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import DEFAULT_DIM, DEFAULT_ROUNDS

# Batch tile: 128 rows × 64 lanes × 4 B = 32 KiB per stream — comfortably
# inside a TPU core's ~16 MiB VMEM alongside W and the output tile.
BLOCK_B = 128


def _mix_kernel(w_ref, s_ref, p_ref, o_ref, *, rounds: int):
    s = s_ref[...]
    w = w_ref[...]
    p = p_ref[...]
    for _ in range(rounds):  # unrolled: no carry, MXU back-to-back
        s = jnp.tanh(jnp.dot(s, w, preferred_element_type=jnp.float32) + p)
    o_ref[...] = s


def _digest_kernel(s_ref, o_ref):
    s = s_ref[...]
    o_ref[...] = jnp.sum(s * s, axis=1)


def mix(states: jnp.ndarray, params: jnp.ndarray, w: jnp.ndarray,
        rounds: int = DEFAULT_ROUNDS, block_b: int = BLOCK_B) -> jnp.ndarray:
    """Batched state mix via Pallas: (B, D), (B, D), (D, D) → (B, D)."""
    b, d = states.shape
    assert params.shape == (b, d), (params.shape, states.shape)
    assert w.shape == (d, d)
    block_b = min(block_b, b)
    grid = (pl.cdiv(b, block_b),)
    kernel = functools.partial(_mix_kernel, rounds=rounds)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # W: constant index_map ⇒ fetched once, resident across steps.
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=True,
    )(w, states, params)


def digest(states: jnp.ndarray, block_b: int = BLOCK_B) -> jnp.ndarray:
    """Batched read digest via Pallas: (B, D) → (B,) sum of squares."""
    b, d = states.shape
    block_b = min(block_b, b)
    grid = (pl.cdiv(b, block_b),)
    return pl.pallas_call(
        _digest_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(states)


DIM = DEFAULT_DIM
ROUNDS = DEFAULT_ROUNDS
