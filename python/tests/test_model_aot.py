"""L2 + AOT: model entry points compose the kernel correctly, and the HLO
text artifacts are well-formed and shape-stable."""

import os
import tempfile

import numpy as np
import pytest

# Everything here lowers through JAX/XLA; degrade to a skip when the
# runtime is absent instead of erroring at collection.
jax = pytest.importorskip("jax", reason="jax/XLA runtime not installed")
import jax.numpy as jnp  # noqa: E402

from compile import aot  # noqa: E402
from compile.model import EXPORTS, digest_op, mix_op  # noqa: E402
from compile.kernels.ref import DEFAULT_DIM, digest_ref, mix_ref, w_matrix  # noqa: E402

RNG = np.random.default_rng(7)


def test_mix_op_matches_ref():
    s = RNG.standard_normal((1, DEFAULT_DIM)).astype(np.float32)
    p = RNG.standard_normal((1, DEFAULT_DIM)).astype(np.float32)
    w = jnp.asarray(w_matrix(DEFAULT_DIM))
    (got,) = mix_op(jnp.asarray(s), jnp.asarray(p), w)
    want = mix_ref(jnp.asarray(s), jnp.asarray(p), w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_digest_op_matches_ref():
    s = RNG.standard_normal((5, DEFAULT_DIM)).astype(np.float32)
    (got,) = digest_op(jnp.asarray(s))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(digest_ref(jnp.asarray(s))), rtol=1e-5, atol=1e-5
    )


def test_exports_cover_request_path_shapes():
    assert "mix" in EXPORTS and "digest" in EXPORTS
    _, shapes = EXPORTS["mix"]
    assert shapes == [(1, DEFAULT_DIM), (1, DEFAULT_DIM), (DEFAULT_DIM, DEFAULT_DIM)]
    _, shapes = EXPORTS["digest"]
    assert shapes == [(1, DEFAULT_DIM)]


def test_hlo_text_export_roundtrip():
    """Every artifact lowers to parseable HLO text with an entry tuple."""
    with tempfile.TemporaryDirectory() as d:
        written = aot.export_all(d)
        assert {n for n, _, _ in written} == set(EXPORTS)
        for name, path, size in written:
            assert size > 0
            text = open(path).read()
            assert text.lstrip().startswith("HloModule"), f"{name}: not HLO text"
            assert "ENTRY" in text
            # return_tuple=True ⇒ a tuple root for rust's to_tuple1()
            assert "tuple" in text, f"{name}: missing tuple root"
        manifest = open(os.path.join(d, "manifest.txt")).read()
        assert "mix = 1,64;1,64;64,64" in manifest
        assert "digest = 1,64" in manifest


def test_lowered_mix_executes_like_eager():
    """Compile the lowered module in-process and compare with eager."""
    s = RNG.standard_normal((1, DEFAULT_DIM)).astype(np.float32)
    p = RNG.standard_normal((1, DEFAULT_DIM)).astype(np.float32)
    w = jnp.asarray(w_matrix(DEFAULT_DIM))
    spec = lambda shp: jax.ShapeDtypeStruct(shp, jnp.float32)
    compiled = jax.jit(mix_op).lower(
        spec((1, DEFAULT_DIM)), spec((1, DEFAULT_DIM)), spec((DEFAULT_DIM, DEFAULT_DIM))
    ).compile()
    (got,) = compiled(jnp.asarray(s), jnp.asarray(p), w)
    (want,) = mix_op(jnp.asarray(s), jnp.asarray(p), w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_no_large_constants_in_hlo_text():
    """Guard against the constant-elision trap: HLO text prints big
    literals as `constant({...})`, which parses back as zeros. No artifact
    may contain an elided constant."""
    with tempfile.TemporaryDirectory() as d:
        for name, path, _ in aot.export_all(d):
            text = open(path).read()
            assert "constant({...})" not in text, (
                f"{name}: large constant elided in HLO text — pass it as a "
                "runtime parameter instead"
            )
