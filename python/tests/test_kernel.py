"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps batch sizes, dims, round counts, block sizes and value
ranges; every case asserts allclose against `ref.py`.
"""

import numpy as np
import pytest

# The whole module depends on the JAX/XLA runtime; skip cleanly when it is
# not installed (offline CI without the PJRT stack).
jax = pytest.importorskip("jax", reason="jax/XLA runtime not installed")
import jax.numpy as jnp  # noqa: E402

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only on minimal images
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        """Fallback decorator: surface the sweep as a skipped test."""

        def deco(f):
            import functools

            @pytest.mark.skip(reason="hypothesis not installed")
            @functools.wraps(f)
            def wrapper():
                pass  # pragma: no cover

            return wrapper

        return deco

    def settings(*_args, **_kwargs):
        return lambda f: f

    class st:  # noqa: N801 - mimic the hypothesis namespace
        @staticmethod
        def integers(**_kwargs):
            return None

        @staticmethod
        def sampled_from(_xs):
            return None


from compile.kernels import mix as k  # noqa: E402
from compile.kernels.ref import digest_ref, mix_ref, w_matrix  # noqa: E402

RNG = np.random.default_rng(0xE16E)


def rand_batch(b, d, scale=1.0):
    return (RNG.standard_normal((b, d)) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# Fixed-shape sanity checks
# ---------------------------------------------------------------------------

def test_mix_matches_ref_default_shape():
    s = rand_batch(4, k.DIM)
    p = rand_batch(4, k.DIM)
    w = w_matrix(k.DIM)
    got = k.mix(jnp.asarray(s), jnp.asarray(p), jnp.asarray(w))
    want = mix_ref(jnp.asarray(s), jnp.asarray(p), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_digest_matches_ref():
    s = rand_batch(16, k.DIM)
    got = k.digest(jnp.asarray(s))
    want = digest_ref(jnp.asarray(s))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_mix_is_deterministic():
    s = rand_batch(2, k.DIM)
    p = rand_batch(2, k.DIM)
    w = w_matrix(k.DIM)
    a = k.mix(jnp.asarray(s), jnp.asarray(p), jnp.asarray(w))
    b = k.mix(jnp.asarray(s), jnp.asarray(p), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_zero_rounds_is_identity():
    s = rand_batch(3, 8)
    p = rand_batch(3, 8)
    w = w_matrix(8)
    got = k.mix(jnp.asarray(s), jnp.asarray(p), jnp.asarray(w), rounds=0)
    np.testing.assert_array_equal(np.asarray(got), s)


def test_output_is_tanh_bounded():
    s = rand_batch(4, k.DIM, scale=100.0)
    p = rand_batch(4, k.DIM, scale=100.0)
    w = w_matrix(k.DIM)
    got = np.asarray(k.mix(jnp.asarray(s), jnp.asarray(p), jnp.asarray(w)))
    assert np.all(got <= 1.0) and np.all(got >= -1.0)


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, rounds, block sizes, magnitudes
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=40),
    d=st.sampled_from([8, 16, 64]),
    rounds=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_mix_sweep(b, d, rounds, seed):
    rng = np.random.default_rng(seed)
    s = rng.standard_normal((b, d)).astype(np.float32)
    p = rng.standard_normal((b, d)).astype(np.float32)
    w = w_matrix(d)
    got = k.mix(jnp.asarray(s), jnp.asarray(p), jnp.asarray(w), rounds=rounds)
    want = mix_ref(jnp.asarray(s), jnp.asarray(p), jnp.asarray(w), rounds=rounds)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=300),
    d=st.sampled_from([4, 64]),
    block=st.sampled_from([1, 7, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_mix_block_size_invariance(b, d, block, seed):
    """The BlockSpec tiling must not change the numbers (incl. ragged
    trailing blocks)."""
    rng = np.random.default_rng(seed)
    s = rng.standard_normal((b, d)).astype(np.float32)
    p = rng.standard_normal((b, d)).astype(np.float32)
    w = w_matrix(d)
    got = k.mix(jnp.asarray(s), jnp.asarray(p), jnp.asarray(w), block_b=block)
    want = mix_ref(jnp.asarray(s), jnp.asarray(p), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=200),
    d=st.sampled_from([8, 64]),
    scale=st.sampled_from([0.0, 0.1, 1.0, 50.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_digest_sweep(b, d, scale, seed):
    rng = np.random.default_rng(seed)
    s = (rng.standard_normal((b, d)) * scale).astype(np.float32)
    got = k.digest(jnp.asarray(s))
    want = digest_ref(jnp.asarray(s))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_w_matrix_matches_rust_spinbackend():
    """W[i,j] = sin(i*d + j)/d — the exact formula in compute.rs."""
    w = w_matrix(8)
    for i in range(8):
        for j in range(8):
            assert w[i, j] == pytest.approx(np.sin(np.float32(i * 8 + j)) / 8, rel=1e-6)
