"""Pytest bootstrap: make the `compile` package importable regardless of
where pytest is invoked from (repo root in CI: `python -m pytest
python/tests -q`)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
