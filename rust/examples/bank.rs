//! Bank workload: concurrent transfers + irrevocable auditing + manual
//! aborts, demonstrating the safety properties the paper claims.
//!
//! ```text
//! cargo run --release --example bank
//! ```
//!
//! * 16 accounts across 4 nodes; 8 client threads do random transfers,
//!   aborting manually when an account would overdraw.
//! * A concurrent **irrevocable** auditor repeatedly sums all balances —
//!   with a side effect (printing: the kind of operation optimistic TMs
//!   cannot re-execute safely) — and must always observe the conserved
//!   total, because irrevocable transactions never read early-released
//!   state and never abort.

use atomic_rmi2::object::{Account, AccountRef};
use atomic_rmi2::{AtomicRmi2, Cluster, NetworkModel, NodeId, Suprema, TxCtx, TxError};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

const NODES: u16 = 4;
const ACCOUNTS: usize = 16;
const CLIENTS: usize = 8;
const TRANSFERS_PER_CLIENT: usize = 30;
const INITIAL: i64 = 1_000;

fn main() {
    let cluster = Arc::new(Cluster::new(NODES, NetworkModel::lan()));
    let sys = AtomicRmi2::new(Arc::clone(&cluster));
    for i in 0..ACCOUNTS {
        sys.host(
            NodeId((i % NODES as usize) as u16),
            &format!("acct-{i}"),
            Box::new(Account::with_balance(INITIAL)),
        );
    }

    let stop = Arc::new(AtomicBool::new(false));
    let audits = Arc::new(AtomicU64::new(0));

    // Irrevocable auditor: sums all accounts, with an I/O side effect.
    let auditor = {
        let sys = Arc::clone(&sys);
        let stop = Arc::clone(&stop);
        let audits = Arc::clone(&audits);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                let mut tx = sys.tx(NodeId(0)).irrevocable();
                let accounts: Vec<AccountRef> = (0..ACCOUNTS)
                    .map(|i| AccountRef::new(tx.reads(&format!("acct-{i}"), 1)))
                    .collect();
                // The audited total is the body's return value.
                let (total, _ops) = tx
                    .run(|t| {
                        let mut total = 0i64;
                        for acct in &accounts {
                            total += acct.balance(t)?;
                        }
                        // The irrevocable side effect: printing mid-transaction.
                        print!("");
                        Ok(total)
                    })
                    .expect("irrevocable audit can never abort");
                assert_eq!(
                    total,
                    INITIAL * ACCOUNTS as i64,
                    "audit saw a non-conserved total — serializability violated"
                );
                audits.fetch_add(1, Ordering::Relaxed);
            }
        })
    };

    // Transfer clients.
    let manual_aborts = Arc::new(AtomicU64::new(0));
    let mut clients = Vec::new();
    for c in 0..CLIENTS {
        let sys = Arc::clone(&sys);
        let manual_aborts = Arc::clone(&manual_aborts);
        clients.push(std::thread::spawn(move || {
            let mut rng = atomic_rmi2::util::prng::Prng::seeded(0xBA_4C ^ c as u64);
            for _ in 0..TRANSFERS_PER_CLIENT {
                let from = rng.index(ACCOUNTS);
                let to = (from + 1 + rng.index(ACCOUNTS - 1)) % ACCOUNTS;
                let amount = 1 + rng.below(500) as i64;
                let client = NodeId((c % NODES as usize) as u16);
                // Manual aborts make cascades possible (§2.3): a reader of
                // early-released state is forcibly aborted — retry it.
                loop {
                    let mut tx = sys.tx(client);
                    let src =
                        AccountRef::new(tx.accesses(&format!("acct-{from}"), Suprema::new(1, 0, 1)));
                    let dst = AccountRef::new(tx.updates(&format!("acct-{to}"), 1));
                    let r = tx.run(|t| {
                        // Both legs of the transfer are submitted without
                        // waiting (§2.6); the overdraw check then reads src.
                        let w = src.withdraw_async(t, amount)?;
                        let d = dst.deposit_async(t, amount)?;
                        w.wait()?;
                        d.wait()?;
                        if src.balance(t)? < 0 {
                            return t.abort(); // would overdraw: roll back
                        }
                        Ok(())
                    });
                    match r {
                        Ok(_) => break,
                        Err(TxError::ManualAbort) => {
                            manual_aborts.fetch_add(1, Ordering::Relaxed);
                            break;
                        }
                        Err(TxError::ForcedAbort(_)) => continue, // cascade
                        Err(e) => panic!("unexpected transaction failure: {e}"),
                    }
                }
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    stop.store(true, Ordering::Release);
    auditor.join().unwrap();

    // Final invariant: money conserved.
    let total: i64 = (0..ACCOUNTS)
        .map(|i| {
            let oid = cluster.registry.locate(&format!("acct-{i}")).unwrap();
            sys.with_object(oid, |o| o.as_any().downcast_ref::<Account>().unwrap().balance())
        })
        .sum();
    println!(
        "final total = {total} (expected {}), commits = {}, manual aborts = {}, audits = {}",
        INITIAL * ACCOUNTS as i64,
        sys.stats.commits.load(Ordering::Relaxed),
        manual_aborts.load(Ordering::Relaxed),
        audits.load(Ordering::Relaxed),
    );
    assert_eq!(total, INITIAL * ACCOUNTS as i64, "money not conserved");
    println!(
        "cascading (forced) aborts: {}",
        sys.stats.forced_aborts.load(Ordering::Relaxed)
    );
    sys.shutdown();
    println!("bank OK");
}
