//! CF compute delegation: transactions drive `ComputeObject`s whose
//! operations run the **AOT-compiled Pallas/XLA kernel** on their home
//! node — the control-flow model's "borrow computational power from
//! remote resource servers" (paper §1).
//!
//! ```text
//! make artifacts && cargo run --release --example pipeline
//! ```
//!
//! A 3-stage pipeline of compute objects on 3 nodes: each transaction
//! reads stage `i`'s digest, mixes stage `i+1` with parameters derived
//! from it, and the suprema let OptSVA-CF release each stage as soon as
//! its last operation ran, so consecutive pipeline transactions overlap.
//! Falls back to the pure-rust `SpinBackend` when artifacts are missing.

use atomic_rmi2::object::{ComputeBackend, ComputeObject, ComputeRef, SpinBackend};
use atomic_rmi2::runtime::{XlaBackend, XlaRuntime};
use atomic_rmi2::{AtomicRmi2, Cluster, NetworkModel, NodeId};
use std::sync::Arc;
use std::time::Instant;

const STAGES: usize = 3;
const ROUNDS_PER_CLIENT: usize = 4;
const CLIENTS: usize = 4;

fn main() {
    let backend: Arc<dyn ComputeBackend> = match XlaBackend::load_default() {
        Ok(b) => {
            println!("kernel backend: xla-pjrt (AOT Pallas artifact)");
            Arc::new(b)
        }
        Err(e) => {
            println!("kernel backend: spin (fallback: {e})");
            Arc::new(SpinBackend::new(64, 4))
        }
    };
    let dim = backend.dim();

    let cluster = Arc::new(Cluster::new(STAGES as u16, NetworkModel::lan()));
    let sys = AtomicRmi2::new(Arc::clone(&cluster));
    for s in 0..STAGES {
        sys.host(
            NodeId(s as u16),
            &format!("stage-{s}"),
            Box::new(ComputeObject::new(Arc::clone(&backend))),
        );
    }

    let t0 = Instant::now();
    let mut threads = Vec::new();
    for c in 0..CLIENTS {
        let sys = Arc::clone(&sys);
        threads.push(std::thread::spawn(move || {
            for round in 0..ROUNDS_PER_CLIENT {
                for s in 0..STAGES - 1 {
                    // Read stage s (digest), update stage s+1 (mix).
                    let mut tx = sys.tx(NodeId(s as u16));
                    let src = ComputeRef::new(tx.reads(&format!("stage-{s}"), 1));
                    let dst = ComputeRef::new(tx.updates(&format!("stage-{}", s + 1), 1));
                    tx.run(|t| {
                        let d = src.digest(t)? as f32;
                        // Parameters derived from the upstream digest.
                        let params: Vec<f32> = (0..dim)
                            .map(|i| (d + (c * 31 + round * 7 + i) as f32 * 0.01).sin() * 0.1)
                            .collect();
                        // Fire-and-forget: the mix is submitted and never
                        // awaited — commit drains it (and would surface any
                        // kernel failure), so the client thread is free
                        // immediately (§2.6 write-behind).
                        let _mix = dst.mix_async(t, params)?;
                        Ok(())
                    })
                    .expect("pipeline transaction failed");
                }
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    let wall = t0.elapsed();

    // Final digests: deterministic given the serialization order count.
    for s in 0..STAGES {
        let oid = cluster.registry.locate(&format!("stage-{s}")).unwrap();
        let digest = sys.with_object(oid, |o| {
            let c = o.as_any().downcast_ref::<ComputeObject>().unwrap();
            c.state().iter().map(|x| x * x).sum::<f32>()
        });
        println!("stage-{s}: digest = {digest:.6}");
        assert!(digest.is_finite());
    }
    let kernel_calls = CLIENTS * ROUNDS_PER_CLIENT * (STAGES - 1) * 2;
    println!(
        "ran {} transactions ({kernel_calls} kernel executions) in {:.1} ms, commits = {}, early releases = {}",
        CLIENTS * ROUNDS_PER_CLIENT * (STAGES - 1),
        wall.as_secs_f64() * 1e3,
        sys.stats.commits.load(std::sync::atomic::Ordering::Relaxed),
        sys.stats.early_releases.load(std::sync::atomic::Ordering::Relaxed),
    );
    let present = XlaRuntime::artifacts_present(&XlaRuntime::default_dir());
    println!("artifacts present: {present}");
    sys.shutdown();
    println!("pipeline OK");
}
