//! Quickstart: the paper's bank-transfer example (Fig 9), end to end, on
//! the typed builder/futures API.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a 2-node simulated cluster, hosts two `Account` objects, and
//! runs the canonical Atomic RMI 2 transaction: declare the access set
//! with suprema in the preamble, transfer money asynchronously (the
//! withdraw and the deposit are `submit`ted and overlap, §2.6/§2.8),
//! abort manually if the balance went negative.

use atomic_rmi2::object::{Account, AccountRef};
use atomic_rmi2::{AtomicRmi2, Cluster, NetworkModel, NodeId, Suprema, TxCtx};
use std::sync::Arc;

fn main() {
    // A simulated 2-node cluster with LAN-like latency.
    let cluster = Arc::new(Cluster::new(2, NetworkModel::lan()));
    let sys = AtomicRmi2::new(Arc::clone(&cluster));

    // Host shared objects at their home nodes (they never migrate: CF).
    sys.host(NodeId(0), "A", Box::new(Account::with_balance(500)));
    sys.host(NodeId(1), "B", Box::new(Account::with_balance(100)));

    // Fig 9: the preamble declares objects + suprema, then the body runs.
    // Typed facades replace hand-rolled OpCall/Value plumbing.
    let mut tx = sys.tx(NodeId(0));
    let a = AccountRef::new(tx.accesses("A", Suprema::new(1, 0, 1))); // 1 read, 1 update
    let b = AccountRef::new(tx.updates("B", 1)); //                      1 update
    let result = tx.run(|t| {
        // Submit both legs of the transfer without waiting: they run on
        // their home nodes concurrently while this thread continues.
        let w = a.withdraw_async(t, 100)?;
        let d = b.deposit_async(t, 100)?;
        w.wait()?;
        d.wait()?;
        // The balance check reads A synchronously, like a classic stub.
        let bal = a.balance(t)?;
        if bal < 0 {
            t.abort()?; // manual rollback, like the paper (always Err)
        }
        Ok(bal)
    });

    println!("transaction: {result:?}");
    let oid_a = cluster.registry.locate("A").unwrap();
    let oid_b = cluster.registry.locate("B").unwrap();
    let bal = |oid| {
        sys.with_object(oid, |o| {
            o.as_any().downcast_ref::<Account>().unwrap().balance()
        })
    };
    println!("A = {}, B = {}", bal(oid_a), bal(oid_b));
    assert_eq!(bal(oid_a), 400);
    assert_eq!(bal(oid_b), 200);
    let (remaining, _ops) = result.expect("transfer commits");
    assert_eq!(remaining, 400, "the body's return value is the A balance");

    let (msgs, bytes, local) = cluster.stats.snapshot();
    println!("network: {msgs} messages, {bytes} bytes, {local} co-located calls");
    println!(
        "commits = {}, aborts = {}",
        sys.stats.commits.load(std::sync::atomic::Ordering::Relaxed),
        sys.stats.manual_aborts.load(std::sync::atomic::Ordering::Relaxed)
    );
    sys.shutdown();
    println!("quickstart OK");
}
