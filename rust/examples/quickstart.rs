//! Quickstart: the paper's bank-transfer example (Fig 9), end to end.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a 2-node simulated cluster, hosts two `Account` objects, and
//! runs the canonical Atomic RMI 2 transaction: declare the access set
//! with suprema in the preamble, transfer money, abort manually if the
//! balance went negative.

use atomic_rmi2::object::{account::ops, Account};
use atomic_rmi2::{AtomicRmi2, Cluster, NetworkModel, NodeId, Suprema, TxCtx};
use std::sync::Arc;

fn main() {
    // A simulated 2-node cluster with LAN-like latency.
    let cluster = Arc::new(Cluster::new(2, NetworkModel::lan()));
    let sys = AtomicRmi2::new(Arc::clone(&cluster));

    // Host shared objects at their home nodes (they never migrate: CF).
    sys.host(NodeId(0), "A", Box::new(Account::with_balance(500)));
    sys.host(NodeId(1), "B", Box::new(Account::with_balance(100)));

    // Fig 9: the preamble declares objects + suprema, then the body runs.
    let mut tx = sys.tx(NodeId(0));
    let a = tx.accesses("A", Suprema::new(1, 0, 1)); // 1 read, 1 update
    let b = tx.updates("B", 1); //                      1 update
    let result = tx.run(|t| {
        t.call(a, ops::withdraw(100))?;
        t.call(b, ops::deposit(100))?;
        if t.call(a, ops::balance())?.as_int() < 0 {
            return t.abort(); // manual rollback, like the paper
        }
        Ok(())
    });

    println!("transaction: {result:?}");
    let oid_a = cluster.registry.locate("A").unwrap();
    let oid_b = cluster.registry.locate("B").unwrap();
    let bal = |oid| {
        sys.with_object(oid, |o| {
            o.as_any().downcast_ref::<Account>().unwrap().balance()
        })
    };
    println!("A = {}, B = {}", bal(oid_a), bal(oid_b));
    assert_eq!(bal(oid_a), 400);
    assert_eq!(bal(oid_b), 200);

    let (msgs, bytes, local) = cluster.stats.snapshot();
    println!("network: {msgs} messages, {bytes} bytes, {local} co-located calls");
    println!(
        "commits = {}, aborts = {}",
        sys.stats.commits.load(std::sync::atomic::Ordering::Relaxed),
        sys.stats.manual_aborts.load(std::sync::atomic::Ordering::Relaxed)
    );
    sys.shutdown();
    println!("quickstart OK");
}
