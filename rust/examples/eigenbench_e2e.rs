//! End-to-end headline run: distributed Eigenbench over **every**
//! framework the paper evaluates, on one scaled-down Fig 10 scenario,
//! printing the paper's comparison table and checking the qualitative
//! claims (the "shape" of §4.3).
//!
//! ```text
//! cargo run --release --example eigenbench_e2e [--quick]
//! ```
//!
//! Scenario (scaled from the paper's 16×64 clients to fit one box):
//! 4 nodes × 4 clients, 10 hot objects/node, 10 ops/txn, 3 read-write
//! ratios (9÷1, 5÷5, 1÷9), 50% locality, history 5, ~3 ms ops (scaled to
//! 1 ms), LAN-model latency. Checks:
//!   1. every framework ≫ GLock;
//!   2. Atomic RMI 2 ≥ Atomic RMI (SVA);
//!   3. Atomic RMI 2 competitive with HyFlow2 (TFA), wins write-heavy;
//!   4. pessimistic frameworks abort 0 transactions, TFA retries under
//!      contention.

use atomic_rmi2::metrics::{fmt_speedup, fmt_throughput, Table};
use atomic_rmi2::workload::{run_eigenbench, EigenbenchParams, FrameworkKind, ALL_FRAMEWORKS};
use atomic_rmi2::NetworkModel;
use std::collections::HashMap;
use std::time::Duration;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (txns, op_delay) = if quick {
        (3u32, Duration::from_micros(200))
    } else {
        (10u32, Duration::from_millis(1))
    };

    let mut table = Table::new(
        "Eigenbench: throughput (shared ops/s), 4 nodes x 4 clients, 10 objects/node",
        &["framework", "9÷1", "5÷5", "1÷9", "aborts", "abort-rate"],
    );
    let mut tput: HashMap<(FrameworkKind, u8), f64> = HashMap::new();

    for kind in ALL_FRAMEWORKS {
        let mut cells = vec![kind.label().to_string()];
        let mut aborts_total = 0u64;
        let mut rate_max = 0.0f64;
        for read_pct in [90u8, 50, 10] {
            let r = run_eigenbench(&EigenbenchParams {
                kind: *kind,
                nodes: 4,
                clients_per_node: 4,
                arrays_per_node: 10,
                txns_per_client: txns,
                hot_ops: 10,
                read_pct,
                op_delay,
                net: NetworkModel::lan(),
                ..Default::default()
            });
            tput.insert((*kind, read_pct), r.throughput);
            cells.push(fmt_throughput(r.throughput));
            aborts_total += r.aborts;
            rate_max = rate_max.max(r.abort_rate);
        }
        cells.push(aborts_total.to_string());
        cells.push(format!("{:.0}%", rate_max * 100.0));
        table.add_row(cells);
        eprintln!("done: {}", kind.label());
    }
    println!("{}", table.render());

    // ---- the paper's qualitative claims ----
    let get = |k: FrameworkKind, r: u8| tput[&(k, r)];
    let mut claims = Vec::new();
    for r in [90u8, 50, 10] {
        claims.push((
            format!("optsva > glock ({r}% reads)"),
            get(FrameworkKind::Optsva, r) > get(FrameworkKind::GLock, r),
        ));
        claims.push((
            format!("optsva >= sva ({r}% reads): {}", fmt_speedup(get(FrameworkKind::Optsva, r), get(FrameworkKind::Sva, r))),
            get(FrameworkKind::Optsva, r) >= 0.95 * get(FrameworkKind::Sva, r),
        ));
    }
    claims.push((
        format!(
            "optsva beats tfa write-heavy: {}",
            fmt_speedup(get(FrameworkKind::Optsva, 10), get(FrameworkKind::Tfa, 10))
        ),
        get(FrameworkKind::Optsva, 10) > 0.9 * get(FrameworkKind::Tfa, 10),
    ));
    let mut all_ok = true;
    for (name, ok) in &claims {
        println!("  [{}] {name}", if *ok { "ok" } else { "FAIL" });
        all_ok &= ok;
    }
    if !all_ok && !quick {
        eprintln!("warning: some qualitative claims did not hold on this run");
    }
    println!("eigenbench_e2e OK");
}
