//! Chrome/Perfetto trace-event JSON exporter.
//!
//! Converts a [`TraceEvent`] stream into the Trace Event Format that
//! `chrome://tracing` and <https://ui.perfetto.dev> load directly: one
//! *process* per simulated node, one *thread* per transaction, complete
//! (`"X"`) spans for transaction lifetime / wait-at-version / exclusive
//! access, and instants (`"i"`) for point events. The document is built on
//! the crate's own [`Json`] model (no serde) and rendered with the same
//! deterministic renderer as the bench reports, so identical event streams
//! produce byte-identical files.

use super::{normalize, EventKind, TraceEvent};
use crate::bench::Json;
use crate::cluster::Oid;
use std::collections::{BTreeMap, BTreeSet};
use std::time::Duration;

/// Thread id used for node-scoped events (messages, executor tasks,
/// fault-detector activity) that belong to no transaction.
const NODE_TID: u64 = 0;

fn us(d: Duration) -> f64 {
    d.as_micros() as f64
}

fn span(name: String, cat: &str, start: Duration, end: Duration, pid: u16, tid: u64) -> Json {
    Json::Obj(vec![
        ("name".into(), Json::Str(name)),
        ("cat".into(), Json::Str(cat.into())),
        ("ph".into(), Json::Str("X".into())),
        ("ts".into(), Json::Num(us(start))),
        ("dur".into(), Json::Num(us(end.saturating_sub(start)))),
        ("pid".into(), Json::Num(pid as f64)),
        ("tid".into(), Json::Num(tid as f64)),
    ])
}

fn instant(name: String, cat: &str, e: &TraceEvent, tid: u64, args: Vec<(String, Json)>) -> Json {
    let mut members = vec![
        ("name".into(), Json::Str(name)),
        ("cat".into(), Json::Str(cat.into())),
        ("ph".into(), Json::Str("i".into())),
        ("ts".into(), Json::Num(us(e.ts))),
        ("pid".into(), Json::Num(e.node as f64)),
        ("tid".into(), Json::Num(tid as f64)),
        ("s".into(), Json::Str("t".into())),
    ];
    if !args.is_empty() {
        members.push(("args".into(), Json::Obj(args)));
    }
    Json::Obj(members)
}

fn metadata(name: &str, pid: u16, tid: Option<u64>, value: String) -> Json {
    let mut members = vec![
        ("name".into(), Json::Str(name.into())),
        ("ph".into(), Json::Str("M".into())),
        ("pid".into(), Json::Num(pid as f64)),
    ];
    if let Some(tid) = tid {
        members.push(("tid".into(), Json::Num(tid as f64)));
    }
    members.push(("args".into(), Json::Obj(vec![("name".into(), Json::Str(value))])));
    Json::Obj(members)
}

/// Export an event stream as a Perfetto/Chrome trace document.
///
/// Timestamps are [`normalize`]d first (strictly increasing in sequence
/// order), so spans stay visible and correctly ordered even when the run's
/// virtual clock never advanced. The output is deterministic: the same
/// event stream renders to the same text.
pub fn export(events: &[TraceEvent]) -> Json {
    let events = normalize(events);
    let mut out: Vec<Json> = Vec::new();

    // (pid, tid) tracks seen, for the metadata block emitted up front.
    let mut tracks: BTreeMap<u16, BTreeSet<u64>> = BTreeMap::new();
    let mut track = |node: u16, tid: u64| {
        tracks.entry(node).or_default().insert(tid);
    };

    // Span state, all keyed deterministically.
    let mut open_tx: BTreeMap<u64, &TraceEvent> = BTreeMap::new();
    let mut open_wait: BTreeMap<(u64, Oid), &TraceEvent> = BTreeMap::new();
    // First object-scoped event per (tx, oid): the exclusive-access span
    // opens there and closes at EarlyRelease — or, failing that, at the
    // transaction's end (commit-time release).
    let mut access_open: BTreeMap<u64, BTreeMap<Oid, (u16, Duration)>> = BTreeMap::new();

    for e in &events {
        let tid = e.kind.tx_id().unwrap_or(NODE_TID);
        track(e.node, tid);
        if let (Some(tx), Some(oid)) = (e.kind.tx_id(), e.kind.oid()) {
            if !matches!(e.kind, EventKind::Rollback { .. }) {
                access_open
                    .entry(tx)
                    .or_default()
                    .entry(oid)
                    .or_insert((e.node, e.ts));
            }
        }
        match &e.kind {
            EventKind::TxBegin { tx, .. } => {
                open_tx.insert(*tx, e);
            }
            EventKind::TxCommit { tx, .. } | EventKind::TxAbort { tx, .. } => {
                let outcome = if matches!(e.kind, EventKind::TxCommit { .. }) {
                    "commit"
                } else {
                    "abort"
                };
                if let Some(begin) = open_tx.remove(tx) {
                    out.push(span(
                        format!("tx{tx} ({outcome})"),
                        "transaction",
                        begin.ts,
                        e.ts,
                        begin.node,
                        *tx,
                    ));
                }
                // Objects the transaction still held: their exclusive
                // access ends with the transaction itself.
                for (oid, (node, start)) in access_open.remove(tx).unwrap_or_default() {
                    out.push(span(format!("access {oid}"), "access", start, e.ts, node, *tx));
                }
                if let EventKind::TxAbort { cause, .. } = &e.kind {
                    out.push(instant(
                        format!("abort: {cause}"),
                        "transaction",
                        e,
                        tid,
                        Vec::new(),
                    ));
                }
            }
            EventKind::WaitStart { tx, oid } => {
                open_wait.insert((*tx, *oid), e);
            }
            EventKind::WaitEnd { tx, oid } => {
                if let Some(start) = open_wait.remove(&(*tx, *oid)) {
                    out.push(span(
                        format!("wait {oid}"),
                        "wait",
                        start.ts,
                        e.ts,
                        start.node,
                        *tx,
                    ));
                }
            }
            EventKind::EarlyRelease { tx, oid, pv } => {
                if let Some((node, start)) =
                    access_open.get_mut(tx).and_then(|m| m.remove(oid))
                {
                    out.push(span(
                        format!("access {oid} (early release)"),
                        "access",
                        start,
                        e.ts,
                        node,
                        *tx,
                    ));
                }
                out.push(instant(
                    format!("early-release {oid}"),
                    "access",
                    e,
                    tid,
                    vec![("pv".into(), Json::Num(*pv as f64))],
                ));
            }
            EventKind::GroupGrant { oid, pv, first_pv, .. } => {
                out.push(instant(
                    format!("group-grant {oid}"),
                    "access",
                    e,
                    tid,
                    vec![
                        ("pv".into(), Json::Num(*pv as f64)),
                        ("group".into(), Json::Num(*first_pv as f64)),
                    ],
                ));
            }
            EventKind::GroupRetire { oid, pv, .. } => {
                out.push(instant(
                    format!("group-retire {oid}"),
                    "access",
                    e,
                    tid,
                    vec![("pv".into(), Json::Num(*pv as f64))],
                ));
            }
            EventKind::BufferRead { oid, .. } | EventKind::BufferCapture { oid, .. } => {
                out.push(instant(format!("{} {oid}", e.kind.label()), "buffer", e, tid, Vec::new()));
            }
            EventKind::Rollback { oid, restored, .. } => {
                out.push(instant(
                    format!("rollback {oid}"),
                    "abort",
                    e,
                    tid,
                    vec![("restored".into(), Json::Bool(*restored))],
                ));
            }
            EventKind::TxRetry { attempt, .. } => {
                out.push(instant(
                    format!("retry (attempt {attempt})"),
                    "transaction",
                    e,
                    tid,
                    Vec::new(),
                ));
            }
            EventKind::MsgSend { from, to, bytes } | EventKind::MsgDeliver { from, to, bytes } => {
                out.push(instant(
                    format!("{} {from}->{to}", e.kind.label()),
                    "net",
                    e,
                    tid,
                    vec![("bytes".into(), Json::Num(*bytes as f64))],
                ));
            }
            EventKind::TaskQueue { .. } | EventKind::TaskRun { .. } => {
                out.push(instant(e.kind.label().into(), "executor", e, tid, Vec::new()));
            }
            EventKind::Evict { oid } => {
                out.push(instant(format!("evict {oid}"), "faults", e, tid, Vec::new()));
            }
            EventKind::FaultScan { evicted } => {
                out.push(instant(
                    "fault-scan".into(),
                    "faults",
                    e,
                    tid,
                    vec![("evicted".into(), Json::Num(*evicted as f64))],
                ));
            }
        }
    }

    // Metadata first, then the content events, so viewers name tracks
    // before populating them.
    let mut doc_events: Vec<Json> = Vec::new();
    for (pid, tids) in &tracks {
        doc_events.push(metadata("process_name", *pid, None, format!("node-{pid}")));
        for tid in tids {
            let name = if *tid == NODE_TID {
                "node".to_string()
            } else {
                format!("tx-{tid}")
            };
            doc_events.push(metadata("thread_name", *pid, Some(*tid), name));
        }
    }
    doc_events.append(&mut out);

    Json::Obj(vec![
        ("traceEvents".into(), Json::Arr(doc_events)),
        ("displayTimeUnit".into(), Json::Str("ms".into())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeId;

    fn ev(seq: u64, us: u64, node: u16, kind: EventKind) -> TraceEvent {
        TraceEvent { seq, ts: Duration::from_micros(us), node, kind }
    }

    fn spans_named(doc: &Json, needle: &str) -> Vec<(f64, f64)> {
        doc.get("traceEvents")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .filter(|e| {
                e.get("ph").and_then(Json::as_str) == Some("X")
                    && e.get("name").and_then(Json::as_str).is_some_and(|n| n.contains(needle))
            })
            .map(|e| {
                (
                    e.get("ts").and_then(Json::as_f64).unwrap(),
                    e.get("dur").and_then(Json::as_f64).unwrap(),
                )
            })
            .collect()
    }

    #[test]
    fn export_builds_tx_wait_and_access_spans() {
        let oid = Oid::new(NodeId(1), 0);
        let events = vec![
            ev(0, 0, 0, EventKind::TxBegin { tx: 1, client: NodeId(0) }),
            ev(1, 0, 1, EventKind::WaitStart { tx: 1, oid }),
            ev(2, 10, 1, EventKind::WaitEnd { tx: 1, oid }),
            ev(3, 20, 1, EventKind::EarlyRelease { tx: 1, oid, pv: 1 }),
            ev(4, 30, 0, EventKind::TxCommit { tx: 1, client: NodeId(0) }),
        ];
        let doc = export(&events);
        let tx = spans_named(&doc, "tx1");
        assert_eq!(tx.len(), 1);
        let wait = spans_named(&doc, "wait n1#0");
        assert_eq!(wait, vec![(1.0, 9.0)], "wait span from normalized WaitStart to WaitEnd");
        let access = spans_named(&doc, "access n1#0");
        assert_eq!(access.len(), 1);
        // Early release: the access span ends strictly before the commit.
        assert!(access[0].0 + access[0].1 < tx[0].0 + tx[0].1);
    }

    #[test]
    fn unreleased_access_closes_at_tx_end_and_doc_parses() {
        let oid = Oid::new(NodeId(0), 0);
        let events = vec![
            ev(0, 0, 0, EventKind::TxBegin { tx: 1, client: NodeId(0) }),
            ev(1, 0, 0, EventKind::BufferCapture { tx: 1, oid }),
            ev(2, 0, 0, EventKind::TxAbort { tx: 1, client: NodeId(0), cause: "manual".into() }),
        ];
        let doc = export(&events);
        let access = spans_named(&doc, "access n0#0");
        let tx = spans_named(&doc, "tx1");
        assert_eq!(access[0].0 + access[0].1, tx[0].0 + tx[0].1, "access ends at abort");
        // The rendered document is valid JSON for the crate's own parser
        // (what CI's artifact validation step checks).
        let text = doc.render();
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }

    #[test]
    fn export_is_deterministic() {
        let oid = Oid::new(NodeId(1), 2);
        let events = vec![
            ev(0, 0, 0, EventKind::TxBegin { tx: 1, client: NodeId(0) }),
            ev(1, 0, 1, EventKind::MsgSend { from: NodeId(0), to: NodeId(1), bytes: 24 }),
            ev(2, 0, 1, EventKind::EarlyRelease { tx: 1, oid, pv: 7 }),
            ev(3, 0, 0, EventKind::TxCommit { tx: 1, client: NodeId(0) }),
        ];
        assert_eq!(export(&events).render(), export(&events).render());
    }
}
