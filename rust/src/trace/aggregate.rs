//! Aggregation over a trace: wait-at-version and exclusive-access-interval
//! histograms, and the `release_shrinkage` metric.
//!
//! `release_shrinkage` quantifies the paper's parallelism mechanism
//! directly: per committed transaction, the fraction of the transaction's
//! lifetime each object was actually held before its early release
//! (`(last early-release − begin) / (commit − begin)`; 1.0 when nothing
//! was released early). A mean shrinkage well below 1.0 is *why* OptSVA-CF
//! outperforms SVA — objects become available to successors while their
//! last user is still running.

use super::{normalize, EventKind, TraceEvent};
use crate::bench::BenchEntry;
use crate::cluster::Oid;
use crate::metrics::Table;
use crate::util::hist::Histogram;
use std::collections::BTreeMap;
use std::time::Duration;

/// Aggregated view of one trace session.
#[derive(Debug, Clone, Default)]
pub struct TraceSummary {
    /// Wait-at-version durations (µs) per object, [`Oid`]-ordered.
    pub wait_per_object: Vec<(Oid, Histogram)>,
    /// Exclusive-access intervals (µs) per object, [`Oid`]-ordered: first
    /// touch by a transaction until its early release (or transaction end).
    pub access_per_object: Vec<(Oid, Histogram)>,
    /// All wait durations merged across objects.
    pub wait_all: Histogram,
    /// All exclusive-access intervals merged across objects.
    pub access_all: Histogram,
    /// Mean over committed transactions of
    /// `(last early-release − begin) / (commit − begin)`; 1.0 when no
    /// transaction released anything early (or nothing committed).
    pub release_shrinkage: f64,
    /// Committed transactions in the trace.
    pub commits: u64,
    /// Aborted transactions (manual, forced, and evictions alike).
    pub aborts: u64,
    /// Retry-driver re-runs.
    pub retries: u64,
    /// Early releases (§2.8 last-use releases, not commit-time ones).
    pub early_releases: u64,
    /// Cross-node messages (sends and deliveries).
    pub messages: u64,
    /// Executor tasks that ran.
    pub tasks_run: u64,
    /// Total events aggregated.
    pub events: u64,
}

/// Build the [`TraceSummary`] of an event stream. Consumes
/// [`normalize`]d timestamps, so interval *ordering* is meaningful even
/// when the traced run's virtual clock never advanced.
pub fn summarize(events: &[TraceEvent]) -> TraceSummary {
    let events = normalize(events);
    let mut s = TraceSummary { events: events.len() as u64, ..TraceSummary::default() };

    let mut waits: BTreeMap<Oid, Histogram> = BTreeMap::new();
    let mut access: BTreeMap<Oid, Histogram> = BTreeMap::new();
    let mut open_wait: BTreeMap<(u64, Oid), Duration> = BTreeMap::new();
    let mut access_open: BTreeMap<u64, BTreeMap<Oid, Duration>> = BTreeMap::new();
    // tx → (begin ts, last early-release ts).
    let mut tx_begin: BTreeMap<u64, Duration> = BTreeMap::new();
    let mut tx_release: BTreeMap<u64, Duration> = BTreeMap::new();
    let mut shrinkages: Vec<f64> = Vec::new();

    let mut record_interval = |map: &mut BTreeMap<Oid, Histogram>, oid: Oid, d: Duration| {
        map.entry(oid).or_default().record_duration(d);
    };

    for e in &events {
        if let (Some(tx), Some(oid)) = (e.kind.tx_id(), e.kind.oid()) {
            if !matches!(e.kind, EventKind::Rollback { .. }) {
                access_open.entry(tx).or_default().entry(oid).or_insert(e.ts);
            }
        }
        match &e.kind {
            EventKind::TxBegin { tx, .. } => {
                tx_begin.insert(*tx, e.ts);
            }
            EventKind::TxCommit { tx, .. } | EventKind::TxAbort { tx, .. } => {
                for (oid, start) in access_open.remove(tx).unwrap_or_default() {
                    record_interval(&mut access, oid, e.ts.saturating_sub(start));
                }
                match &e.kind {
                    EventKind::TxCommit { .. } => {
                        s.commits += 1;
                        if let Some(begin) = tx_begin.remove(tx) {
                            let full = e.ts.saturating_sub(begin).as_micros() as f64;
                            let held = tx_release
                                .remove(tx)
                                .map(|r| r.saturating_sub(begin).as_micros() as f64);
                            shrinkages.push(match held {
                                Some(h) if full > 0.0 => (h / full).min(1.0),
                                _ => 1.0,
                            });
                        }
                    }
                    _ => {
                        s.aborts += 1;
                        tx_begin.remove(tx);
                        tx_release.remove(tx);
                    }
                }
            }
            EventKind::TxRetry { .. } => s.retries += 1,
            EventKind::WaitStart { tx, oid } => {
                open_wait.insert((*tx, *oid), e.ts);
            }
            EventKind::WaitEnd { tx, oid } => {
                if let Some(start) = open_wait.remove(&(*tx, *oid)) {
                    record_interval(&mut waits, *oid, e.ts.saturating_sub(start));
                }
            }
            EventKind::EarlyRelease { tx, oid, .. } => {
                s.early_releases += 1;
                tx_release.insert(*tx, e.ts);
                if let Some(start) = access_open.get_mut(tx).and_then(|m| m.remove(oid)) {
                    record_interval(&mut access, *oid, e.ts.saturating_sub(start));
                }
            }
            EventKind::MsgSend { .. } | EventKind::MsgDeliver { .. } => s.messages += 1,
            EventKind::TaskRun { .. } => s.tasks_run += 1,
            _ => {}
        }
    }

    for h in waits.values() {
        s.wait_all.merge(h);
    }
    for h in access.values() {
        s.access_all.merge(h);
    }
    s.wait_per_object = waits.into_iter().collect();
    s.access_per_object = access.into_iter().collect();
    s.release_shrinkage = if shrinkages.is_empty() {
        1.0
    } else {
        shrinkages.iter().sum::<f64>() / shrinkages.len() as f64
    };
    s
}

impl TraceSummary {
    /// Per-object wait/access quantile table for console output.
    pub fn table(&self, title: impl Into<String>) -> Table {
        let mut t = Table::new(
            title,
            &["object", "waits", "wait_p50_us", "wait_p99_us", "access_p50_us", "access_p99_us"],
        );
        let empty = Histogram::new();
        let oids: Vec<Oid> = self
            .wait_per_object
            .iter()
            .map(|(o, _)| *o)
            .chain(self.access_per_object.iter().map(|(o, _)| *o))
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect();
        for oid in oids {
            let w = self
                .wait_per_object
                .iter()
                .find(|(o, _)| *o == oid)
                .map_or(&empty, |(_, h)| h);
            let a = self
                .access_per_object
                .iter()
                .find(|(o, _)| *o == oid)
                .map_or(&empty, |(_, h)| h);
            t.add_row(vec![
                oid.to_string(),
                w.count().to_string(),
                w.quantile(0.5).to_string(),
                w.quantile(0.99).to_string(),
                a.quantile(0.5).to_string(),
                a.quantile(0.99).to_string(),
            ]);
        }
        t
    }

    /// The summary as a [`BenchEntry`] in the `bench::report` schema, for
    /// `BENCH_trace.json` emission by the `trace` CLI.
    pub fn bench_entry(&self, name: impl Into<String>) -> BenchEntry {
        BenchEntry::new(name)
            .metric("release_shrinkage", self.release_shrinkage)
            .metric("wait_p50_us", self.wait_all.quantile(0.5) as f64)
            .metric("wait_p99_us", self.wait_all.quantile(0.99) as f64)
            .metric("access_p50_us", self.access_all.quantile(0.5) as f64)
            .metric("access_p99_us", self.access_all.quantile(0.99) as f64)
            .metric("commits", self.commits as f64)
            .metric("aborts", self.aborts as f64)
            .metric("retries", self.retries as f64)
            .metric("early_releases", self.early_releases as f64)
            .metric("messages", self.messages as f64)
            .metric("tasks_run", self.tasks_run as f64)
            .metric("events", self.events as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeId;

    fn ev(seq: u64, us: u64, kind: EventKind) -> TraceEvent {
        TraceEvent { seq, ts: Duration::from_micros(us), node: 0, kind }
    }

    #[test]
    fn wait_and_access_histograms_and_shrinkage() {
        let oid = Oid::new(NodeId(0), 0);
        let events = vec![
            ev(0, 0, EventKind::TxBegin { tx: 1, client: NodeId(0) }),
            ev(1, 10, EventKind::WaitStart { tx: 1, oid }),
            ev(2, 110, EventKind::WaitEnd { tx: 1, oid }),
            ev(3, 150, EventKind::EarlyRelease { tx: 1, oid, pv: 1 }),
            ev(4, 400, EventKind::TxCommit { tx: 1, client: NodeId(0) }),
        ];
        let s = summarize(&events);
        assert_eq!(s.commits, 1);
        assert_eq!(s.early_releases, 1);
        assert_eq!(s.wait_all.count(), 1);
        assert!(s.wait_all.max() >= 96, "wait ≈ 100 µs, got {}", s.wait_all.max());
        assert_eq!(s.access_all.count(), 1);
        // Held 150 µs of a 400 µs transaction.
        assert!((s.release_shrinkage - 0.375).abs() < 0.01, "{}", s.release_shrinkage);
        assert!(s.release_shrinkage < 1.0);
        let entry = s.bench_entry("probe");
        assert_eq!(entry.get("commits"), Some(1.0));
        assert_eq!(entry.get("release_shrinkage"), Some(s.release_shrinkage));
    }

    #[test]
    fn no_early_release_means_shrinkage_one() {
        let oid = Oid::new(NodeId(0), 0);
        let events = vec![
            ev(0, 0, EventKind::TxBegin { tx: 1, client: NodeId(0) }),
            ev(1, 5, EventKind::BufferCapture { tx: 1, oid }),
            ev(2, 50, EventKind::TxCommit { tx: 1, client: NodeId(0) }),
        ];
        let s = summarize(&events);
        assert_eq!(s.release_shrinkage, 1.0);
        assert_eq!(s.access_all.count(), 1, "access interval closed at commit");
    }

    #[test]
    fn aborted_transactions_do_not_skew_shrinkage() {
        let oid = Oid::new(NodeId(0), 0);
        let events = vec![
            ev(0, 0, EventKind::TxBegin { tx: 1, client: NodeId(0) }),
            ev(1, 1, EventKind::EarlyRelease { tx: 1, oid, pv: 1 }),
            ev(2, 2, EventKind::TxAbort { tx: 1, client: NodeId(0), cause: "manual".into() }),
        ];
        let s = summarize(&events);
        assert_eq!(s.aborts, 1);
        assert_eq!(s.release_shrinkage, 1.0, "only committed txs contribute");
        assert!(!s.table("t").is_empty());
    }

    #[test]
    fn zero_duration_trace_still_summarizes() {
        // All-zero virtual timestamps: normalize gives seq-order ticks, so
        // shrinkage is still strictly < 1.0 when an early release exists.
        let oid = Oid::new(NodeId(0), 0);
        let events = vec![
            ev(0, 0, EventKind::TxBegin { tx: 1, client: NodeId(0) }),
            ev(1, 0, EventKind::EarlyRelease { tx: 1, oid, pv: 1 }),
            ev(2, 0, EventKind::TxCommit { tx: 1, client: NodeId(0) }),
        ];
        let s = summarize(&events);
        assert!(s.release_shrinkage < 1.0, "{}", s.release_shrinkage);
    }
}
