//! Virtual-time-aware structured tracing for the whole substrate.
//!
//! The paper's parallelism claim rests on *shrinking each object's interval
//! of exclusive access* — buffering, early release, asynchrony (§2.6–§2.8).
//! The bench reports measure the end effect (throughput); this module makes
//! the mechanism itself observable: every layer of the stack emits typed
//! [`TraceEvent`]s — transaction lifecycle (`optsva::transaction`),
//! per-object access incl. the headline **early release** span
//! (`optsva::proxy`), message send/deliver (`cluster`), task queue/run
//! (`executor`), and fault-detector evictions (`faults`) — into a sharded
//! process-global recorder. On top of the stream sit an aggregation pass
//! ([`aggregate`]: per-object wait / exclusive-access histograms and the
//! `release_shrinkage` metric) and a Chrome/Perfetto trace-event exporter
//! ([`perfetto`]). See `docs/OBSERVABILITY.md` for the event catalogue and
//! an import walkthrough.
//!
//! ## Zero cost when off
//!
//! Tracing is gated by one process-global atomic ([`enabled`], a single
//! `Relaxed` load) that every instrumentation point checks **before
//! constructing the event**. With no active [`TraceSession`] the overhead
//! per would-be event is that one load — verified by the `trace_overhead`
//! entry of the `micro` bench against the pre-tracing baseline.
//!
//! ## Determinism
//!
//! Events are stamped with a sequence number (global `fetch_add`) and the
//! session clock's [`Clock::now`]. Under a
//! [`VirtualClock`](crate::clock::VirtualClock) + single-threaded schedule
//! replay (the `analysis` explorer) both stamps are deterministic, so the
//! same `S<seed>` schedule id produces a byte-identical exported trace —
//! regression-tested in `tests/trace_determinism.rs` and re-checked by CI.

pub mod aggregate;
pub mod perfetto;

use crate::clock::Clock;
use crate::cluster::{NodeId, Oid};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, RwLock};
use std::time::Duration;

/// One recorded instrumentation point.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Global sequence number (total order over all shards; deterministic
    /// under single-threaded replay).
    pub seq: u64,
    /// Session-clock timestamp at emission ([`Duration::ZERO`] when no
    /// session clock was installed).
    pub ts: Duration,
    /// Node the event is attributed to (the client node for transaction
    /// events, the home node for object events).
    pub node: u16,
    /// What happened.
    pub kind: EventKind,
}

/// The typed event catalogue (documented in full in
/// `docs/OBSERVABILITY.md`).
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// A transaction acquired its start locks and began executing.
    TxBegin {
        /// Session-unique transaction id (see [`next_tx_id`]).
        tx: u64,
        /// Client node running the transaction.
        client: NodeId,
    },
    /// A transaction committed.
    TxCommit {
        /// Transaction id.
        tx: u64,
        /// Client node.
        client: NodeId,
    },
    /// A transaction aborted (manual, forced, or eviction).
    TxAbort {
        /// Transaction id.
        tx: u64,
        /// Client node.
        client: NodeId,
        /// Render of the [`TxError`](crate::api::TxError) that caused it.
        cause: String,
    },
    /// The retry driver is re-running an aborted transaction body.
    TxRetry {
        /// Client node.
        client: NodeId,
        /// 1-based attempt number that just failed.
        attempt: u64,
    },
    /// A proxy started waiting at its private version (access or commit
    /// condition — the wait-at-version span opens).
    WaitStart {
        /// Transaction id.
        tx: u64,
        /// Object being waited on.
        oid: Oid,
    },
    /// The wait-at-version span closed (access granted or timed out).
    WaitEnd {
        /// Transaction id.
        tx: u64,
        /// Object.
        oid: Oid,
    },
    /// A read was served from the local copy buffer (§2.7 — no
    /// synchronization, no remote call).
    BufferRead {
        /// Transaction id.
        tx: u64,
        /// Object.
        oid: Oid,
    },
    /// The object's state was captured into the transaction-local copy
    /// buffer (§2.6 buffering).
    BufferCapture {
        /// Transaction id.
        tx: u64,
        /// Object.
        oid: Oid,
    },
    /// **Early release** (§2.8): the transaction released the object at its
    /// last use, before committing — the exclusive-access span closes here
    /// instead of at commit.
    EarlyRelease {
        /// Transaction id.
        tx: u64,
        /// Object.
        oid: Oid,
        /// The private version being released.
        pv: u64,
    },
    /// A commuting transaction joined the object's pv-group: it holds the
    /// object *concurrently* with the group's other members instead of at
    /// an exclusive chain position (docs/COMMUTATIVITY.md).
    GroupGrant {
        /// Transaction id.
        tx: u64,
        /// Object.
        oid: Oid,
        /// The member's own private version.
        pv: u64,
        /// The group's chain position (first member's pv).
        first_pv: u64,
    },
    /// The last member of a pv-group terminated: the group dissolved and
    /// the version chain advanced past all of it in one step.
    GroupRetire {
        /// Transaction id of the dissolving member.
        tx: u64,
        /// Object.
        oid: Oid,
        /// The member's own private version.
        pv: u64,
    },
    /// A proxy rolled the object back during abort.
    Rollback {
        /// Transaction id.
        tx: u64,
        /// Object.
        oid: Oid,
        /// Whether the checkpointed state was restored (`false` when the
        /// transaction never modified the object).
        restored: bool,
    },
    /// A cross-node message left its sender (requests, one-way sends).
    MsgSend {
        /// Sending node.
        from: NodeId,
        /// Receiving node.
        to: NodeId,
        /// Payload size.
        bytes: usize,
    },
    /// A cross-node message arrived (responses, pipelined deliveries).
    MsgDeliver {
        /// Sending node.
        from: NodeId,
        /// Receiving node.
        to: NodeId,
        /// Payload size.
        bytes: usize,
    },
    /// An asynchronous task was queued on a node executor (§3.3).
    TaskQueue {
        /// Executor's node.
        node: u16,
    },
    /// A queued executor task's condition held and its action ran.
    TaskRun {
        /// Executor's node.
        node: u16,
    },
    /// The fault detector (§3.4) evicted a stale transaction's proxy.
    Evict {
        /// Object the stale proxy held.
        oid: Oid,
    },
    /// One fault-detector scan completed and evicted `evicted` proxies.
    FaultScan {
        /// Number of proxies evicted by this scan.
        evicted: u64,
    },
}

impl EventKind {
    /// Short stable label for this event kind (timeline + Perfetto names).
    pub fn label(&self) -> &'static str {
        match self {
            EventKind::TxBegin { .. } => "tx-begin",
            EventKind::TxCommit { .. } => "tx-commit",
            EventKind::TxAbort { .. } => "tx-abort",
            EventKind::TxRetry { .. } => "tx-retry",
            EventKind::WaitStart { .. } => "wait-start",
            EventKind::WaitEnd { .. } => "wait-end",
            EventKind::BufferRead { .. } => "buffer-read",
            EventKind::BufferCapture { .. } => "buffer-capture",
            EventKind::EarlyRelease { .. } => "early-release",
            EventKind::GroupGrant { .. } => "group-grant",
            EventKind::GroupRetire { .. } => "group-retire",
            EventKind::Rollback { .. } => "rollback",
            EventKind::MsgSend { .. } => "msg-send",
            EventKind::MsgDeliver { .. } => "msg-deliver",
            EventKind::TaskQueue { .. } => "task-queue",
            EventKind::TaskRun { .. } => "task-run",
            EventKind::Evict { .. } => "evict",
            EventKind::FaultScan { .. } => "fault-scan",
        }
    }

    /// The transaction this event belongs to, if it is transaction-scoped.
    pub fn tx_id(&self) -> Option<u64> {
        match self {
            EventKind::TxBegin { tx, .. }
            | EventKind::TxCommit { tx, .. }
            | EventKind::TxAbort { tx, .. }
            | EventKind::WaitStart { tx, .. }
            | EventKind::WaitEnd { tx, .. }
            | EventKind::BufferRead { tx, .. }
            | EventKind::BufferCapture { tx, .. }
            | EventKind::EarlyRelease { tx, .. }
            | EventKind::GroupGrant { tx, .. }
            | EventKind::GroupRetire { tx, .. }
            | EventKind::Rollback { tx, .. } => Some(*tx),
            _ => None,
        }
    }

    /// The object this event concerns, if it is object-scoped.
    pub fn oid(&self) -> Option<Oid> {
        match self {
            EventKind::WaitStart { oid, .. }
            | EventKind::WaitEnd { oid, .. }
            | EventKind::BufferRead { oid, .. }
            | EventKind::BufferCapture { oid, .. }
            | EventKind::EarlyRelease { oid, .. }
            | EventKind::GroupGrant { oid, .. }
            | EventKind::GroupRetire { oid, .. }
            | EventKind::Rollback { oid, .. }
            | EventKind::Evict { oid } => Some(*oid),
            _ => None,
        }
    }
}

impl std::fmt::Display for EventKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EventKind::TxBegin { tx, client } => write!(f, "tx{tx}@{client} begin"),
            EventKind::TxCommit { tx, client } => write!(f, "tx{tx}@{client} commit"),
            EventKind::TxAbort { tx, client, cause } => {
                write!(f, "tx{tx}@{client} abort ({cause})")
            }
            EventKind::TxRetry { client, attempt } => {
                write!(f, "{client} retry after attempt {attempt}")
            }
            EventKind::WaitStart { tx, oid } => write!(f, "tx{tx} wait {oid} start"),
            EventKind::WaitEnd { tx, oid } => write!(f, "tx{tx} wait {oid} end"),
            EventKind::BufferRead { tx, oid } => write!(f, "tx{tx} buffer-read {oid}"),
            EventKind::BufferCapture { tx, oid } => write!(f, "tx{tx} buffer-capture {oid}"),
            EventKind::EarlyRelease { tx, oid, pv } => {
                write!(f, "tx{tx} early-release {oid} pv={pv}")
            }
            EventKind::GroupGrant { tx, oid, pv, first_pv } => {
                write!(f, "tx{tx} group-grant {oid} pv={pv} group@{first_pv}")
            }
            EventKind::GroupRetire { tx, oid, pv } => {
                write!(f, "tx{tx} group-retire {oid} pv={pv}")
            }
            EventKind::Rollback { tx, oid, restored } => {
                write!(f, "tx{tx} rollback {oid} restored={restored}")
            }
            EventKind::MsgSend { from, to, bytes } => write!(f, "{from}->{to} send {bytes}B"),
            EventKind::MsgDeliver { from, to, bytes } => {
                write!(f, "{from}->{to} deliver {bytes}B")
            }
            EventKind::TaskQueue { node } => write!(f, "n{node} task queued"),
            EventKind::TaskRun { node } => write!(f, "n{node} task ran"),
            EventKind::Evict { oid } => write!(f, "evict stale proxy of {oid}"),
            EventKind::FaultScan { evicted } => write!(f, "fault scan evicted {evicted}"),
        }
    }
}

// ---------------------------------------------------------------------
// Recorder
// ---------------------------------------------------------------------

/// Shards the recorder fans events over (keyed `node % NSHARDS`), bounding
/// lock contention when many client threads trace concurrently.
const NSHARDS: usize = 16;

/// Per-shard ring capacity; events past it are counted in
/// [`dropped_events`] rather than growing without bound.
const SHARD_CAP: usize = 1 << 16;

struct Recorder {
    gate: AtomicU8,
    seq: AtomicU64,
    tx_ids: AtomicU64,
    dropped: AtomicU64,
    shards: Vec<Mutex<Vec<TraceEvent>>>,
    clock: RwLock<Option<Arc<dyn Clock>>>,
}

fn recorder() -> &'static Recorder {
    static RECORDER: OnceLock<Recorder> = OnceLock::new();
    RECORDER.get_or_init(|| Recorder {
        gate: AtomicU8::new(0),
        seq: AtomicU64::new(0),
        tx_ids: AtomicU64::new(1),
        dropped: AtomicU64::new(0),
        shards: (0..NSHARDS).map(|_| Mutex::new(Vec::new())).collect(),
        clock: RwLock::new(None),
    })
}

/// Is a trace session active? One `Relaxed` atomic load — every
/// instrumentation point checks this *before* constructing its event, so
/// the disabled path costs nothing else.
#[inline]
pub fn enabled() -> bool {
    recorder().gate.load(Ordering::Relaxed) != 0
}

/// Record one event, stamped with the next global sequence number and the
/// session clock. No-op (after the gate load) when tracing is off.
pub fn emit(node: u16, kind: EventKind) {
    let r = recorder();
    if r.gate.load(Ordering::Relaxed) == 0 {
        return;
    }
    let ts = r
        .clock
        .read()
        .unwrap()
        .as_ref()
        .map_or(Duration::ZERO, |c| c.now());
    let seq = r.seq.fetch_add(1, Ordering::Relaxed);
    let mut shard = r.shards[node as usize % NSHARDS].lock().unwrap();
    if shard.len() >= SHARD_CAP {
        drop(shard);
        r.dropped.fetch_add(1, Ordering::Relaxed);
        return;
    }
    shard.push(TraceEvent { seq, ts, node, kind });
}

/// Allocate a session-unique transaction id (used by `Transaction::begin`
/// to correlate lifecycle and per-object events).
pub fn next_tx_id() -> u64 {
    recorder().tx_ids.fetch_add(1, Ordering::Relaxed)
}

/// Install the clock events of the current session are stamped with
/// (typically the traced cluster's [`VirtualClock`](crate::clock::VirtualClock)).
/// Events emitted before this call carry [`Duration::ZERO`].
pub fn set_session_clock(clock: Arc<dyn Clock>) {
    *recorder().clock.write().unwrap() = Some(clock);
}

/// Events dropped because a shard hit its capacity during this session.
/// Non-zero means the trace is truncated — surfaced by the `trace` CLI.
pub fn dropped_events() -> u64 {
    recorder().dropped.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------
// Session
// ---------------------------------------------------------------------

static SESSION: Mutex<()> = Mutex::new(());

/// RAII guard over one tracing session.
///
/// [`TraceSession::start`] clears the recorder, resets sequence/transaction
/// counters, and flips the global gate on; [`TraceSession::finish`] (or
/// drop) flips it off. The recorder is process-global, so sessions are
/// serialized through an internal lock — two concurrent `start` calls
/// (e.g. `cargo test` threads) queue rather than interleave their events.
pub struct TraceSession {
    _serial: MutexGuard<'static, ()>,
}

impl TraceSession {
    /// Begin a session: blocks until any other session finishes, then
    /// resets the recorder and enables the gate.
    pub fn start() -> TraceSession {
        // A panicking traced test must not poison tracing for the rest of
        // the process; the guard's only job is mutual exclusion.
        let serial = SESSION.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let r = recorder();
        for shard in &r.shards {
            shard.lock().unwrap().clear();
        }
        r.seq.store(0, Ordering::SeqCst);
        r.tx_ids.store(1, Ordering::SeqCst);
        r.dropped.store(0, Ordering::SeqCst);
        *r.clock.write().unwrap() = None;
        r.gate.store(1, Ordering::SeqCst);
        TraceSession { _serial: serial }
    }

    /// End the session and return its events, sorted by sequence number.
    pub fn finish(self) -> Vec<TraceEvent> {
        let r = recorder();
        r.gate.store(0, Ordering::SeqCst);
        let mut events = Vec::new();
        for shard in &r.shards {
            events.append(&mut shard.lock().unwrap());
        }
        events.sort_by_key(|e| e.seq);
        events
        // `self` drops here: the gate is already off, Drop just clears the
        // session clock and releases the serialization lock.
    }
}

impl Drop for TraceSession {
    fn drop(&mut self) {
        let r = recorder();
        r.gate.store(0, Ordering::SeqCst);
        *r.clock.write().unwrap() = None;
    }
}

// ---------------------------------------------------------------------
// Post-processing
// ---------------------------------------------------------------------

/// Timestamps made strictly increasing in sequence order.
///
/// Under the explorer's `VirtualClock` + instant network, simulated time
/// may never advance — every event would carry `ts = 0` and all spans
/// would collapse. This pass keeps real timestamps where the clock moved
/// and breaks ties by sequence order (each tied event lands 1 µs after its
/// predecessor), so span *ordering* — e.g. "early release strictly before
/// commit" — survives export unconditionally. Both the Perfetto exporter
/// and the aggregation pass consume normalized events.
pub fn normalize(events: &[TraceEvent]) -> Vec<TraceEvent> {
    let mut out = events.to_vec();
    out.sort_by_key(|e| e.seq);
    let mut last: Option<u64> = None;
    for e in &mut out {
        let mut us = e.ts.as_micros() as u64;
        if let Some(prev) = last {
            if us <= prev {
                us = prev + 1;
            }
        }
        last = Some(us);
        e.ts = Duration::from_micros(us);
    }
    out
}

/// Human-readable dump of an event stream, one line per event — what
/// `atomic-rmi2 check --schedule S<seed> --timeline` prints for a
/// violation replay.
pub fn render_timeline(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for e in normalize(events) {
        out.push_str(&format!(
            "{:>6}  +{:<10} n{:<3} {}\n",
            e.seq,
            format!("{}us", e.ts.as_micros()),
            e.node,
            e.kind
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Marker node: while one of these sessions is open, *other* unit
    /// tests in this binary may run real transactions and emit into it.
    /// No real component uses node ids this large, so filtering on the
    /// marker keeps the assertions immune to that concurrency.
    const M: u16 = 40_000;

    fn marked(events: &[TraceEvent]) -> Vec<TraceEvent> {
        events.iter().filter(|e| e.node >= M).cloned().collect()
    }

    #[test]
    fn gate_off_means_no_events() {
        // No session: emit must be a no-op (and cheap).
        emit(M, EventKind::TaskQueue { node: M });
        let session = TraceSession::start();
        assert!(enabled());
        let events = session.finish();
        assert!(marked(&events).is_empty(), "pre-session emits must not leak in");
    }

    #[test]
    fn events_are_recorded_in_sequence_order_across_shards() {
        let session = TraceSession::start();
        for i in 0..40u16 {
            // 40 consecutive node ids touch every shard.
            emit(M + i, EventKind::TaskQueue { node: M + i });
        }
        let events = marked(&session.finish());
        assert_eq!(events.len(), 40);
        for (i, pair) in events.windows(2).enumerate() {
            assert!(pair[0].seq < pair[1].seq, "seq order lost at {i}");
        }
        for (i, e) in events.iter().enumerate() {
            assert_eq!(e.node, M + i as u16, "emission order lost");
        }
    }

    #[test]
    fn session_resets_counters() {
        let session = TraceSession::start();
        let id1 = next_tx_id();
        assert!(id1 >= 1);
        emit(M, EventKind::TxBegin { tx: id1, client: NodeId(0) });
        drop(session);
        // Restart: prior session's events are gone. (Transaction-id
        // restart shows up as byte-identical re-exports, pinned by the
        // `trace_determinism` integration suite where nothing runs
        // concurrently.)
        let session = TraceSession::start();
        let events = session.finish();
        assert!(marked(&events).is_empty(), "start clears prior session's events");
    }

    #[test]
    fn normalize_breaks_ties_and_preserves_real_gaps() {
        let ev = |seq, us| TraceEvent {
            seq,
            ts: Duration::from_micros(us),
            node: 0,
            kind: EventKind::TaskRun { node: 0 },
        };
        let n = normalize(&[ev(0, 0), ev(1, 0), ev(2, 0), ev(3, 500), ev(4, 500)]);
        let us: Vec<u64> = n.iter().map(|e| e.ts.as_micros() as u64).collect();
        assert_eq!(us, vec![0, 1, 2, 500, 501]);
    }

    #[test]
    fn timeline_renders_every_event() {
        let session = TraceSession::start();
        emit(M, EventKind::TxBegin { tx: 1, client: NodeId(0) });
        emit(
            M,
            EventKind::EarlyRelease { tx: 1, oid: Oid::new(NodeId(1), 0), pv: 3 },
        );
        emit(M, EventKind::TxCommit { tx: 1, client: NodeId(0) });
        let events = marked(&session.finish());
        let tl = render_timeline(&events);
        assert_eq!(tl.lines().count(), 3);
        assert!(tl.contains("early-release n1#0 pv=3"), "{tl}");
        assert!(tl.contains("tx1@n0 commit"), "{tl}");
    }
}
