//! **OptSVA-CF / Atomic RMI 2** — the paper's contribution (§2, §3).
//!
//! A pessimistic, abort-free (unless manually aborted) DTM for the
//! control-flow model, built from:
//!   * supremum versioning (`versioning`) for ordering,
//!   * copy/log buffers (`buffers`) for invisible local operations,
//!   * a per-node executor (`executor`) for asynchronous buffering and
//!     asynchronous last-write release,
//!   * per-(transaction, object) server-side proxies (`proxy`) that inject
//!     the concurrency control around method dispatch — the rust analogue
//!     of Atomic RMI 2's reflection proxies (§3.1).
//!
//! Layout mirrors the paper's architecture diagram (Fig 6): client-side
//! `Transaction` objects drive server-side proxies; buffers live with the
//! objects at their home nodes.

pub mod proxy;
pub mod transaction;

pub use proxy::{Proxy, ProxyConfig};
pub use transaction::Transaction;

use crate::api::{run_with_retries, Dtm, TxCtx, TxError, TxSpec, TxStats};
use crate::cluster::{Cluster, NodeId, Oid, Registry};
use crate::executor::{Executor, ExecutorPool};
use crate::object::SharedObject;
use crate::versioning::ObjectCc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// A hosted shared object and its concurrency-control block.
pub struct ObjectSlot {
    /// Identity of the hosted object (home node + slot index).
    pub oid: Oid,
    /// Supremum-versioning counters guarding this object (§2.3).
    pub cc: ObjectCc,
    /// The object's interface, cached at hosting time so method-mode
    /// lookups never contend on the object lock (operation bodies can
    /// hold it for milliseconds).
    pub interface: &'static [crate::object::MethodSpec],
    /// Name → interface-position table, built once at hosting time.
    /// Submit paths stamp unindexed [`crate::object::OpCall`]s through it,
    /// so the dispatch hot path resolves method specs in O(1) instead of
    /// scanning the interface per operation.
    pub methods: crate::cluster::registry::MethodTable,
    /// The live object. Locked for the duration of each method body.
    pub object: Mutex<Box<dyn SharedObject>>,
    /// Crash-stop flag (§3.4): once set, every access raises
    /// `TxError::ObjectCrashed`.
    pub crashed: AtomicBool,
    /// Live proxies linked to this object (weak: a proxy dies with its
    /// transaction). Scanned by the failure detector (§3.4).
    pub(crate) active: Mutex<Vec<std::sync::Weak<Proxy>>>,
}

impl ObjectSlot {
    fn new(
        oid: Oid,
        object: Box<dyn SharedObject>,
        clock: Arc<dyn crate::clock::Clock>,
    ) -> Arc<Self> {
        let interface = object.interface();
        Arc::new(ObjectSlot {
            oid,
            cc: ObjectCc::with_clock(clock),
            interface,
            methods: crate::cluster::registry::MethodTable::new(interface),
            object: Mutex::new(object),
            crashed: AtomicBool::new(false),
            active: Mutex::new(Vec::new()),
        })
    }

    /// Fail with [`TxError::ObjectCrashed`] if this object has crash-stopped.
    pub fn check_alive(&self) -> Result<(), TxError> {
        if self.crashed.load(Ordering::Acquire) {
            Err(TxError::ObjectCrashed(self.oid))
        } else {
            Ok(())
        }
    }
}

struct NodeState {
    slots: RwLock<Vec<Arc<ObjectSlot>>>,
    executor: Arc<Executor>,
}

/// System-wide counters (benchmark reporting; Fig 13's abort rows).
#[derive(Default)]
pub struct SysStats {
    /// Successfully committed transactions.
    pub commits: AtomicU64,
    /// Programmatic aborts requested by transaction bodies.
    pub manual_aborts: AtomicU64,
    /// Aborts forced by cascades, invalidation or failure suspicion.
    pub forced_aborts: AtomicU64,
    /// Objects released before their transaction terminated (§2.8).
    pub early_releases: AtomicU64,
    /// Buffering / release tasks handed to node executors (§3.3).
    pub async_tasks: AtomicU64,
    /// Checkpoint/buffer snapshots taken (`CopyBuffer::capture` on the
    /// proxy paths). The `state_size`-aware capture skips (blind-write
    /// finalization, commuting group grants) show up as this *not*
    /// incrementing — regression-tested by `tests/fig12_captures.rs`.
    pub captures: AtomicU64,
    /// Total bytes snapshotted by those captures (`state_size` at capture
    /// time).
    pub capture_bytes: AtomicU64,
    /// Commuting group grants issued (docs/COMMUTATIVITY.md).
    pub group_grants: AtomicU64,
}

/// A deliberately seeded protocol defect, used to validate the schedule
/// explorer (`analysis::`): a correct checker must catch each of these
/// within the seed budget. `None` is the real protocol.
///
/// The mutations are confined to [`proxy`] and are inert unless an
/// instance is built with [`AtomicRmi2::for_analysis`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ProtocolMutation {
    /// The real, unmutated protocol.
    #[default]
    None,
    /// Release an update-mode object one operation *before* its declared
    /// supremum is reached (§2.8.3 done wrong): a successor can observe
    /// state the transaction will still change, so a stale copy buffer or
    /// a dirty read becomes visible — a last-use-opacity violation.
    PrematureRelease,
    /// Skip `mark_invalid` during rollback (§2.7 done wrong): successors
    /// that consumed the aborted transaction's writes via early release
    /// are never cascade-aborted and commit dirty state.
    SkipInvalidation,
    /// Trust commutativity declarations blindly (docs/COMMUTATIVITY.md
    /// done wrong): a transaction invoking a commuting-class method joins
    /// the pv-group regardless of its read/write suprema, and the group
    /// grant is treated as exclusive direct access — so its *reads*
    /// execute on the live object while other members are still mutating
    /// it, an unserialized observation the opacity checker must flag.
    BogusCommute,
}

impl ProtocolMutation {
    /// Parse the CLI spelling (`none` / `premature-release` /
    /// `skip-invalidation`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "none" => Some(ProtocolMutation::None),
            "premature-release" => Some(ProtocolMutation::PrematureRelease),
            "skip-invalidation" => Some(ProtocolMutation::SkipInvalidation),
            "bogus-commute" => Some(ProtocolMutation::BogusCommute),
            _ => None,
        }
    }

    /// The CLI spelling.
    pub fn label(&self) -> &'static str {
        match self {
            ProtocolMutation::None => "none",
            ProtocolMutation::PrematureRelease => "premature-release",
            ProtocolMutation::SkipInvalidation => "skip-invalidation",
            ProtocolMutation::BogusCommute => "bogus-commute",
        }
    }
}

/// Tuning knobs for the OptSVA-CF instance.
#[derive(Debug, Clone, Copy)]
pub struct OptsvaConfig {
    /// Failure-suspicion deadline for versioning waits (§3.4). `None`
    /// disables suspicion (waits are unbounded).
    pub wait_timeout: Option<Duration>,
    /// Disable the asynchronous read-only buffering and last-write release
    /// optimizations (ablation benches): tasks still run, but inline.
    pub asynchrony: bool,
}

impl Default for OptsvaConfig {
    fn default() -> Self {
        OptsvaConfig { wait_timeout: Some(Duration::from_secs(60)), asynchrony: true }
    }
}

/// The Atomic RMI 2 system: hosts objects across the simulated cluster and
/// creates OptSVA-CF transactions.
pub struct AtomicRmi2 {
    cluster: Arc<Cluster>,
    nodes: Vec<NodeState>,
    /// Work-stealing pool backing the node executors (`None` in the
    /// explorer's manual mode, where tasks are scheduling decisions).
    pool: Option<Arc<ExecutorPool>>,
    /// System-wide commit/abort/release counters.
    pub stats: Arc<SysStats>,
    config: OptsvaConfig,
    /// Seeded protocol defect ([`ProtocolMutation::None`] outside the
    /// schedule explorer's mutation-validation runs).
    mutation: ProtocolMutation,
}

impl AtomicRmi2 {
    /// Stand up the system on `cluster` with the default configuration.
    pub fn new(cluster: Arc<Cluster>) -> Arc<Self> {
        Self::with_config(cluster, OptsvaConfig::default())
    }

    /// Stand up the system on `cluster` with explicit tuning knobs.
    ///
    /// Node executors are shards of one work-stealing [`ExecutorPool`]
    /// (one queue per node, at most `MAX_POOL_WORKERS` worker threads),
    /// so a single process can instantiate 10²–10³ simulated nodes
    /// without a thread per node.
    pub fn with_config(cluster: Arc<Cluster>, config: OptsvaConfig) -> Arc<Self> {
        let pool = ExecutorPool::start(cluster.node_count() as usize);
        let nodes = cluster
            .node_ids()
            .map(|node| {
                let executor = pool.executor(node.0 as usize);
                executor.set_trace_label(node);
                NodeState { slots: RwLock::new(Vec::new()), executor }
            })
            .collect();
        Arc::new(AtomicRmi2 {
            cluster,
            nodes,
            pool: Some(pool),
            stats: Arc::new(SysStats::default()),
            config,
            mutation: ProtocolMutation::None,
        })
    }

    /// Stand up the system for the schedule explorer: node executors run
    /// in manual (threadless) mode so every asynchronous task becomes an
    /// explicit scheduling decision, and `mutation` optionally seeds a
    /// protocol defect. Production code wants [`AtomicRmi2::with_config`].
    pub fn for_analysis(
        cluster: Arc<Cluster>,
        config: OptsvaConfig,
        mutation: ProtocolMutation,
    ) -> Arc<Self> {
        let nodes = cluster
            .node_ids()
            .map(|node| {
                let executor = Executor::manual();
                executor.set_trace_label(node);
                NodeState { slots: RwLock::new(Vec::new()), executor }
            })
            .collect();
        Arc::new(AtomicRmi2 {
            cluster,
            nodes,
            pool: None,
            stats: Arc::new(SysStats::default()),
            config,
            mutation,
        })
    }

    /// The simulated cluster this system runs on.
    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    /// The configuration this system was stood up with.
    pub fn config(&self) -> OptsvaConfig {
        self.config
    }

    /// Host `object` on `node` under `name`; registers it and wires its
    /// version counters to the node's executor signal.
    pub fn host(&self, node: NodeId, name: &str, object: Box<dyn SharedObject>) -> Oid {
        let state = &self.nodes[node.0 as usize];
        let mut slots = state.slots.write().unwrap();
        let oid = Oid::new(node, slots.len() as u32);
        let slot = ObjectSlot::new(oid, object, Arc::clone(self.cluster.clock()));
        slot.cc.watch(state.executor.signal());
        slots.push(slot);
        drop(slots);
        self.cluster.registry.bind(name, oid);
        oid
    }

    /// Resolve an object id to its slot.
    pub fn slot(&self, oid: Oid) -> Arc<ObjectSlot> {
        let state = &self.nodes[oid.node.0 as usize];
        let slots = state.slots.read().unwrap();
        Arc::clone(&slots[oid.index as usize])
    }

    /// The executor of the node hosting `oid`.
    pub(crate) fn executor_of(&self, node: NodeId) -> Arc<Executor> {
        Arc::clone(&self.nodes[node.0 as usize].executor)
    }

    /// Begin building a transaction from `client` (the concrete OptSVA-CF
    /// preamble; the framework-agnostic front end is
    /// `(dyn Dtm).tx(client)` from [`crate::api::TxBuilder`]).
    pub fn tx(self: &Arc<Self>, client: NodeId) -> Transaction {
        Transaction::new(Arc::clone(self), client)
    }

    /// Inject a crash-stop failure on an object (§3.4, fault testing).
    pub fn crash_object(&self, oid: Oid) {
        self.slot(oid).crashed.store(true, Ordering::Release);
        self.cluster.registry.unbind(
            &self
                .cluster
                .registry
                .names_on(oid.node)
                .into_iter()
                .find(|n| self.cluster.registry.locate(n) == Some(oid))
                .unwrap_or_default(),
        );
    }

    /// Every hosted slot (failure detector, diagnostics).
    pub fn all_slots(&self) -> Vec<Arc<ObjectSlot>> {
        self.nodes
            .iter()
            .flat_map(|n| n.slots.read().unwrap().iter().cloned().collect::<Vec<_>>())
            .collect()
    }

    /// Shut down all node executors (drains queues). With a pool this
    /// marks every shard shut down and joins the workers; in manual mode
    /// it falls back to per-executor shutdown.
    pub fn shutdown(&self) {
        match &self.pool {
            Some(pool) => pool.shutdown(),
            None => {
                for n in &self.nodes {
                    n.executor.shutdown();
                }
            }
        }
    }

    /// Peek at an object's state (test/diagnostic helper; **not**
    /// transactional — do not call while transactions are running).
    pub fn with_object<R>(&self, oid: Oid, f: impl FnOnce(&dyn SharedObject) -> R) -> R {
        let slot = self.slot(oid);
        let obj = slot.object.lock().unwrap();
        f(obj.as_ref())
    }
}

impl Dtm for Arc<AtomicRmi2> {
    fn framework_name(&self) -> &'static str {
        "atomic-rmi2 (OptSVA-CF)"
    }

    fn registry(&self) -> Option<&Registry> {
        Some(&self.cluster.registry)
    }

    fn run_tx(
        &self,
        client: NodeId,
        spec: &TxSpec,
        body: &mut dyn FnMut(&mut dyn TxCtx) -> Result<(), TxError>,
    ) -> Result<TxStats, TxError> {
        run_with_retries(
            spec.max_attempts.unwrap_or(crate::api::DEFAULT_MAX_ATTEMPTS),
            || {
                let mut tx = self.tx(client);
                if spec.irrevocable {
                    tx = tx.irrevocable();
                }
                match spec.wait_timeout {
                    Some(Some(t)) => tx = tx.timeout(t),
                    Some(None) => tx = tx.no_timeout(),
                    None => {}
                }
                if let Some(a) = spec.asynchrony {
                    tx = tx.asynchronous(a);
                }
                for d in &spec.decls {
                    tx.declare(d.clone());
                }
                tx.run(&mut *body).map(|((), ops)| ops)
            },
            |attempt, _err| {
                if crate::trace::enabled() {
                    crate::trace::emit(
                        client.0,
                        crate::trace::EventKind::TxRetry { client, attempt },
                    );
                }
            },
        )
    }

    fn aborts(&self) -> u64 {
        self.stats.manual_aborts.load(Ordering::Relaxed)
            + self.stats.forced_aborts.load(Ordering::Relaxed)
    }

    fn commits(&self) -> u64 {
        self.stats.commits.load(Ordering::Relaxed)
    }
}
