//! Server-side per-(transaction, object) proxy — OptSVA-CF's operation
//! handlers (paper §2.8, §3.1).
//!
//! One proxy links one shared object on its home node with one client-side
//! transaction. It owns the transaction's view of the object: the suprema
//! and per-mode counters, the abort checkpoint `st`, the copy buffer `buf`,
//! the log buffer `log`, and the handle of any asynchronous buffering /
//! release task running on the home node's executor. All buffers live here,
//! on the server side, because CF semantics require side effects to occur
//! at the object's home (§2.6).
//!
//! The state machine per object (§2.8.2–§2.8.4):
//!
//! ```text
//!                read/update                     write (no prior r/u)
//!   [fresh] ───────────────────▶ [accessed]   [fresh] ─▶ log buffer
//!      │  wait access, st := copy      │                  │ last write &
//!      │  apply log if pending         │ last w/u:        │ no updates:
//!      │                               │ buf := copy      ▼ async task:
//!      │ read-only object:             ▼ release      wait access, st,
//!      └─▶ async: buf := copy,     [released]         apply log, buf,
//!          release                 reads use buf      release
//! ```

use crate::api::{Suprema, TxError};
use crate::buffers::{CopyBuffer, LogBuffer};
use crate::clock::Clock;
use crate::cluster::Oid;
use crate::executor::{Executor, TaskHandle};
use crate::object::{MethodSpec, Mode, OpCall, Value};
use crate::trace::{self, EventKind};
use crate::versioning::ObjectCc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use super::{ObjectSlot, SysStats};

/// Configuration shared by all proxies of one transaction.
#[derive(Clone)]
pub struct ProxyConfig {
    /// Failure-suspicion deadline for versioning waits (§3.4).
    pub wait_timeout: Option<Duration>,
    /// Irrevocable transactions replace every access-condition wait by a
    /// termination-condition wait (§2.4).
    pub irrevocable: bool,
    /// When false, "asynchronous" tasks run inline (ablation mode).
    pub asynchrony: bool,
    /// The cluster's time source: deadlines, heartbeats and staleness all
    /// run against it (virtual under [`crate::clock::VirtualClock`]).
    pub clock: Arc<dyn Clock>,
    /// Seeded protocol defect for explorer validation —
    /// [`super::ProtocolMutation::None`] everywhere outside
    /// [`super::AtomicRmi2::for_analysis`] runs.
    pub(crate) mutation: super::ProtocolMutation,
    /// Trace identity of the owning transaction ([`crate::trace`]); `0`
    /// when tracing was off at `begin`, so proxies never emit events for
    /// transactions whose lifecycle the session did not capture.
    pub(crate) trace_tx: u64,
}

impl ProxyConfig {
    fn deadline(&self) -> Option<Duration> {
        self.wait_timeout.map(|t| self.clock.now() + t)
    }
}

/// Mutable per-object transaction state, guarded by one mutex.
struct ProxyState {
    /// Per-mode operation counters `rc/wc/uc` (§2.2, §2.7).
    rc: u64,
    wc: u64,
    uc: u64,
    /// Did this transaction modify the live object (directly or via an
    /// applied log)? Governs abort-time invalidation + restore.
    modified: bool,
    /// Abort checkpoint `st_i(x)` — captured at first synchronized access.
    st: Option<CopyBuffer>,
    /// Reversion sequence at checkpoint time (valid-lineage discriminator):
    /// a *full* restore positioned below our pv since then means `st`
    /// captured since-rewound state and must not be restored; a *surgical*
    /// reversion below us since then is replayed on top of the restore.
    st_seq: u64,
    /// Reversion sequence at group-join time (commuting fast path): a
    /// reversion positioned before the group since then already wiped our
    /// contribution, so our inverses must not run at abort.
    join_seq: u64,
    /// Inverse operations recorded by the commuting fast path, one per
    /// executed update, in execution order. Abort applies them in reverse
    /// in place of a checkpoint restore (docs/COMMUTATIVITY.md).
    inverses: Vec<OpCall>,
    /// Copy buffer `buf_i(x)` — serves local reads after release.
    buf: Option<CopyBuffer>,
    /// Log buffer `log_i(x)` — records pure writes before synchronization.
    log: LogBuffer,
    /// Abort rollback already performed (idempotence for §3.4 eviction).
    rolled_back: bool,
}

/// Server-side proxy: injects OptSVA-CF around method dispatch.
pub struct Proxy {
    /// Identity of the shared object this proxy fronts.
    pub oid: Oid,
    /// Private version acquired for this transaction at start (§2.10.2).
    pub pv: u64,
    /// Declared per-mode operation bounds for this object.
    pub sup: Suprema,
    slot: Arc<ObjectSlot>,
    executor: Arc<Executor>,
    stats: Arc<SysStats>,
    config: ProxyConfig,
    /// Transaction-wide doom flag: set as soon as *any* proxy of this
    /// transaction observes an invalidation mark covering its pv (§2.8.2:
    /// "by checking for all the objects we force it to [abort] as early as
    /// we can detect").
    tx_doomed: Arc<AtomicBool>,
    /// §3.4: set by the failure detector when the object rolled itself
    /// back after suspecting the client crashed. Every later use of this
    /// proxy fails.
    evicted: AtomicBool,
    /// Last time (in clock time) the client was heard from (updated on
    /// every dispatch).
    last_beat: Mutex<Duration>,
    /// Passed the access condition and operates on the object directly.
    /// True-only; flipped while holding `inner`, read lock-free on the
    /// executor gate path ([`Proxy::ready_for`]).
    accessed: AtomicBool,
    /// `lv` was advanced on our behalf (early release or async release).
    /// True-only; same locking discipline as `accessed`.
    released: AtomicBool,
    /// `ltv` was advanced on our behalf. The swap makes [`Proxy::terminate`]
    /// at-most-once: eviction (§3.4) and the client's own commit/abort can
    /// both reach it, and a group member must decrement the group's
    /// `unterminated` count exactly once.
    term_done: AtomicBool,
    /// The pv-group this proxy joined on the commuting fast path: the
    /// group's `first_pv`, or 0 when not a member. Set-once while holding
    /// `inner`; read lock-free on the executor gate path and by the
    /// commit/abort routing (group members release/terminate through the
    /// group variants, never the exclusive-chain ones).
    group_first_pv: AtomicU64,
    /// Handle of the async read-only-buffering or last-write-release task.
    /// Set at most once per proxy: the read-only constructor path and the
    /// final-pure-write path are mutually exclusive (`sup.read_only()`
    /// implies the write counter can never reach a positive supremum).
    task: OnceLock<TaskHandle>,
    inner: Mutex<ProxyState>,
}

impl Proxy {
    pub(super) fn new(
        slot: Arc<ObjectSlot>,
        pv: u64,
        sup: Suprema,
        executor: Arc<Executor>,
        stats: Arc<SysStats>,
        config: ProxyConfig,
        tx_doomed: Arc<AtomicBool>,
    ) -> Arc<Self> {
        let now = config.clock.now();
        let proxy = Arc::new(Proxy {
            oid: slot.oid,
            pv,
            sup,
            slot,
            executor,
            stats,
            config,
            tx_doomed,
            evicted: AtomicBool::new(false),
            last_beat: Mutex::new(now),
            accessed: AtomicBool::new(false),
            released: AtomicBool::new(false),
            term_done: AtomicBool::new(false),
            group_first_pv: AtomicU64::new(0),
            task: OnceLock::new(),
            inner: Mutex::new(ProxyState {
                rc: 0,
                wc: 0,
                uc: 0,
                modified: false,
                st: None,
                st_seq: 0,
                join_seq: 0,
                inverses: Vec::new(),
                log: LogBuffer::new(),
                buf: None,
                rolled_back: false,
            }),
        });
        // Register with the hosting slot so the failure detector (§3.4)
        // can find live proxies.
        proxy
            .slot
            .active
            .lock()
            .unwrap()
            .push(Arc::downgrade(&proxy));
        // §2.8.1: read-only objects are buffered and released by an
        // asynchronous task scheduled at transaction start.
        if proxy.sup.read_only() {
            proxy.schedule_buffer_and_release();
        }
        proxy
    }

    fn cc(&self) -> &ObjectCc {
        &self.slot.cc
    }

    /// Emit a trace event at this object's home node, tagged with the
    /// owning transaction. The gate check comes first so a disabled
    /// recorder costs one relaxed atomic load and no event construction;
    /// `trace_tx == 0` (tracing was off at `begin`) keeps proxies of
    /// untraced transactions silent even if a session starts mid-flight.
    fn t_emit(&self, kind: impl FnOnce(u64, Oid) -> EventKind) {
        if trace::enabled() && self.config.trace_tx != 0 {
            trace::emit(self.oid.node.0, kind(self.config.trace_tx, self.oid));
        }
    }

    /// Access-condition wait — or termination-condition wait for
    /// irrevocable transactions (§2.4).
    fn wait_access(&self) -> Result<(), TxError> {
        self.t_emit(|tx, oid| EventKind::WaitStart { tx, oid });
        let r = self.wait_access_inner();
        self.t_emit(|tx, oid| EventKind::WaitEnd { tx, oid });
        r
    }

    fn wait_access_inner(&self) -> Result<(), TxError> {
        let deadline = self.config.deadline();
        if self.config.irrevocable {
            self.cc().wait_commit_cond(self.pv, deadline)?;
        } else {
            self.cc().wait_access(self.pv, deadline)?;
        }
        Ok(())
    }

    fn access_cond_ready(&self) -> bool {
        if self.config.irrevocable {
            self.cc().commit_ready(self.pv)
        } else {
            self.cc().access_ready(self.pv)
        }
    }

    /// Doom check (§2.8.2): if an invalidation mark covers our pv on this
    /// object, the whole transaction is doomed — flag it and abort the
    /// current operation.
    fn check_doomed(&self) -> Result<(), TxError> {
        if self.tx_doomed.load(Ordering::Acquire) {
            return Err(TxError::ForcedAbort("transaction observed invalidated state".into()));
        }
        if self.cc().doomed(self.pv) {
            self.tx_doomed.store(true, Ordering::Release);
            return Err(TxError::ForcedAbort(format!(
                "object {} was invalidated by an aborting transaction",
                self.oid
            )));
        }
        Ok(())
    }

    /// Method spec of `call` from the cached interface. The call's method
    /// index (stamped by the `ops::` constructors or resolved at submit
    /// time from the registry's per-type table) makes this O(1); an
    /// unstamped or mismatched index falls back to the linear scan, so a
    /// hand-built `OpCall` still dispatches correctly.
    pub(super) fn spec_of(&self, call: &OpCall) -> Result<&'static MethodSpec, crate::object::ObjectError> {
        let iface = self.slot.interface;
        if let Some(m) = iface.get(call.midx as usize) {
            // &'static method names are interned per interface, so a
            // pointer compare settles the common case without a strcmp.
            if std::ptr::eq(m.name, call.method) || m.name == call.method {
                return Ok(m);
            }
        }
        crate::object::spec_of(iface, call.method)
    }

    /// Mode of `call` from the cached interface. Client-side lookup is
    /// free: the stub ships the interface with the proxy, exactly as Java
    /// RMI ships the remote interface class.
    pub(super) fn mode_of(&self, call: &OpCall) -> Result<Mode, crate::object::ObjectError> {
        self.spec_of(call).map(|m| m.mode)
    }

    /// Stamp a hand-built call with its interface position (see
    /// [`crate::cluster::registry::MethodTable::stamp`]); pre-stamped
    /// calls pass through untouched.
    pub(super) fn stamp(&self, call: &mut OpCall) {
        self.slot.methods.stamp(call);
    }

    /// The commutativity class `call` may execute under on this proxy, or
    /// `None` for the exclusive-chain path. `Some` requires the method to
    /// declare `Commutes::Class` *with* an inverse, and the transaction's
    /// declaration for this object to be update-only (`reads == 0 &&
    /// writes == 0`) — the shape under which blind commuting execution
    /// with inverse-based abort is sound (docs/COMMUTATIVITY.md). The
    /// seeded `bogus-commute` defect trusts the method declaration alone.
    pub(super) fn commute_class(&self, call: &OpCall) -> Option<u8> {
        let spec = self.spec_of(call).ok()?;
        let class = spec.commutes.class()?;
        spec.inverse?;
        if self.config.irrevocable {
            // An irrevocable transaction must never be forced to abort,
            // but a group member can be doomed by a co-member's abort —
            // so irrevocable transactions always take the exclusive chain.
            return None;
        }
        let shape_ok = self.sup.reads == 0 && self.sup.writes == 0;
        if shape_ok || matches!(self.config.mutation, super::ProtocolMutation::BogusCommute) {
            Some(class)
        } else {
            None
        }
    }

    /// The group `first_pv` if this proxy joined a commuting pv-group.
    fn group_first(&self) -> Option<u64> {
        match self.group_first_pv.load(Ordering::Acquire) {
            0 => None,
            first => Some(first),
        }
    }

    /// Snapshot `obj` into a [`CopyBuffer`], accounting the capture and
    /// its `state_size` cost — the counters the capture-skip paths (blind
    /// writes, commuting groups) are regression-tested against.
    fn capture(&self, obj: &dyn crate::object::SharedObject) -> CopyBuffer {
        self.stats.captures.fetch_add(1, Ordering::Relaxed);
        self.stats
            .capture_bytes
            .fetch_add(obj.state_size() as u64, Ordering::Relaxed);
        CopyBuffer::capture(obj)
    }

    /// Would [`Proxy::invoke`] for an operation of `mode` run to completion
    /// without blocking on a versioning wait or an unfinished task join?
    /// This is the executor gate for asynchronously submitted operations:
    /// the single executor thread per node must never park inside an
    /// operation, or it would starve the very release tasks that unblock
    /// it. Conservative `false` answers only delay the operation; `true`
    /// answers must be exact (all of them are monotone: a finished task
    /// stays finished, `accessed`/`released` never revert, and our access
    /// condition `lv == pv - 1` can only be invalidated by our own
    /// release).
    /// Lock-free apart from the versioning check: the executor evaluates
    /// this gate on every scheduler pass over every parked operation, so it
    /// must not contend on `inner` with operation bodies.
    ///
    /// `commutes` is [`Proxy::commute_class`] of the pending call: a
    /// commuting update is also ready when it can join (or has joined) the
    /// object's pv-group, even though the exclusive access condition does
    /// not hold.
    pub(super) fn ready_for(&self, mode: Mode, commutes: Option<u8>) -> bool {
        if let Some(t) = self.task.get() {
            if !t.is_done() {
                return false; // invoke would join the buffering/release task
            }
        }
        match mode {
            // Pure writes execute on the log buffer (§2.6) or, once the
            // object is held, in place — never a wait. Post-release writes
            // fail the supremum check before any synchronization.
            Mode::Write => true,
            // Read-only objects read the start-time buffer (task gated
            // above); released objects read their copy buffer.
            Mode::Read if self.sup.read_only() => true,
            Mode::Update if commutes.is_some() && !self.accessed.load(Ordering::Acquire) => {
                self.group_first().is_some()
                    || self.released.load(Ordering::Acquire)
                    || self.cc().group_joinable(self.pv, commutes.unwrap())
            }
            _ => {
                self.accessed.load(Ordering::Acquire)
                    || self.released.load(Ordering::Acquire)
                    || self.access_cond_ready()
            }
        }
    }

    /// Dispatch one operation with full OptSVA-CF handling. Runs on the
    /// object's home node (the caller pays RPC latency).
    pub fn invoke(self: &Arc<Self>, call: &OpCall) -> Result<Value, TxError> {
        // Mode lookup from the cached interface — never touches the
        // object lock (which concurrent operation bodies may hold for
        // milliseconds).
        let mode = self.mode_of(call)?;
        self.invoke_with_mode(call, mode)
    }

    /// [`Proxy::invoke`] with the interface scan already done. Asynchronous
    /// submission resolves the mode once at submit time (it needs it for
    /// the [`Proxy::ready_for`] gate) and passes it through here so the
    /// dispatch path never scans the interface twice per operation.
    pub(super) fn invoke_with_mode(
        self: &Arc<Self>,
        call: &OpCall,
        mode: Mode,
    ) -> Result<Value, TxError> {
        self.slot.check_alive()?;
        *self.last_beat.lock().unwrap() = self.config.clock.now();
        if self.evicted.load(Ordering::Acquire) {
            return Err(TxError::ForcedAbort(format!(
                "object {} rolled itself back (client suspected crashed)",
                self.oid
            )));
        }
        match mode {
            Mode::Read => self.read(call),
            Mode::Write => self.write(call),
            Mode::Update => self.update(call),
        }
    }

    /// READ (§2.8.2).
    fn read(self: &Arc<Self>, call: &OpCall) -> Result<Value, TxError> {
        {
            let mut s = self.inner.lock().unwrap();
            s.rc += 1;
            if s.rc > self.sup.reads {
                return Err(TxError::SupremaExceeded {
                    oid: self.oid,
                    mode: "read",
                    count: s.rc,
                    bound: self.sup.reads,
                });
            }
        }

        // Read-only object: wait for the start-time buffering task, then
        // read from the copy buffer (§2.7).
        if self.sup.read_only() {
            self.join_task()?;
            self.check_doomed()?;
            self.t_emit(|tx, oid| EventKind::BufferRead { tx, oid });
            let mut s = self.inner.lock().unwrap();
            let buf = s.buf.as_mut().expect("read-only buffering task sets buf");
            return Ok(buf.invoke(call)?);
        }

        // Object already released (async last-write release or early
        // release): wait for the releasing task, then read the buffer.
        if self.released_or_pending() {
            self.join_task()?;
            self.check_doomed()?;
            self.t_emit(|tx, oid| EventKind::BufferRead { tx, oid });
            let mut s = self.inner.lock().unwrap();
            let buf = s
                .buf
                .as_mut()
                .expect("released object must have a copy buffer for later reads");
            return Ok(buf.invoke(call)?);
        }

        self.ensure_direct_access()?;
        self.check_doomed()?;

        let mut s = self.inner.lock().unwrap();
        let mut obj = self.slot.object.lock().unwrap();
        // Re-check under the object lock: an earlier transaction's abort
        // (mark + restore, also under this lock) may have doomed us between
        // the check above and acquiring the lock; executing now would
        // modify/observe the restored lineage with no rollback to cover it.
        self.check_doomed()?;
        let v = obj.invoke(call)?;
        // Last operation of any kind on this object ⇒ release (§2.8.2).
        if s.rc == self.sup.reads && s.wc == self.sup.writes && s.uc == self.sup.updates {
            drop(obj);
            self.release_now();
        }
        Ok(v)
    }

    /// UPDATE (§2.8.3).
    fn update(self: &Arc<Self>, call: &OpCall) -> Result<Value, TxError> {
        {
            let mut s = self.inner.lock().unwrap();
            s.uc += 1;
            if s.uc > self.sup.updates {
                return Err(TxError::SupremaExceeded {
                    oid: self.oid,
                    mode: "update",
                    count: s.uc,
                    bound: self.sup.updates,
                });
            }
        }

        // Commuting fast path (docs/COMMUTATIVITY.md): an update-only
        // proxy whose method declares a commutativity class joins the
        // object's pv-group instead of taking an exclusive chain position.
        if !self.accessed.load(Ordering::Acquire) || self.group_first().is_some() {
            if let Some(class) = self.commute_class(call) {
                return self.update_in_group(call, class);
            }
            if self.group_first().is_some() {
                // Already inside a group, now asked for a non-commuting
                // update: the shared slot cannot be widened to exclusive
                // access mid-flight, so the transaction must abort. (The
                // declaration lint flags interfaces that invite this.)
                return Err(TxError::ForcedAbort(format!(
                    "non-commuting operation `{}` on {} after a group grant",
                    call.method, self.oid
                )));
            }
        }

        self.ensure_direct_access()?;
        self.check_doomed()?;

        let mut s = self.inner.lock().unwrap();
        let mut obj = self.slot.object.lock().unwrap();
        // Re-check under the object lock (see `read` for why).
        self.check_doomed()?;
        let v = obj.invoke(call)?;
        s.modified = true;
        // No further writes or updates ⇒ snapshot to buf and release; all
        // remaining reads are served from the buffer (§2.8.3).
        let updates_done = match self.config.mutation {
            // Seeded defect: treat the *penultimate* update as the last
            // use, releasing one operation too early — a successor can
            // observe state this transaction will still change.
            super::ProtocolMutation::PrematureRelease => s.uc + 1 >= self.sup.updates,
            _ => s.uc == self.sup.updates,
        };
        if s.wc == self.sup.writes && updates_done {
            if s.rc < self.sup.reads {
                s.buf = Some(self.capture(obj.as_ref()));
                self.t_emit(|tx, oid| EventKind::BufferCapture { tx, oid });
            }
            drop(obj);
            self.release_now();
        }
        Ok(v)
    }

    /// Commuting fast path (docs/COMMUTATIVITY.md): execute `call` inside
    /// the object's pv-group, sharing the version slot with same-class
    /// co-members instead of taking an exclusive chain position. No
    /// checkpoint and no copy buffer are captured — abort is handled by
    /// replaying the recorded per-op inverses.
    fn update_in_group(self: &Arc<Self>, call: &OpCall, class: u8) -> Result<Value, TxError> {
        let inverse = self
            .spec_of(call)?
            .inverse
            .expect("commute_class admits only methods with an inverse");
        if self.group_first().is_none() {
            // First commuting update: join (or open) the pv-group. Blocks
            // like an access-condition wait; never holds `inner`.
            self.check_doomed()?;
            self.t_emit(|tx, oid| EventKind::WaitStart { tx, oid });
            let joined = self.cc().join_group(self.pv, class, self.config.deadline());
            self.t_emit(|tx, oid| EventKind::WaitEnd { tx, oid });
            let first = joined?;
            self.group_first_pv.store(first, Ordering::Release);
            self.stats.group_grants.fetch_add(1, Ordering::Relaxed);
            let pv = self.pv;
            self.t_emit(|tx, oid| EventKind::GroupGrant { tx, oid, pv, first_pv: first });
            if matches!(self.config.mutation, super::ProtocolMutation::BogusCommute) {
                // Seeded defect: treat the shared grant as exclusive direct
                // access, so later reads run on the live object while
                // co-members keep mutating it (an unserializable read the
                // opacity checker must flag).
                self.accessed.store(true, Ordering::Release);
            }
        }
        let mut s = self.inner.lock().unwrap();
        let mut obj = self.slot.object.lock().unwrap();
        // Re-check under the object lock (see `read` for why).
        self.check_doomed()?;
        if s.inverses.is_empty() {
            // Sample the reversion sequence under the object lock, right
            // before our first mutation: reverts before this point never
            // touched our (nonexistent) contribution, so they must stay
            // invisible to our abort guard.
            s.join_seq = self.cc().revert_seq();
        }
        let v = obj.invoke(call)?;
        s.modified = true;
        s.inverses.push(OpCall {
            method: inverse,
            args: call.args.clone(),
            midx: crate::object::NO_METHOD_IDX,
        });
        let last = s.uc == self.sup.updates;
        drop(obj);
        drop(s);
        // Last declared update: retire our group slot so successors (or
        // the next group) can run while we await commit — unless the
        // seeded bogus-commute defect holds the grant open for its
        // unserialized reads.
        if last && !matches!(self.config.mutation, super::ProtocolMutation::BogusCommute) {
            self.release_in_group();
        }
        Ok(v)
    }

    /// Retire this proxy's slot in its pv-group (the group-grant analogue
    /// of [`Proxy::release_now`]); at-most-once via the same swap.
    fn release_in_group(&self) {
        if !self.released.swap(true, Ordering::AcqRel) {
            self.cc().release_group(self.pv);
            self.stats.early_releases.fetch_add(1, Ordering::Relaxed);
            let pv = self.pv;
            self.t_emit(|tx, oid| EventKind::EarlyRelease { tx, oid, pv });
        }
    }

    /// WRITE (§2.8.4).
    fn write(self: &Arc<Self>, call: &OpCall) -> Result<Value, TxError> {
        let mut s = self.inner.lock().unwrap();
        s.wc += 1;
        if s.wc > self.sup.writes {
            return Err(TxError::SupremaExceeded {
                oid: self.oid,
                mode: "write",
                count: s.wc,
                bound: self.sup.writes,
            });
        }

        if !self.accessed.load(Ordering::Acquire) {
            // No preceding reads or updates: execute on the log buffer with
            // no synchronization whatsoever.
            let v = s.log.record(call.clone());
            // Final write, and no updates will ever run on this object:
            // split off the apply-and-release procedure to the executor
            // (§2.7, Fig 5) — the main thread continues immediately.
            if s.wc == self.sup.writes && self.sup.updates == 0 {
                drop(s);
                self.schedule_apply_log_and_release();
            }
            return Ok(v);
        }

        // Preceding reads/updates gave us direct access already.
        drop(s);
        self.check_doomed()?;
        let mut s = self.inner.lock().unwrap();
        let mut obj = self.slot.object.lock().unwrap();
        // Re-check under the object lock (see `read` for why).
        self.check_doomed()?;
        let v = obj.invoke(call)?;
        s.modified = true;
        if s.wc == self.sup.writes && s.uc == self.sup.updates {
            if s.rc < self.sup.reads {
                s.buf = Some(self.capture(obj.as_ref()));
                self.t_emit(|tx, oid| EventKind::BufferCapture { tx, oid });
            }
            drop(obj);
            // Done inline, not in a separate thread: "the transaction
            // already has access to obj_x" (§2.8.4).
            self.release_now();
        }
        Ok(v)
    }

    /// First synchronized access: wait at the access condition, make the
    /// checkpoint `st`, and apply any pending log-buffer writes (§2.8.2).
    fn ensure_direct_access(&self) -> Result<(), TxError> {
        if self.accessed.load(Ordering::Acquire) {
            return Ok(());
        }
        debug_assert!(
            !self.released.load(Ordering::Acquire),
            "direct access after release"
        );
        // Never hold `inner` while blocking on the version condvar.
        self.wait_access()?;
        let mut s = self.inner.lock().unwrap();
        if self.accessed.load(Ordering::Acquire) {
            return Ok(());
        }
        let mut obj = self.slot.object.lock().unwrap();
        // Doomed transactions must not checkpoint or modify the restored
        // lineage (their abort will not restore, §2.8.6).
        self.check_doomed()?;
        if s.st.is_none() {
            s.st_seq = self.cc().revert_seq();
            s.st = Some(self.capture(obj.as_ref()));
        }
        if !s.log.is_empty() {
            let mut log = std::mem::take(&mut s.log);
            log.apply(obj.as_mut())?;
            s.modified = true;
        }
        self.accessed.store(true, Ordering::Release);
        Ok(())
    }

    /// Advance `lv` on our behalf and account the early release. The
    /// atomic swap makes the release at-most-once even though commit,
    /// abort and the async release task can all race to it.
    fn release_now(&self) {
        if !self.released.swap(true, Ordering::AcqRel) {
            self.cc().release(self.pv);
            self.stats.early_releases.fetch_add(1, Ordering::Relaxed);
            // The headline span boundary: the object is now available to
            // successors while this transaction keeps running.
            let pv = self.pv;
            self.t_emit(|tx, oid| EventKind::EarlyRelease { tx, oid, pv });
        }
    }

    /// Has the object been released, or is a releasing task in flight?
    fn released_or_pending(&self) -> bool {
        self.released.load(Ordering::Acquire) || self.task.get().is_some()
    }

    /// Wait for the async buffering/release task, if any (§2.8.5: commit
    /// "waits for extant threads to finish"). Public for tests and
    /// diagnostics.
    pub fn join_task(&self) -> Result<(), TxError> {
        if let Some(h) = self.task.get() {
            h.join(self.config.clock.as_ref(), self.config.deadline()).map_err(|()| {
                TxError::Timeout(crate::versioning::WaitTimeout {
                    what: "async task join",
                    waited_ms: self
                        .config
                        .wait_timeout
                        .map(|t| t.as_millis() as u64)
                        .unwrap_or(0),
                })
            })?;
        }
        Ok(())
    }

    /// §2.8.1: asynchronously snapshot a read-only object into `buf` and
    /// release it as soon as the access condition passes — possibly before
    /// the first read is even attempted.
    fn schedule_buffer_and_release(self: &Arc<Self>) {
        let me = Arc::clone(self);
        let action = move || {
            if me.released.load(Ordering::Acquire) {
                // The transaction released through another path (rollback
                // after a timed-out join) before this task became runnable:
                // buffering now would snapshot a successor's state.
                return;
            }
            let mut s = me.inner.lock().unwrap();
            let obj = me.slot.object.lock().unwrap();
            // Record the grant *before* observing state, under the object
            // lock, so an aborter's mark+restore (also under the object
            // lock) either sees our grant or restores before our snapshot.
            me.cc().note_granted(me.pv);
            s.buf = Some(me.capture(obj.as_ref()));
            me.t_emit(|tx, oid| EventKind::BufferCapture { tx, oid });
            drop(obj);
            drop(s);
            me.release_now();
        };
        self.schedule(action);
    }

    /// §2.8.4 final-write path: asynchronously wait at the access
    /// condition, checkpoint, apply the log, snapshot to `buf`, release.
    fn schedule_apply_log_and_release(self: &Arc<Self>) {
        let me = Arc::clone(self);
        let action = move || {
            if me.released.load(Ordering::Acquire) {
                // Stale task (see `schedule_buffer_and_release`): the
                // rollback already discarded the log.
                return;
            }
            let mut s = me.inner.lock().unwrap();
            let mut obj = me.slot.object.lock().unwrap();
            me.cc().note_granted(me.pv);
            // A doomed transaction must not modify the restored lineage:
            // flag it and release without applying the log.
            if me.cc().doomed(me.pv) {
                me.tx_doomed.store(true, Ordering::Release);
                drop(obj);
                drop(s);
                me.release_now();
                return;
            }
            if s.st.is_none() {
                s.st_seq = me.cc().revert_seq();
                s.st = Some(me.capture(obj.as_ref()));
            }
            let mut log = std::mem::take(&mut s.log);
            // Log replay of pure writes: errors are surfaced at commit by
            // re-checking; a failed replay leaves the checkpoint intact.
            if log.apply(obj.as_mut()).is_ok() {
                s.modified = true;
            }
            // Conservative `sup.reads > 0` (not `rc < reads`): this runs on
            // the executor thread and must not race the main thread's read
            // counter.
            if me.sup.reads > 0 {
                s.buf = Some(me.capture(obj.as_ref()));
                me.t_emit(|tx, oid| EventKind::BufferCapture { tx, oid });
            }
            drop(obj);
            drop(s);
            me.release_now();
        };
        self.schedule(action);
    }

    /// Run `action` once this object's access condition holds: on the home
    /// node's executor (§3.3), or inline when asynchrony is disabled.
    fn schedule(self: &Arc<Self>, action: impl FnOnce() + Send + 'static) {
        self.stats.async_tasks.fetch_add(1, Ordering::Relaxed);
        if !self.config.asynchrony {
            // Ablation mode: block the calling thread at the condition.
            let _ = self.wait_access();
            action();
            assert!(
                self.task.set(TaskHandle::ready()).is_ok(),
                "a proxy schedules its async task at most once"
            );
            return;
        }
        // Publish the handle *before* handing the task to the executor so
        // `ready_for`/`released_or_pending` can never observe the window
        // between submission and publication.
        let handle = TaskHandle::new();
        assert!(
            self.task.set(handle.clone()).is_ok(),
            "a proxy schedules its async task at most once"
        );
        let me = Arc::clone(self);
        self.executor.submit_with_handle(
            handle,
            // `|| released`: if the transaction released through another
            // path (rollback after a timed-out join) before this task ever
            // became runnable, its own release has made the access
            // condition false forever — let the task fire and no-op (the
            // actions guard on `released`) instead of pinning the executor
            // queue open across shutdown.
            move || me.access_cond_ready() || me.released.load(Ordering::Acquire),
            action,
        );
    }

    // ------------------------------------------------------------------
    // Commit / abort participation (driven by `Transaction`, §2.8.5–6).
    // ------------------------------------------------------------------

    /// Wait for this object's commit (termination) condition. Group
    /// members wait only for the chain *before* the group — co-members
    /// terminate in any order (their operations commute).
    pub(super) fn wait_commit(&self) -> Result<(), TxError> {
        match self.group_first() {
            Some(first) => self.cc().wait_commit_cond_group(first, self.config.deadline())?,
            None => self.cc().wait_commit_cond(self.pv, self.config.deadline())?,
        }
        Ok(())
    }

    /// Commit-time finalization (§2.8.5): apply a pending log (write-only
    /// object whose supremum was never reached), release if still held.
    pub(super) fn finalize_commit(&self) -> Result<(), TxError> {
        if self.group_first().is_some() {
            // A group member is update-only: no log to apply, nothing
            // buffered. Just retire the slot if still held.
            if !self.released.swap(true, Ordering::AcqRel) {
                self.cc().release_group(self.pv);
            }
            return Ok(());
        }
        let mut s = self.inner.lock().unwrap();
        if !s.log.is_empty() {
            let mut obj = self.slot.object.lock().unwrap();
            self.cc().note_granted(self.pv);
            // Capture-skip (docs/COMMUTATIVITY.md §capture-accounting): the
            // commit condition holds here, so every predecessor has
            // terminated and no future abort can doom us. A single-entry
            // log applies atomically (methods validate arguments before
            // mutating), so a failed apply leaves the object untouched and
            // the checkpoint would never be restored. Multi-entry logs can
            // fail partway through and keep the snapshot.
            if s.st.is_none() && s.log.len() > 1 {
                s.st_seq = self.cc().revert_seq();
                s.st = Some(self.capture(obj.as_ref()));
            }
            let mut log = std::mem::take(&mut s.log);
            // `modified` is flagged *before* the apply: a multi-entry log
            // that fails partway has still mutated the object, and the
            // rollback that follows the failed commit must restore.
            s.modified = true;
            log.apply(obj.as_mut())?;
        }
        // Commit-time release is not an *early* release — skip the stat.
        if !self.released.swap(true, Ordering::AcqRel) {
            self.cc().release(self.pv);
        }
        Ok(())
    }

    /// Is this transaction doomed through this object?
    pub(super) fn is_doomed(&self) -> bool {
        self.tx_doomed.load(Ordering::Acquire) || self.cc().doomed(self.pv)
    }

    /// Abort-time rollback (§2.8.6): invalidate + restore (or, for a
    /// commuting group member, apply the recorded inverses), under the
    /// object lock to serialize against in-flight buffering tasks of later
    /// transactions.
    pub(super) fn rollback(&self) {
        let mut s = self.inner.lock().unwrap();
        if s.rolled_back {
            return;
        }
        s.rolled_back = true;
        let group = self.group_first();
        let mut obj = self.slot.object.lock().unwrap();
        if s.modified {
            // Invalidate everyone who observed our (now aborted) state.
            match self.config.mutation {
                // Seeded defect: successors that consumed our writes via
                // early release are never cascade-aborted.
                super::ProtocolMutation::SkipInvalidation => {}
                _ => self.cc().mark_invalid(self.pv),
            }
            if let Some(first) = group {
                // Group member: undo our own contribution surgically by
                // applying the recorded inverses in reverse order — unless
                // a full restore positioned before the group already wiped
                // it wholesale (checkpoints taken below the group predate
                // every member's work).
                let wiped = self.cc().wiped_since(s.join_seq, first);
                let restored = !wiped && !s.inverses.is_empty();
                if std::env::var_os("ARMI2_TRACE").is_some() {
                    eprintln!(
                        "[trace] rollback {} pv={} group@{} inverses={}",
                        self.oid, self.pv, first, restored
                    );
                }
                self.t_emit(|tx, oid| EventKind::Rollback { tx, oid, restored });
                if restored {
                    let mut applied = Vec::with_capacity(s.inverses.len());
                    for inv in s.inverses.iter().rev() {
                        // Inverses of executed commuting ops cannot fail on
                        // any co-serializable state (`deposit(n)` always
                        // leaves enough for `withdraw(n)`); a failure here
                        // means a declaration bug, surfaced by the lint.
                        if obj.invoke(inv).is_ok() {
                            applied.push(inv.clone());
                        }
                    }
                    self.cc().note_reverted(self.pv, applied);
                }
                s.inverses.clear();
            } else {
                // Exclusive chain: restore the checkpoint unless a full
                // restore positioned below us already rewound our work
                // (then the older restore stands — §2.8.6). After
                // restoring, replay any surgical reverts our snapshot
                // re-instated (a group member below us whose inverse ran
                // after our capture).
                let wiped = s
                    .st
                    .as_ref()
                    .map(|_| self.cc().wiped_since(s.st_seq, self.pv))
                    .unwrap_or(false);
                let should_restore = s.st.is_some() && !wiped;
                if std::env::var_os("ARMI2_TRACE").is_some() {
                    eprintln!(
                        "[trace] rollback {} pv={} restore={}",
                        self.oid, self.pv, should_restore
                    );
                }
                self.t_emit(|tx, oid| EventKind::Rollback { tx, oid, restored: should_restore });
                if should_restore {
                    if let Some(st) = &s.st {
                        st.restore_into(obj.as_mut());
                        for inv in self.cc().surgical_reverts_since(s.st_seq, self.pv) {
                            let _ = obj.invoke(&inv);
                        }
                        self.cc().note_restored(self.pv);
                    }
                }
            }
        }
        // Pending log-buffer writes are simply discarded.
        s.log = LogBuffer::new();
        drop(obj);
        if !self.released.swap(true, Ordering::AcqRel) {
            match group {
                Some(_) => {
                    self.cc().release_group(self.pv);
                }
                None => self.cc().release(self.pv),
            }
        }
    }

    /// Advance `ltv` — the very last step of commit and abort. A group
    /// member retires through the group (the group's slot terminates when
    /// its last member does, in any internal order).
    pub(super) fn terminate(&self) {
        if self.term_done.swap(true, Ordering::AcqRel) {
            return;
        }
        match self.group_first() {
            Some(_) => {
                if self.cc().terminate_group(self.pv) {
                    let pv = self.pv;
                    self.t_emit(|tx, oid| EventKind::GroupRetire { tx, oid, pv });
                }
            }
            None => self.cc().terminate(self.pv),
        }
    }

    /// §3.4 failure path, called by the failure detector: the object
    /// "performs a rollback on itself: it reverts its state and releases
    /// itself". Only legal when the commit condition holds (the detector
    /// checks), so `terminate` keeps the versioning order intact.
    pub(crate) fn evict(&self) {
        if trace::enabled() {
            trace::emit(self.oid.node.0, EventKind::Evict { oid: self.oid });
        }
        self.evicted.store(true, Ordering::Release);
        self.rollback();
        self.terminate();
    }

    /// Was this proxy evicted by the failure detector?
    pub fn is_evicted(&self) -> bool {
        self.evicted.load(Ordering::Acquire)
    }

    /// Clock time since the client last dispatched through this proxy.
    pub(crate) fn staleness(&self) -> Duration {
        self.config
            .clock
            .now()
            .saturating_sub(*self.last_beat.lock().unwrap())
    }

    /// Is this proxy finished (its `ltv` advanced past it)?
    pub(crate) fn terminated(&self) -> bool {
        self.cc().versions().1 >= self.pv
    }

    /// Does this proxy's commit (termination) condition hold right now?
    /// Explorer gate: `Transaction::finish_ready` must be exact, because
    /// the single-threaded harness may never take a blocking step.
    /// Crate-visible for the `analysis::` wait-graph builder.
    pub(crate) fn commit_cond_ready(&self) -> bool {
        match self.group_first() {
            Some(first) => self.cc().commit_ready_group(first),
            None => self.cc().commit_ready(self.pv),
        }
    }

    /// Has the async buffering/release task finished? `true` when none
    /// was ever scheduled. Crate-visible for `analysis::`.
    pub(crate) fn task_done(&self) -> bool {
        self.task.get().map(TaskHandle::is_done).unwrap_or(true)
    }

    /// Would eviction preserve termination order right now?
    pub(crate) fn evictable(&self) -> bool {
        !self.terminated() && self.commit_cond_ready()
    }

    /// Counters snapshot (tests, diagnostics).
    pub fn counts(&self) -> (u64, u64, u64) {
        let s = self.inner.lock().unwrap();
        (s.rc, s.wc, s.uc)
    }

    /// Was the object released early (before commit/abort)?
    pub fn released(&self) -> bool {
        self.released.load(Ordering::Acquire)
    }

    /// Total operations executed through this proxy.
    pub(super) fn ops(&self) -> u64 {
        let s = self.inner.lock().unwrap();
        s.rc + s.wc + s.uc
    }
}

#[cfg(test)]
mod tests {
    use super::super::{AtomicRmi2, OptsvaConfig};
    use crate::api::Suprema;
    use crate::cluster::{Cluster, NetworkModel, NodeId};
    use crate::object::{account::ops, Account};
    use std::sync::Arc;
    use std::time::Duration;

    fn sys() -> Arc<AtomicRmi2> {
        let cluster = Arc::new(Cluster::new(1, NetworkModel::instant()));
        AtomicRmi2::with_config(
            cluster,
            OptsvaConfig { wait_timeout: Some(Duration::from_secs(10)), asynchrony: true },
        )
    }

    #[test]
    fn read_only_object_is_buffered_and_released_before_first_read() {
        let sys = sys();
        let oid = sys.host(NodeId(0), "A", Box::new(Account::with_balance(7)));
        let mut tx = sys.tx(NodeId(0));
        let h = tx.reads("A", 2);
        tx.begin().unwrap();
        let proxy = tx.proxy(h);
        proxy.join_task().unwrap();
        assert!(proxy.released(), "read-only object released by the async task");
        let (lv, _) = sys.slot(oid).cc.versions();
        assert_eq!(lv, proxy.pv, "lv advanced before any read executed");
        // Reads still see the buffered state.
        assert_eq!(proxy.invoke(&ops::balance()).unwrap().as_int(), 7);
        tx.commit().unwrap();
        sys.shutdown();
    }

    #[test]
    fn supremum_violation_is_reported() {
        let sys = sys();
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
        let mut tx = sys.tx(NodeId(0));
        let h = tx.accesses("A", Suprema::new(1, 0, 0));
        tx.begin().unwrap();
        let proxy = tx.proxy(h);
        proxy.invoke(&ops::balance()).unwrap();
        let err = proxy.invoke(&ops::balance()).unwrap_err();
        assert!(matches!(err, crate::api::TxError::SupremaExceeded { .. }));
        tx.abort().unwrap();
        sys.shutdown();
    }

    #[test]
    fn pure_write_executes_without_synchronization_while_object_is_held() {
        let sys = sys();
        let oid = sys.host(NodeId(0), "A", Box::new(Account::with_balance(5)));
        // T1 takes direct access and holds the object.
        let mut t1 = sys.tx(NodeId(0));
        let h1 = t1.accesses("A", Suprema::new(1, 0, 1));
        t1.begin().unwrap();
        t1.proxy(h1).invoke(&ops::balance()).unwrap();

        // T2's pure write must return immediately (log buffer), despite T1
        // holding the access condition.
        let mut t2 = sys.tx(NodeId(0));
        let h2 = t2.accesses("A", Suprema::new(0, 1, 0));
        t2.begin().unwrap();
        t2.proxy(h2).invoke(&ops::reset()).unwrap();
        assert!(
            !sys.slot(oid).cc.access_ready(t2.proxy(h2).pv),
            "T2 never passed the access condition for its write"
        );

        // T1 finishes; T2's async apply-log task then fires and releases.
        t1.proxy(h1).invoke(&ops::deposit(10)).unwrap();
        t1.commit().unwrap();
        t2.proxy(h2).join_task().unwrap();
        assert!(t2.proxy(h2).released());
        t2.commit().unwrap();
        assert_eq!(sys.with_object(oid, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()), 0);
        sys.shutdown();
    }

    #[test]
    fn update_releases_after_last_write_update_and_reads_use_buffer() {
        let sys = sys();
        let oid = sys.host(NodeId(0), "A", Box::new(Account::with_balance(100)));
        let mut tx = sys.tx(NodeId(0));
        let h = tx.accesses("A", Suprema::new(1, 0, 1));
        tx.begin().unwrap();
        let p = tx.proxy(h);
        p.invoke(&ops::deposit(50)).unwrap(); // last update ⇒ buf + release
        assert!(p.released());
        let (lv, _) = sys.slot(oid).cc.versions();
        assert_eq!(lv, p.pv);
        // The remaining read is served locally from buf.
        assert_eq!(p.invoke(&ops::balance()).unwrap().as_int(), 150);
        tx.commit().unwrap();
        sys.shutdown();
    }
}
