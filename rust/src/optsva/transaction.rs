//! Client-side OptSVA-CF transaction (paper Fig 8/9, §2.8.1, §2.8.5–6).
//!
//! The lifecycle mirrors the paper's API: a *preamble* declares the access
//! set with optional suprema (`reads`/`writes`/`updates`/`accesses`) and
//! per-transaction knobs (`irrevocable`, `timeout`, `asynchronous`), then
//! [`Transaction::begin`] atomically acquires private versions for the
//! whole set (under start locks taken in global `Oid` order, §2.10.2) and
//! creates one server-side [`Proxy`] per object.
//!
//! Operations flow through the [`TxCtx`] trait in two flavors:
//!
//!  * [`TxCtx::call`] — the classic blocking RMI stub path: the client
//!    thread pays request + response latency and the full server-side
//!    handling inline (Fig 6);
//!  * [`TxCtx::submit`] — the asynchronous path this module adds: the stub
//!    ships the request (one-way cost only) and enqueues the operation on
//!    the home node's executor, gated so the executor never parks inside
//!    an operation; the returned [`OpFuture`] resolves when the operation
//!    has run and its response has (virtually) arrived. Operations on the
//!    *same* object are chained in program order (the per-object counters
//!    and release points of §2.8 demand it); operations on *different*
//!    objects overlap freely — the §2.6/§2.7 parallelism, now visible to
//!    callers.
//!
//! Commit joins every outstanding submitted operation first: a dropped
//! [`OpFuture`] still executes, still counts toward the declared suprema,
//! and a failure that nobody waited on aborts the transaction at commit.

use super::proxy::{Proxy, ProxyConfig};
use super::AtomicRmi2;
use crate::api::{AccessDecl, ObjHandle, OpFuture, PendingOp, Suprema, TxCtx, TxError};
use crate::clock::Clock;
use crate::cluster::{Cluster, NodeId};
use crate::executor::TaskHandle;
use crate::object::{OpCall, Value};
use crate::trace::{self, EventKind};
use crate::versioning::{acquire_start_locks, WaitTimeout};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Preamble,
    Running,
    Done,
}

/// Result slot of one asynchronously submitted operation, shared between
/// the executor action, the client-held [`OpFuture`], and the commit-time
/// drain.
struct SubmittedState {
    result: Option<Result<Value, TxError>>,
    /// Clock time the operation completed at the home node (response
    /// send instant — the arrival the future's `wait` pays up to).
    done_at: Duration,
    resp_bytes: usize,
    /// The result was observed (by `wait` or by the commit drain); an
    /// unobserved `Err` aborts the transaction at commit.
    taken: bool,
}

/// One submitted operation: executor handle plus its result slot. The slot
/// lives inside this struct (one `Arc` per submit, not two): the executor
/// action, the client-held [`OpFuture`] and the commit-time drain all share
/// the same `Arc<SubmittedOp>`.
struct SubmittedOp {
    handle: TaskHandle,
    state: Mutex<SubmittedState>,
    node: NodeId,
    /// Executed inline on the client thread (ablation mode): the round
    /// trip is already paid, so neither `wait` nor the commit drain may
    /// deliver a response for it.
    inline: bool,
}

/// [`PendingOp`] backing for [`TxCtx::submit`] on OptSVA-CF.
struct PendingRemoteOp {
    op: Arc<SubmittedOp>,
    cluster: Arc<Cluster>,
    client: NodeId,
    clock: Arc<dyn Clock>,
    timeout: Option<Duration>,
    /// The operation ran inline on the client thread (ablation mode): the
    /// round trip was already paid, so `wait` must not deliver a response.
    inline: bool,
}

impl PendingOp for PendingRemoteOp {
    fn is_ready(&self) -> bool {
        if !self.op.handle.is_done() {
            return false;
        }
        if self.inline || self.op.node == self.client {
            return true;
        }
        // `wait` also blocks until the simulated response arrival: only
        // report ready once that instant has passed (or the response was
        // already delivered by an earlier wait/commit drain).
        let s = self.op.state.lock().unwrap();
        s.taken || s.done_at + self.cluster.network().delay(s.resp_bytes) <= self.clock.now()
    }

    fn wait(self: Box<Self>) -> Result<Value, TxError> {
        let deadline = self.timeout.map(|t| self.clock.now() + t);
        self.op
            .handle
            .join(self.clock.as_ref(), deadline)
            .map_err(|()| {
                TxError::Timeout(WaitTimeout {
                    what: "submitted operation",
                    waited_ms: self.timeout.map(|t| t.as_millis() as u64).unwrap_or(0),
                })
            })?;
        let (r, done_at, resp_bytes, already_delivered) = {
            let mut s = self.op.state.lock().unwrap();
            let already = s.taken;
            s.taken = true;
            (
                s.result.clone().expect("completed task sets its result"),
                s.done_at,
                s.resp_bytes,
                already,
            )
        };
        // Co-located operations have no response leg (the blocking rpc
        // path counts them as a single local call; the submit already did).
        if !self.inline && !already_delivered && self.op.node != self.client {
            // The response left the home node when the operation
            // completed; block only until its (pipelined) arrival.
            self.cluster.deliver(self.op.node, self.client, resp_bytes, done_at);
        }
        r
    }
}

/// A client-side OptSVA-CF transaction.
pub struct Transaction {
    sys: Arc<AtomicRmi2>,
    client: NodeId,
    irrevocable: bool,
    /// Per-transaction failure-suspicion deadline (defaults to the system
    /// configuration; `None` disables suspicion).
    wait_timeout: Option<Duration>,
    /// Per-transaction asynchrony switch (defaults to the system
    /// configuration; `false` is the ablation mode in which `submit`
    /// degrades to the sequential blocking path).
    asynchrony: bool,
    decls: Vec<AccessDecl>,
    proxies: Vec<Arc<Proxy>>,
    tx_doomed: Arc<AtomicBool>,
    /// Set once commit/abort processing starts: a submitted operation that
    /// races past it resolves to `Err(Completed)` instead of touching the
    /// (possibly rolled-back) object.
    closed: Arc<AtomicBool>,
    /// Last submitted operation per handle — the per-object program-order
    /// chain for executor gating.
    chain: Vec<Option<TaskHandle>>,
    /// Every operation submitted through the futures API, for the commit
    /// and abort drains.
    submitted: Vec<Arc<SubmittedOp>>,
    phase: Phase,
    /// Trace identity ([`crate::trace`]): allocated at `begin` when a
    /// trace session is recording, `0` otherwise (no events emitted).
    trace_tx: u64,
}

impl Transaction {
    pub(super) fn new(sys: Arc<AtomicRmi2>, client: NodeId) -> Self {
        let config = sys.config();
        Transaction {
            sys,
            client,
            irrevocable: false,
            wait_timeout: config.wait_timeout,
            asynchrony: config.asynchrony,
            decls: Vec::new(),
            proxies: Vec::new(),
            tx_doomed: Arc::new(AtomicBool::new(false)),
            closed: Arc::new(AtomicBool::new(false)),
            chain: Vec::new(),
            submitted: Vec::new(),
            phase: Phase::Preamble,
            trace_tx: 0,
        }
    }

    /// Emit a lifecycle trace event on the client's node. No event is
    /// constructed unless this transaction was assigned a trace identity
    /// at `begin` (i.e. a trace session was recording).
    fn t_emit(&self, kind: impl FnOnce(u64, NodeId) -> EventKind) {
        if self.trace_tx != 0 {
            trace::emit(self.client.0, kind(self.trace_tx, self.client));
        }
    }

    /// Mark the transaction irrevocable (§2.4): every access-condition wait
    /// becomes a termination-condition wait; it can never be forced to
    /// abort, at the price of never accepting early-released objects.
    pub fn irrevocable(mut self) -> Self {
        assert_eq!(self.phase, Phase::Preamble, "irrevocable() after begin");
        self.irrevocable = true;
        self
    }

    /// Per-transaction failure-suspicion deadline override (§3.4).
    pub fn timeout(mut self, t: Duration) -> Self {
        assert_eq!(self.phase, Phase::Preamble, "timeout() after begin");
        self.wait_timeout = Some(t);
        self
    }

    /// Disable failure suspicion for this transaction (unbounded waits).
    pub fn no_timeout(mut self) -> Self {
        assert_eq!(self.phase, Phase::Preamble, "no_timeout() after begin");
        self.wait_timeout = None;
        self
    }

    /// Per-transaction asynchrony override: `false` runs every
    /// asynchronous task inline and resolves every `submit` synchronously
    /// (the ablation mode, byte-identical to the sequential semantics).
    pub fn asynchronous(mut self, on: bool) -> Self {
        assert_eq!(self.phase, Phase::Preamble, "asynchronous() after begin");
        self.asynchrony = on;
        self
    }

    /// Preamble: declare read-only access with supremum `n` (Fig 8).
    pub fn reads(&mut self, name: &str, n: u64) -> ObjHandle {
        self.accesses(name, Suprema::reads(n))
    }

    /// Preamble: declare write-only access with supremum `n`.
    pub fn writes(&mut self, name: &str, n: u64) -> ObjHandle {
        self.accesses(name, Suprema::writes(n))
    }

    /// Preamble: declare update access with supremum `n`.
    pub fn updates(&mut self, name: &str, n: u64) -> ObjHandle {
        self.accesses(name, Suprema::updates(n))
    }

    /// Preamble: declare mixed access with full per-mode suprema.
    pub fn accesses(&mut self, name: &str, sup: Suprema) -> ObjHandle {
        self.declare(AccessDecl::new(name, sup))
    }

    /// Preamble: declare access from a prepared [`AccessDecl`] — the path
    /// the framework-agnostic [`crate::api::TxBuilder`] drives, carrying a
    /// pre-interned [`crate::cluster::NameId`] so `begin` never hashes the
    /// name. Declarations without an id are interned here (one stripe read
    /// for any hosted name; unknown names stay un-interned and fail at
    /// `begin` with [`TxError::NotDeclared`]).
    pub fn declare(&mut self, mut decl: AccessDecl) -> ObjHandle {
        assert_eq!(self.phase, Phase::Preamble, "declaration after begin");
        if decl.interned.is_none() {
            decl.interned = self.sys.cluster().registry.lookup(&decl.name);
        }
        self.decls.push(decl);
        ObjHandle(self.decls.len() - 1)
    }

    fn clock(&self) -> Arc<dyn Clock> {
        Arc::clone(self.sys.cluster().clock())
    }

    fn deadline(&self) -> Option<Duration> {
        self.wait_timeout.map(|t| self.clock().now() + t)
    }

    /// §2.8.1: resolve the access set, atomically acquire private versions
    /// (start locks in global `Oid` order), create server-side proxies, and
    /// schedule read-only buffering tasks.
    pub fn begin(&mut self) -> Result<(), TxError> {
        assert_eq!(self.phase, Phase::Preamble, "begin called twice");
        let cluster = Arc::clone(self.sys.cluster());

        // Resolve names and keep declaration order for handles. Interned
        // declarations resolve by id (no string hashing — the per-attempt
        // hot path); the string fallback covers names bound after they
        // were declared.
        let mut resolved = Vec::with_capacity(self.decls.len());
        for d in &self.decls {
            let oid = d
                .interned
                .and_then(|id| cluster.registry.resolve(id))
                .or_else(|| cluster.registry.locate(&d.name))
                .ok_or_else(|| TxError::NotDeclared(d.name.clone()))?;
            resolved.push((oid, d.suprema));
        }

        // Sort a view by Oid for globally ordered start-lock acquisition.
        let mut order: Vec<usize> = (0..resolved.len()).collect();
        order.sort_by_key(|&i| resolved[i].0);
        for w in order.windows(2) {
            assert_ne!(
                resolved[w[0]].0, resolved[w[1]].0,
                "object declared twice in the preamble: {}",
                resolved[w[0]].0
            );
        }

        let slots: Vec<_> = order.iter().map(|&i| self.sys.slot(resolved[i].0)).collect();
        for slot in &slots {
            slot.check_alive()?;
        }
        let lock_view: Vec<_> = order
            .iter()
            .zip(&slots)
            .map(|(&i, slot)| (resolved[i].0, &slot.cc))
            .collect();
        let client = self.client;
        let pvs = acquire_start_locks(&lock_view, |oid| {
            // Remote lock acquisition costs one round trip to the home node.
            cluster.rpc(client, oid.node, 24, || ((), 16));
        });

        // Create proxies back in declaration order. The trace identity is
        // allocated (and TxBegin emitted) first: a read-only proxy's
        // buffering task may start emitting the moment it is created.
        self.trace_tx = if trace::enabled() { trace::next_tx_id() } else { 0 };
        self.t_emit(|tx, client| EventKind::TxBegin { tx, client });
        let config = ProxyConfig {
            wait_timeout: self.wait_timeout,
            irrevocable: self.irrevocable,
            asynchrony: self.asynchrony,
            clock: Arc::clone(cluster.clock()),
            mutation: self.sys.mutation,
            trace_tx: self.trace_tx,
        };
        let mut proxies: Vec<Option<Arc<Proxy>>> = vec![None; resolved.len()];
        for (pos, &i) in order.iter().enumerate() {
            let (oid, sup) = resolved[i];
            proxies[i] = Some(Proxy::new(
                Arc::clone(&slots[pos]),
                pvs[pos],
                sup,
                self.sys.executor_of(oid.node),
                self.sys.stats_arc(),
                config.clone(),
                Arc::clone(&self.tx_doomed),
            ));
        }
        self.proxies = proxies.into_iter().map(Option::unwrap).collect();
        self.chain = vec![None; self.proxies.len()];
        self.phase = Phase::Running;
        Ok(())
    }

    /// The proxy behind a handle (tests, diagnostics).
    pub fn proxy(&self, h: ObjHandle) -> &Arc<Proxy> {
        &self.proxies[h.0]
    }

    /// Explorer gate: would [`TxCtx::call`] for `call` on `h` run to
    /// completion right now without blocking on a versioning wait, a
    /// program-order chain, or an unfinished async task?
    ///
    /// The schedule explorer (`analysis::`) runs everything on one thread
    /// over threadless executors, so it may only take steps this gate
    /// approves — a blocking step would hang the harness. `true` answers
    /// must therefore be exact; all the conditions involved are monotone
    /// under the explorer's single-threaded discipline (a finished task
    /// stays finished, `accessed`/`released` never revert, and the access
    /// condition can only be invalidated by this transaction's own
    /// release).
    pub fn call_ready(&self, h: ObjHandle, call: &OpCall) -> Result<bool, TxError> {
        if self.phase != Phase::Running {
            return Ok(true); // the call would fail fast with `Completed`
        }
        let p = self
            .proxies
            .get(h.0)
            .ok_or_else(|| TxError::NotDeclared(format!("handle #{}", h.0)))?;
        if let Some(prev) = &self.chain[h.0] {
            if !prev.is_done() {
                return Ok(false); // program order behind a submitted op
            }
        }
        let mode = p.mode_of(call)?;
        Ok(p.ready_for(mode, p.commute_class(call)))
    }

    /// Explorer gate: would [`Transaction::commit`] /
    /// [`Transaction::abort`] run to completion right now without
    /// blocking? Both join every submitted operation and async task and
    /// wait out every object's commit (termination) condition, so all of
    /// those must already hold. Same exactness contract as
    /// [`Transaction::call_ready`].
    pub fn finish_ready(&self) -> bool {
        if self.phase != Phase::Running {
            return true;
        }
        self.submitted.iter().all(|op| op.handle.is_done())
            && self
                .proxies
                .iter()
                .all(|p| p.task_done() && (p.is_evicted() || p.commit_cond_ready()))
    }

    /// Execute `body` as the transaction's code: begin, run, then commit —
    /// or abort on any error. Returns the body's value and the number of
    /// shared-object operations executed (submitted operations included).
    pub fn run<R>(
        mut self,
        mut body: impl FnMut(&mut dyn TxCtx) -> Result<R, TxError>,
    ) -> Result<(R, u64), TxError> {
        if self.phase == Phase::Preamble {
            self.begin()?;
        }
        match body(&mut self) {
            Ok(r) => {
                self.commit()?;
                Ok((r, self.ops()))
            }
            Err(e) => {
                self.abort_with(&e)?;
                Err(e)
            }
        }
    }

    fn ops(&self) -> u64 {
        self.proxies.iter().map(|p| p.ops()).sum()
    }

    /// Join every submitted operation and surface the first failure nobody
    /// `wait`ed on. Part of the §2.8.5 "wait for extant threads" step,
    /// extended to the futures API: an [`OpFuture`] dropped unresolved
    /// still executes and still enforces the supremum accounting.
    fn drain_submitted(&self) -> Result<(), TxError> {
        let clock = self.clock();
        let deadline = self.deadline();
        for op in &self.submitted {
            op.handle.join(clock.as_ref(), deadline).map_err(|()| {
                TxError::Timeout(WaitTimeout {
                    what: "submitted operation (commit drain)",
                    waited_ms: self
                        .wait_timeout
                        .map(|t| t.as_millis() as u64)
                        .unwrap_or(0),
                })
            })?;
        }
        let cluster = Arc::clone(self.sys.cluster());
        let mut first_err: Option<TxError> = None;
        for op in &self.submitted {
            let mut s = op.state.lock().unwrap();
            if s.taken {
                continue; // observed by a `wait` (response delivered there)
            }
            s.taken = true;
            let (resp_bytes, done_at) = (s.resp_bytes, s.done_at);
            let err = match &s.result {
                Some(Err(e)) => Some(e.clone()),
                _ => None,
            };
            drop(s);
            if !op.inline && op.node != self.client {
                // Even a fire-and-forget operation's response crosses the
                // network: account it (and wait out its arrival) so the
                // pipelined and blocking paths report the same traffic.
                // Co-located ops have no response leg (counted once at
                // submit, like the blocking rpc path).
                cluster.deliver(op.node, self.client, resp_bytes, done_at);
            }
            if first_err.is_none() {
                first_err = err;
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Best-effort drain for the abort path: a rollback must not race an
    /// in-flight operation, but a stuck operation must not wedge the abort
    /// either (§3.4 crash semantics take over after the deadline).
    fn drain_submitted_quietly(&self) {
        let clock = self.clock();
        let deadline = self.deadline();
        for op in &self.submitted {
            let _ = op.handle.join(clock.as_ref(), deadline);
        }
    }

    /// §2.8.5 COMMIT: drain submitted operations, join extant async tasks,
    /// wait for every object's commit condition, finalize (apply pending
    /// logs, release), check invalidation (abort instead if doomed), then
    /// advance `ltv`s.
    pub fn commit(&mut self) -> Result<(), TxError> {
        assert_eq!(self.phase, Phase::Running, "commit outside running phase");
        if let Err(e) = self.drain_submitted() {
            // A submitted operation failed (or never became runnable
            // before the suspicion deadline): abort instead of committing.
            self.abort_with(&e)?;
            return Err(e);
        }
        self.closed.store(true, Ordering::Release);
        let cluster = Arc::clone(self.sys.cluster());
        let client = self.client;

        for p in &self.proxies {
            p.join_task()?;
        }
        // §3.4: an object evicted by the failure detector has already been
        // rolled back and terminated — waiting on its commit condition
        // would deadlock; the transaction is doomed instead.
        if self.proxies.iter().any(|p| p.is_evicted()) {
            for p in &self.proxies {
                p.rollback();
                p.terminate();
            }
            self.phase = Phase::Done;
            self.sys.stats.forced_aborts.fetch_add(1, Ordering::Relaxed);
            let e = TxError::ForcedAbort(
                "object rolled itself back (client suspected crashed)".into(),
            );
            let cause = e.to_string();
            self.t_emit(|tx, client| EventKind::TxAbort { tx, client, cause });
            return Err(e);
        }
        for p in &self.proxies {
            // One commit-protocol message per object.
            let r = cluster.rpc(client, p.oid.node, 24, || (p.wait_commit(), 16));
            if let Err(e) = r {
                self.emergency_finalize();
                return Err(e);
            }
        }
        let mut finalize_err = None;
        for p in &self.proxies {
            if let Err(e) = p.finalize_commit() {
                finalize_err = Some(e);
                break;
            }
        }
        let doomed = self.proxies.iter().any(|p| p.is_doomed() || p.is_evicted());
        if doomed || finalize_err.is_some() {
            // Abort instead of committing: rollback in place (the commit
            // condition already holds for every object).
            for p in &self.proxies {
                p.rollback();
            }
            for p in &self.proxies {
                p.terminate();
            }
            self.phase = Phase::Done;
            self.sys.stats.forced_aborts.fetch_add(1, Ordering::Relaxed);
            let e = match finalize_err {
                Some(e) => e,
                None => TxError::ForcedAbort("invalidated at commit".into()),
            };
            let cause = e.to_string();
            self.t_emit(|tx, client| EventKind::TxAbort { tx, client, cause });
            return Err(e);
        }
        for p in &self.proxies {
            p.terminate();
        }
        self.phase = Phase::Done;
        self.sys.stats.commits.fetch_add(1, Ordering::Relaxed);
        self.t_emit(|tx, client| EventKind::TxCommit { tx, client });
        Ok(())
    }

    /// §2.8.6 ABORT (manual).
    pub fn abort(&mut self) -> Result<(), TxError> {
        self.abort_with(&TxError::ManualAbort)
    }

    fn abort_with(&mut self, cause: &TxError) -> Result<(), TxError> {
        assert_eq!(self.phase, Phase::Running, "abort outside running phase");
        // Close *before* draining: an aborting transaction's effects are
        // all discarded, so a submitted operation that has not started yet
        // must resolve `Err(Completed)` rather than race the rollback —
        // setting the flag first closes the window in which a stuck
        // operation could become runnable between a timed-out join and the
        // rollback below. Operations already executing are joined as
        // usual; their effects are covered by the checkpoint.
        self.closed.store(true, Ordering::Release);
        self.drain_submitted_quietly();
        let cluster = Arc::clone(self.sys.cluster());
        let client = self.client;

        for p in &self.proxies {
            // A doomed/failed task join must not wedge the abort.
            let _ = p.join_task();
        }
        let mut timed_out = false;
        for p in &self.proxies {
            if p.is_evicted() {
                continue; // already rolled back and terminated (§3.4)
            }
            let r = cluster.rpc(client, p.oid.node, 24, || (p.wait_commit(), 16));
            if r.is_err() {
                timed_out = true; // §3.4 fault path: clean up regardless
            }
        }
        for p in &self.proxies {
            p.rollback();
        }
        for p in &self.proxies {
            p.terminate();
        }
        self.phase = Phase::Done;
        match cause {
            TxError::ManualAbort | TxError::Retry => {
                self.sys.stats.manual_aborts.fetch_add(1, Ordering::Relaxed);
            }
            _ => {
                self.sys.stats.forced_aborts.fetch_add(1, Ordering::Relaxed);
            }
        }
        let cause_text = cause.to_string();
        self.t_emit(|tx, client| EventKind::TxAbort { tx, client, cause: cause_text });
        if timed_out {
            return Err(TxError::Timeout(crate::versioning::WaitTimeout {
                what: "abort commit-condition wait",
                waited_ms: 0,
            }));
        }
        Ok(())
    }

    /// Last-resort cleanup when a commit-condition wait times out (§3.4):
    /// restore, release and terminate everything so other transactions can
    /// make progress, ignoring ordering (crash semantics).
    fn emergency_finalize(&mut self) {
        for p in &self.proxies {
            p.rollback();
            p.terminate();
        }
        self.phase = Phase::Done;
        self.sys.stats.forced_aborts.fetch_add(1, Ordering::Relaxed);
        self.t_emit(|tx, client| EventKind::TxAbort {
            tx,
            client,
            cause: "commit-condition wait timed out (§3.4 emergency finalize)".into(),
        });
    }
}

impl TxCtx for Transaction {
    /// Non-blocking dispatch: ship the request (one-way cost), enqueue the
    /// operation on the home node's executor behind (a) the previous
    /// operation on the same object and (b) the proxy's no-block gate, and
    /// hand back a future. With asynchrony disabled this degrades to the
    /// blocking path and returns a resolved future.
    fn submit(&mut self, h: ObjHandle, call: OpCall) -> Result<OpFuture, TxError> {
        if self.phase != Phase::Running {
            return Err(TxError::Completed);
        }
        let p = Arc::clone(
            self.proxies
                .get(h.0)
                .ok_or_else(|| TxError::NotDeclared(format!("handle #{}", h.0)))?,
        );
        // Hand-built calls resolve their interface position once here; the
        // typed `ops::` constructors arrive pre-stamped.
        let mut call = call;
        p.stamp(&mut call);
        let cluster = Arc::clone(self.sys.cluster());
        let clock = Arc::clone(cluster.clock());
        if !self.asynchrony {
            // Ablation mode: sequential semantics, identical to `call` —
            // but still registered with the commit drain, so an error in a
            // dropped future cannot vanish (same contract as the async
            // path).
            let node = p.oid.node;
            let r = self.call(h, call);
            let op = Arc::new(SubmittedOp {
                handle: TaskHandle::ready(),
                state: Mutex::new(SubmittedState {
                    result: Some(r),
                    done_at: clock.now(),
                    resp_bytes: 0,
                    taken: false,
                }),
                node,
                inline: true,
            });
            self.submitted.push(Arc::clone(&op));
            return Ok(OpFuture::pending(Box::new(PendingRemoteOp {
                op,
                cluster,
                client: self.client,
                clock,
                timeout: self.wait_timeout,
                inline: true,
            })));
        }
        // Resolve the mode once, at submit time: the `ready_for` gate needs
        // it, and the executor action reuses it (`invoke_with_mode`), so
        // the interface is scanned exactly once per operation.
        let mode = p.mode_of(&call)?;
        // A commuting call's class is likewise resolved once: the gate may
        // run on every scheduler pass, and the class never changes.
        let commutes = p.commute_class(&call);
        // The stub serializes and ships the request; the client pays only
        // the one-way cost and continues — §2.6's "the transaction can
        // proceed without waiting".
        cluster.send(self.client, p.oid.node, call.wire_size());

        let handle = TaskHandle::new();
        let op = Arc::new(SubmittedOp {
            handle: handle.clone(),
            state: Mutex::new(SubmittedState {
                result: None,
                done_at: Duration::ZERO,
                resp_bytes: 16,
                taken: false,
            }),
            node: p.oid.node,
            inline: false,
        });
        let prev = self.chain[h.0].clone();
        let gate = Arc::clone(&p);
        let cond = move || {
            prev.as_ref().map_or(true, TaskHandle::is_done) && gate.ready_for(mode, commutes)
        };
        let run_p = Arc::clone(&p);
        let run_op = Arc::clone(&op);
        let closed = Arc::clone(&self.closed);
        let run_clock = Arc::clone(&clock);
        let action = move || {
            let r = if closed.load(Ordering::Acquire) {
                // The transaction finished (commit/abort) without this
                // operation ever becoming runnable: refuse rather than
                // touching the possibly rolled-back object.
                Err(TxError::Completed)
            } else {
                run_p.invoke_with_mode(&call, mode)
            };
            let resp_bytes = match &r {
                Ok(v) => v.wire_size(),
                Err(_) => 16,
            };
            let mut s = run_op.state.lock().unwrap();
            s.result = Some(r);
            s.done_at = run_clock.now();
            s.resp_bytes = resp_bytes;
        };
        self.sys
            .executor_of(p.oid.node)
            .submit_with_handle(handle.clone(), cond, action);
        self.chain[h.0] = Some(handle);
        self.submitted.push(Arc::clone(&op));
        Ok(OpFuture::pending(Box::new(PendingRemoteOp {
            op,
            cluster,
            client: self.client,
            clock,
            timeout: self.wait_timeout,
            inline: false,
        })))
    }

    /// Blocking RMI stub path (Fig 6): the client thread pays request +
    /// response latency around the server-side dispatch. Kept as a direct
    /// implementation (not `submit().wait()`) so the sequential semantics
    /// — including the `asynchrony = false` ablation — stay byte-identical
    /// to the pre-futures API.
    fn call(&mut self, h: ObjHandle, call: OpCall) -> Result<Value, TxError> {
        if self.phase != Phase::Running {
            return Err(TxError::Completed);
        }
        let p = Arc::clone(
            self.proxies
                .get(h.0)
                .ok_or_else(|| TxError::NotDeclared(format!("handle #{}", h.0)))?,
        );
        // Hand-built calls resolve their interface position once here; the
        // typed `ops::` constructors arrive pre-stamped.
        let mut call = call;
        p.stamp(&mut call);
        // Program order with previously *submitted* operations on the same
        // object: the blocking stub must not overtake them (§2.8's
        // per-object counters and release points assume program order).
        if let Some(prev) = self.chain[h.0].clone() {
            prev.join(self.clock().as_ref(), self.deadline()).map_err(|()| {
                TxError::Timeout(WaitTimeout {
                    what: "submitted operation (program order)",
                    waited_ms: self
                        .wait_timeout
                        .map(|t| t.as_millis() as u64)
                        .unwrap_or(0),
                })
            })?;
        }
        let cluster = Arc::clone(self.sys.cluster());
        let req = call.wire_size();
        cluster.rpc(self.client, p.oid.node, req, || {
            let r = p.invoke(&call);
            let resp = match &r {
                Ok(v) => v.wire_size(),
                Err(_) => 16,
            };
            (r, resp)
        })
    }

    fn client(&self) -> NodeId {
        self.client
    }
}

impl Drop for Transaction {
    fn drop(&mut self) {
        // A transaction dropped mid-flight (panic, programming error) must
        // not wedge the rest of the system: roll it back.
        if self.phase == Phase::Running {
            let _ = self.abort_with(&TxError::ManualAbort);
        }
    }
}

/// Convenience: stats field as an `Arc` for proxies.
impl AtomicRmi2 {
    pub(super) fn stats_arc(&self) -> Arc<super::SysStats> {
        Arc::clone(&self.stats)
    }
}

// `TxStats` is produced by the `Dtm` driver in `optsva::mod`; re-exported
// here so callers that use the concrete API see the same type.
pub use crate::api::TxStats as Stats;

#[cfg(test)]
mod tests {
    use super::super::{AtomicRmi2, OptsvaConfig};
    use crate::api::{Suprema, TxCtx, TxError};
    use crate::cluster::{Cluster, NetworkModel, NodeId};
    use crate::object::{account::ops, Account};
    use std::sync::Arc;
    use std::time::Duration;

    fn sys_n(nodes: u16) -> Arc<AtomicRmi2> {
        let cluster = Arc::new(Cluster::new(nodes, NetworkModel::instant()));
        AtomicRmi2::with_config(
            cluster,
            OptsvaConfig { wait_timeout: Some(Duration::from_secs(10)), asynchrony: true },
        )
    }

    fn balance(sys: &AtomicRmi2, oid: crate::cluster::Oid) -> i64 {
        sys.with_object(oid, |o| o.as_any().downcast_ref::<Account>().unwrap().balance())
    }

    #[test]
    fn transfer_commits_and_is_visible() {
        let sys = sys_n(2);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(100)));
        let b = sys.host(NodeId(1), "B", Box::new(Account::with_balance(0)));

        let mut tx = sys.tx(NodeId(0));
        let ha = tx.accesses("A", Suprema::new(1, 0, 1));
        let hb = tx.updates("B", 1);
        tx.begin().unwrap();
        tx.call(ha, ops::withdraw(100)).unwrap();
        tx.call(hb, ops::deposit(100)).unwrap();
        assert_eq!(tx.call(ha, ops::balance()).unwrap().as_int(), 0);
        tx.commit().unwrap();

        assert_eq!(balance(&sys, a), 0);
        assert_eq!(balance(&sys, b), 100);
        assert_eq!(sys.stats.commits.load(std::sync::atomic::Ordering::Relaxed), 1);
        sys.shutdown();
    }

    #[test]
    fn manual_abort_restores_state() {
        let sys = sys_n(1);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(50)));
        let mut tx = sys.tx(NodeId(0));
        let ha = tx.updates("A", 2);
        tx.begin().unwrap();
        tx.call(ha, ops::withdraw(100)).unwrap();
        tx.abort().unwrap();
        assert_eq!(balance(&sys, a), 50);
        sys.shutdown();
    }

    #[test]
    fn commuting_deposits_share_a_group_grant() {
        use std::sync::atomic::Ordering;
        let sys = sys_n(1);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(100)));

        let mut t1 = sys.tx(NodeId(0));
        let h1 = t1.updates("A", 2);
        t1.begin().unwrap();
        let mut t2 = sys.tx(NodeId(0));
        let h2 = t2.updates("A", 1);
        t2.begin().unwrap();

        // t1 opens the group and stays active (1 of 2 updates); t2 joins
        // it and deposits concurrently — no chain wait, no copy-buffer
        // capture on either side.
        t1.call(h1, ops::deposit(10)).unwrap();
        t2.call(h2, ops::deposit(20)).unwrap();
        t1.call(h1, ops::deposit(5)).unwrap();
        assert_eq!(sys.stats.group_grants.load(Ordering::Relaxed), 2);
        assert_eq!(sys.stats.captures.load(Ordering::Relaxed), 0);

        // Intra-group commit order is free: the later member first.
        t2.commit().unwrap();
        t1.commit().unwrap();
        assert_eq!(balance(&sys, a), 135);

        // The group retired: an exclusive successor (it declares a read)
        // proceeds through the ordinary chain and sees the total.
        let mut t3 = sys.tx(NodeId(0));
        let h3 = t3.accesses("A", Suprema::new(1, 0, 1));
        t3.begin().unwrap();
        t3.call(h3, ops::deposit(1)).unwrap();
        assert_eq!(t3.call(h3, ops::balance()).unwrap().as_int(), 136);
        t3.commit().unwrap();
        assert_eq!(balance(&sys, a), 136);
        sys.shutdown();
    }

    #[test]
    fn group_member_abort_is_undone_by_inverse() {
        let sys = sys_n(1);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(100)));

        let mut t1 = sys.tx(NodeId(0));
        let h1 = t1.updates("A", 2);
        t1.begin().unwrap();
        let mut t2 = sys.tx(NodeId(0));
        let h2 = t2.updates("A", 1);
        t2.begin().unwrap();

        t1.call(h1, ops::deposit(10)).unwrap();
        t2.call(h2, ops::deposit(20)).unwrap();
        // t2 aborts mid-group: no checkpoint was taken, so its deposit is
        // surgically reverted by the declared inverse (withdraw(20)) —
        // the co-member's concurrent contribution survives untouched.
        t2.abort().unwrap();
        t1.call(h1, ops::deposit(5)).unwrap();
        t1.commit().unwrap();

        assert_eq!(balance(&sys, a), 115);
        assert_eq!(sys.stats.commits.load(std::sync::atomic::Ordering::Relaxed), 1);
        sys.shutdown();
    }

    #[test]
    fn run_driver_commits_on_ok_and_aborts_on_err() {
        let sys = sys_n(1);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(10)));

        let mut tx = sys.tx(NodeId(0));
        let ha = tx.updates("A", 1);
        let r = tx.run(|t| {
            t.call(ha, ops::deposit(5))?;
            Ok(())
        });
        assert_eq!(r.unwrap().1, 1, "one shared-object operation executed");

        // Fig 9 shape: withdraw then abort when the balance went negative.
        let mut tx = sys.tx(NodeId(0));
        let ha2 = tx.accesses("A", Suprema::new(1, 0, 1));
        let r = tx.run(|t| {
            t.call(ha2, ops::withdraw(100))?;
            if t.call(ha2, ops::balance())?.as_int() < 0 {
                return t.abort();
            }
            Ok(())
        });
        assert_eq!(r.unwrap_err(), TxError::ManualAbort);
        assert_eq!(balance(&sys, a), 15);
        sys.shutdown();
    }

    #[test]
    fn run_returns_the_body_value() {
        let sys = sys_n(1);
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(7)));
        let mut tx = sys.tx(NodeId(0));
        let h = tx.reads("A", 1);
        let (seen, ops) = tx.run(|t| t.call(h, ops::balance()).map(|v| v.as_int())).unwrap();
        assert_eq!(seen, 7);
        assert_eq!(ops, 1);
        sys.shutdown();
    }

    #[test]
    fn unknown_object_name_fails_begin() {
        let sys = sys_n(1);
        let mut tx = sys.tx(NodeId(0));
        tx.reads("nope", 1);
        assert!(matches!(tx.begin(), Err(TxError::NotDeclared(_))));
    }

    #[test]
    fn versioning_orders_conflicting_transactions() {
        let sys = sys_n(1);
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
        let mut handles = vec![];
        for _ in 0..8 {
            let sys = Arc::clone(&sys);
            handles.push(std::thread::spawn(move || {
                let mut tx = sys.tx(NodeId(0));
                let h = tx.updates("A", 1);
                tx.run(|t| {
                    t.call(h, ops::deposit(1))?;
                    Ok(())
                })
                .unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let oid = sys.cluster().registry.locate("A").unwrap();
        assert_eq!(balance(&sys, oid), 8);
        assert_eq!(sys.stats.commits.load(std::sync::atomic::Ordering::Relaxed), 8);
        sys.shutdown();
    }

    #[test]
    fn cascading_abort_dooms_the_reader() {
        let sys = sys_n(1);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(100)));

        // T1 updates A and releases early (supremum reached), then aborts.
        let mut t1 = sys.tx(NodeId(0));
        let h1 = t1.updates("A", 1);
        t1.begin().unwrap();
        t1.call(h1, ops::deposit(900)).unwrap(); // released early (lv := pv1)

        // T2 reads the early-released (dirty) state.
        let mut t2 = sys.tx(NodeId(0));
        let h2 = t2.accesses("A", Suprema::new(1, 0, 1));
        t2.begin().unwrap();
        assert_eq!(t2.call(h2, ops::balance()).unwrap().as_int(), 1000);

        // T1 aborts ⇒ A restored; T2 is doomed and must fail at commit.
        t1.abort().unwrap();
        let r = t2.commit();
        assert!(matches!(r, Err(TxError::ForcedAbort(_))), "got {r:?}");
        assert_eq!(balance(&sys, a), 100);
        sys.shutdown();
    }

    #[test]
    fn irrevocable_transaction_waits_for_termination_not_release() {
        let sys = sys_n(1);
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));

        // T1 updates A and releases early, but does not terminate yet.
        let mut t1 = sys.tx(NodeId(0));
        let h1 = t1.updates("A", 1);
        t1.begin().unwrap();
        t1.call(h1, ops::deposit(1)).unwrap();
        assert!(t1.proxy(h1).released());

        // An irrevocable T2 must NOT accept the early release: its read
        // blocks until T1 terminates.
        let sys2 = Arc::clone(&sys);
        let t2_thread = std::thread::spawn(move || {
            let mut t2 = sys2.tx(NodeId(0)).irrevocable();
            let h2 = t2.accesses("A", Suprema::new(1, 0, 1));
            t2.begin().unwrap();
            let v = t2.call(h2, ops::balance()).unwrap().as_int();
            t2.commit().unwrap();
            v
        });
        std::thread::sleep(Duration::from_millis(50));
        assert!(!t2_thread.is_finished(), "irrevocable read must wait for ltv");
        t1.commit().unwrap();
        assert_eq!(t2_thread.join().unwrap(), 1);
        sys.shutdown();
    }

    #[test]
    fn dropped_running_transaction_rolls_back() {
        let sys = sys_n(1);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(5)));
        {
            let mut tx = sys.tx(NodeId(0));
            let h = tx.updates("A", 2);
            tx.begin().unwrap();
            tx.call(h, ops::deposit(10)).unwrap();
            // dropped without commit/abort
        }
        assert_eq!(balance(&sys, a), 5);
        // A following transaction is not blocked.
        let mut tx = sys.tx(NodeId(0));
        let h = tx.updates("A", 1);
        tx.run(|t| {
            t.call(h, ops::deposit(1))?;
            Ok(())
        })
        .unwrap();
        sys.shutdown();
    }

    // ------------------------------------------------------------------
    // Futures API
    // ------------------------------------------------------------------

    #[test]
    fn submit_then_wait_returns_values_and_commits() {
        let sys = sys_n(2);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(10)));
        let b = sys.host(NodeId(1), "B", Box::new(Account::with_balance(20)));

        let mut tx = sys.tx(NodeId(0));
        let ha = tx.accesses("A", Suprema::new(1, 0, 1));
        let hb = tx.accesses("B", Suprema::new(1, 0, 1));
        tx.begin().unwrap();
        // Fan out both updates, then both reads, then wait everything.
        let f1 = tx.submit(ha, ops::deposit(5)).unwrap();
        let f2 = tx.submit(hb, ops::deposit(7)).unwrap();
        let f3 = tx.submit(ha, ops::balance()).unwrap();
        let f4 = tx.submit(hb, ops::balance()).unwrap();
        // Waiting out of submission order is fine: per-object chains keep
        // program order, cross-object order is unconstrained.
        assert_eq!(f4.wait().unwrap().as_int(), 27);
        assert_eq!(f3.wait().unwrap().as_int(), 15);
        f1.wait().unwrap();
        f2.wait().unwrap();
        tx.commit().unwrap();
        assert_eq!(balance(&sys, a), 15);
        assert_eq!(balance(&sys, b), 27);
        sys.shutdown();
    }

    #[test]
    fn submitted_ops_on_one_object_run_in_program_order() {
        let sys = sys_n(1);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
        let mut tx = sys.tx(NodeId(0));
        let h = tx.accesses("A", Suprema::new(1, 0, 2));
        tx.begin().unwrap();
        let f1 = tx.submit(h, ops::deposit(5)).unwrap();
        let f2 = tx.submit(h, ops::deposit(10)).unwrap();
        let f3 = tx.submit(h, ops::balance()).unwrap();
        assert_eq!(f3.wait().unwrap().as_int(), 15, "reads see all prior submits");
        f2.wait().unwrap();
        f1.wait().unwrap();
        tx.commit().unwrap();
        assert_eq!(balance(&sys, a), 15);
        sys.shutdown();
    }

    #[test]
    fn ablation_mode_resolves_submits_inline() {
        let cluster = Arc::new(Cluster::new(1, NetworkModel::instant()));
        let sys = AtomicRmi2::with_config(
            cluster,
            OptsvaConfig { wait_timeout: Some(Duration::from_secs(10)), asynchrony: false },
        );
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(1)));
        let mut tx = sys.tx(NodeId(0));
        let h = tx.accesses("A", Suprema::new(0, 0, 1));
        tx.begin().unwrap();
        let f = tx.submit(h, ops::deposit(2)).unwrap();
        assert!(f.is_ready(), "asynchrony=false resolves at submission");
        f.wait().unwrap();
        tx.commit().unwrap();
        assert_eq!(balance(&sys, a), 3);
        sys.shutdown();
    }

    #[test]
    fn per_tx_asynchrony_override_wins_over_system_config() {
        let sys = sys_n(1);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
        let mut tx = sys.tx(NodeId(0)).asynchronous(false);
        let h = tx.updates("A", 1);
        tx.begin().unwrap();
        let f = tx.submit(h, ops::deposit(4)).unwrap();
        assert!(f.is_ready());
        f.wait().unwrap();
        tx.commit().unwrap();
        assert_eq!(balance(&sys, a), 4);
        sys.shutdown();
    }
}
