//! Client-side OptSVA-CF transaction (paper Fig 8/9, §2.8.1, §2.8.5–6).
//!
//! The lifecycle mirrors the paper's API: a *preamble* declares the access
//! set with optional suprema (`reads`/`writes`/`updates`/`accesses`), then
//! [`Transaction::begin`] atomically acquires private versions for the
//! whole set (under start locks taken in global `Oid` order, §2.10.2) and
//! creates one server-side [`Proxy`] per object. Operations flow through
//! [`Transaction::call`], which pays simulated network latency to the
//! object's home node — exactly Java RMI's stub → remote-proxy path.

use super::proxy::{Proxy, ProxyConfig};
use super::AtomicRmi2;
use crate::api::{ObjHandle, Suprema, TxCtx, TxError};
use crate::cluster::NodeId;
use crate::object::{OpCall, Value};
use crate::versioning::acquire_start_locks;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Alias kept for symmetry with the `Dtm` driver code: the builder *is*
/// the transaction (declarations before `begin`, operations after).
pub type TxBuilder = Transaction;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Preamble,
    Running,
    Done,
}

/// A client-side OptSVA-CF transaction.
pub struct Transaction {
    sys: Arc<AtomicRmi2>,
    client: NodeId,
    irrevocable: bool,
    decls: Vec<(String, Suprema)>,
    proxies: Vec<Arc<Proxy>>,
    tx_doomed: Arc<AtomicBool>,
    phase: Phase,
}

impl Transaction {
    pub(super) fn new(sys: Arc<AtomicRmi2>, client: NodeId) -> Self {
        Transaction {
            sys,
            client,
            irrevocable: false,
            decls: Vec::new(),
            proxies: Vec::new(),
            tx_doomed: Arc::new(AtomicBool::new(false)),
            phase: Phase::Preamble,
        }
    }

    /// Mark the transaction irrevocable (§2.4): every access-condition wait
    /// becomes a termination-condition wait; it can never be forced to
    /// abort, at the price of never accepting early-released objects.
    pub fn irrevocable(mut self) -> Self {
        assert_eq!(self.phase, Phase::Preamble, "irrevocable() after begin");
        self.irrevocable = true;
        self
    }

    /// Preamble: declare read-only access with supremum `n` (Fig 8).
    pub fn reads(&mut self, name: &str, n: u64) -> ObjHandle {
        self.accesses(name, Suprema::reads(n))
    }

    /// Preamble: declare write-only access with supremum `n`.
    pub fn writes(&mut self, name: &str, n: u64) -> ObjHandle {
        self.accesses(name, Suprema::writes(n))
    }

    /// Preamble: declare update access with supremum `n`.
    pub fn updates(&mut self, name: &str, n: u64) -> ObjHandle {
        self.accesses(name, Suprema::updates(n))
    }

    /// Preamble: declare mixed access with full per-mode suprema.
    pub fn accesses(&mut self, name: &str, sup: Suprema) -> ObjHandle {
        assert_eq!(self.phase, Phase::Preamble, "declaration after begin");
        self.decls.push((name.to_string(), sup));
        ObjHandle(self.decls.len() - 1)
    }

    /// §2.8.1: resolve the access set, atomically acquire private versions
    /// (start locks in global `Oid` order), create server-side proxies, and
    /// schedule read-only buffering tasks.
    pub fn begin(&mut self) -> Result<(), TxError> {
        assert_eq!(self.phase, Phase::Preamble, "begin called twice");
        let cluster = Arc::clone(self.sys.cluster());

        // Resolve names and keep declaration order for handles.
        let mut resolved = Vec::with_capacity(self.decls.len());
        for (name, sup) in &self.decls {
            let oid = cluster
                .registry
                .locate(name)
                .ok_or_else(|| TxError::NotDeclared(name.clone()))?;
            resolved.push((oid, *sup));
        }

        // Sort a view by Oid for globally ordered start-lock acquisition.
        let mut order: Vec<usize> = (0..resolved.len()).collect();
        order.sort_by_key(|&i| resolved[i].0);
        for w in order.windows(2) {
            assert_ne!(
                resolved[w[0]].0, resolved[w[1]].0,
                "object declared twice in the preamble: {}",
                resolved[w[0]].0
            );
        }

        let slots: Vec<_> = order.iter().map(|&i| self.sys.slot(resolved[i].0)).collect();
        for slot in &slots {
            slot.check_alive()?;
        }
        let lock_view: Vec<_> = order
            .iter()
            .zip(&slots)
            .map(|(&i, slot)| (resolved[i].0, &slot.cc))
            .collect();
        let client = self.client;
        let pvs = acquire_start_locks(&lock_view, |oid| {
            // Remote lock acquisition costs one round trip to the home node.
            cluster.rpc(client, oid.node, 24, || ((), 16));
        });

        // Create proxies back in declaration order.
        let config = ProxyConfig {
            wait_timeout: self.sys.config().wait_timeout,
            irrevocable: self.irrevocable,
            asynchrony: self.sys.config().asynchrony,
            clock: Arc::clone(cluster.clock()),
        };
        let mut proxies: Vec<Option<Arc<Proxy>>> = vec![None; resolved.len()];
        for (pos, &i) in order.iter().enumerate() {
            let (oid, sup) = resolved[i];
            proxies[i] = Some(Proxy::new(
                Arc::clone(&slots[pos]),
                pvs[pos],
                sup,
                self.sys.executor_of(oid.node),
                self.sys.stats_arc(),
                config.clone(),
                Arc::clone(&self.tx_doomed),
            ));
        }
        self.proxies = proxies.into_iter().map(Option::unwrap).collect();
        self.phase = Phase::Running;
        Ok(())
    }

    /// The proxy behind a handle (tests, diagnostics).
    pub fn proxy(&self, h: ObjHandle) -> &Arc<Proxy> {
        &self.proxies[h.0]
    }

    /// Execute `body` as the transaction's code: begin, run, then commit —
    /// or abort on any error. Returns the number of shared-object
    /// operations executed.
    pub fn run(
        mut self,
        mut body: impl FnMut(&mut dyn TxCtx) -> Result<(), TxError>,
    ) -> Result<u64, TxError> {
        if self.phase == Phase::Preamble {
            self.begin()?;
        }
        match body(&mut self) {
            Ok(()) => {
                let ops = self.ops();
                self.commit()?;
                Ok(ops)
            }
            Err(e) => {
                self.abort_with(&e)?;
                Err(e)
            }
        }
    }

    fn ops(&self) -> u64 {
        self.proxies.iter().map(|p| p.ops()).sum()
    }

    /// §2.8.5 COMMIT: join extant async tasks, wait for every object's
    /// commit condition, finalize (apply pending logs, release), check
    /// invalidation (abort instead if doomed), then advance `ltv`s.
    pub fn commit(&mut self) -> Result<(), TxError> {
        assert_eq!(self.phase, Phase::Running, "commit outside running phase");
        let cluster = Arc::clone(self.sys.cluster());
        let client = self.client;

        for p in &self.proxies {
            p.join_task()?;
        }
        // §3.4: an object evicted by the failure detector has already been
        // rolled back and terminated — waiting on its commit condition
        // would deadlock; the transaction is doomed instead.
        if self.proxies.iter().any(|p| p.is_evicted()) {
            for p in &self.proxies {
                p.rollback();
                p.terminate();
            }
            self.phase = Phase::Done;
            self.sys.stats.forced_aborts.fetch_add(1, Ordering::Relaxed);
            return Err(TxError::ForcedAbort(
                "object rolled itself back (client suspected crashed)".into(),
            ));
        }
        for p in &self.proxies {
            // One commit-protocol message per object.
            let r = cluster.rpc(client, p.oid.node, 24, || (p.wait_commit(), 16));
            if let Err(e) = r {
                self.emergency_finalize();
                return Err(e);
            }
        }
        let mut finalize_err = None;
        for p in &self.proxies {
            if let Err(e) = p.finalize_commit() {
                finalize_err = Some(e);
                break;
            }
        }
        let doomed = self.proxies.iter().any(|p| p.is_doomed() || p.is_evicted());
        if doomed || finalize_err.is_some() {
            // Abort instead of committing: rollback in place (the commit
            // condition already holds for every object).
            for p in &self.proxies {
                p.rollback();
            }
            for p in &self.proxies {
                p.terminate();
            }
            self.phase = Phase::Done;
            self.sys.stats.forced_aborts.fetch_add(1, Ordering::Relaxed);
            return Err(match finalize_err {
                Some(e) => e,
                None => TxError::ForcedAbort("invalidated at commit".into()),
            });
        }
        for p in &self.proxies {
            p.terminate();
        }
        self.phase = Phase::Done;
        self.sys.stats.commits.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// §2.8.6 ABORT (manual).
    pub fn abort(&mut self) -> Result<(), TxError> {
        self.abort_with(&TxError::ManualAbort)
    }

    fn abort_with(&mut self, cause: &TxError) -> Result<(), TxError> {
        assert_eq!(self.phase, Phase::Running, "abort outside running phase");
        let cluster = Arc::clone(self.sys.cluster());
        let client = self.client;

        for p in &self.proxies {
            // A doomed/failed task join must not wedge the abort.
            let _ = p.join_task();
        }
        let mut timed_out = false;
        for p in &self.proxies {
            if p.is_evicted() {
                continue; // already rolled back and terminated (§3.4)
            }
            let r = cluster.rpc(client, p.oid.node, 24, || (p.wait_commit(), 16));
            if r.is_err() {
                timed_out = true; // §3.4 fault path: clean up regardless
            }
        }
        for p in &self.proxies {
            p.rollback();
        }
        for p in &self.proxies {
            p.terminate();
        }
        self.phase = Phase::Done;
        match cause {
            TxError::ManualAbort | TxError::Retry => {
                self.sys.stats.manual_aborts.fetch_add(1, Ordering::Relaxed);
            }
            _ => {
                self.sys.stats.forced_aborts.fetch_add(1, Ordering::Relaxed);
            }
        }
        if timed_out {
            return Err(TxError::Timeout(crate::versioning::WaitTimeout {
                what: "abort commit-condition wait",
                waited_ms: 0,
            }));
        }
        Ok(())
    }

    /// Last-resort cleanup when a commit-condition wait times out (§3.4):
    /// restore, release and terminate everything so other transactions can
    /// make progress, ignoring ordering (crash semantics).
    fn emergency_finalize(&mut self) {
        for p in &self.proxies {
            p.rollback();
            p.terminate();
        }
        self.phase = Phase::Done;
        self.sys.stats.forced_aborts.fetch_add(1, Ordering::Relaxed);
    }
}

impl TxCtx for Transaction {
    fn call(&mut self, h: ObjHandle, call: OpCall) -> Result<Value, TxError> {
        if self.phase != Phase::Running {
            return Err(TxError::Completed);
        }
        let p = Arc::clone(
            self.proxies
                .get(h.0)
                .ok_or_else(|| TxError::NotDeclared(format!("handle #{}", h.0)))?,
        );
        let cluster = Arc::clone(self.sys.cluster());
        let req = call.wire_size();
        // The stub forwards the invocation to the server-side proxy: the
        // client thread pays request + response latency (Fig 6).
        cluster.rpc(self.client, p.oid.node, req, || {
            let r = p.invoke(&call);
            let resp = match &r {
                Ok(v) => v.wire_size(),
                Err(_) => 16,
            };
            (r, resp)
        })
    }

    fn client(&self) -> NodeId {
        self.client
    }
}

impl Drop for Transaction {
    fn drop(&mut self) {
        // A transaction dropped mid-flight (panic, programming error) must
        // not wedge the rest of the system: roll it back.
        if self.phase == Phase::Running {
            let _ = self.abort_with(&TxError::ManualAbort);
        }
    }
}

/// Convenience: stats field as an `Arc` for proxies.
impl AtomicRmi2 {
    pub(super) fn stats_arc(&self) -> Arc<super::SysStats> {
        Arc::clone(&self.stats)
    }
}

// `TxStats` is produced by the `Dtm` driver in `optsva::mod`; re-exported
// here so callers that use the concrete API see the same type.
pub use crate::api::TxStats as Stats;

#[cfg(test)]
mod tests {
    use super::super::{AtomicRmi2, OptsvaConfig};
    use crate::api::{Suprema, TxCtx, TxError};
    use crate::cluster::{Cluster, NetworkModel, NodeId};
    use crate::object::{account::ops, Account};
    use std::sync::Arc;
    use std::time::Duration;

    fn sys_n(nodes: u16) -> Arc<AtomicRmi2> {
        let cluster = Arc::new(Cluster::new(nodes, NetworkModel::instant()));
        AtomicRmi2::with_config(
            cluster,
            OptsvaConfig { wait_timeout: Some(Duration::from_secs(10)), asynchrony: true },
        )
    }

    #[test]
    fn transfer_commits_and_is_visible() {
        let sys = sys_n(2);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(100)));
        let b = sys.host(NodeId(1), "B", Box::new(Account::with_balance(0)));

        let mut tx = sys.tx(NodeId(0));
        let ha = tx.accesses("A", Suprema::new(1, 0, 1));
        let hb = tx.updates("B", 1);
        tx.begin().unwrap();
        tx.call(ha, ops::withdraw(100)).unwrap();
        tx.call(hb, ops::deposit(100)).unwrap();
        assert_eq!(tx.call(ha, ops::balance()).unwrap().as_int(), 0);
        tx.commit().unwrap();

        assert_eq!(sys.with_object(a, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()), 0);
        assert_eq!(sys.with_object(b, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()), 100);
        assert_eq!(sys.stats.commits.load(std::sync::atomic::Ordering::Relaxed), 1);
        sys.shutdown();
    }

    #[test]
    fn manual_abort_restores_state() {
        let sys = sys_n(1);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(50)));
        let mut tx = sys.tx(NodeId(0));
        let ha = tx.updates("A", 2);
        tx.begin().unwrap();
        tx.call(ha, ops::withdraw(100)).unwrap();
        tx.abort().unwrap();
        assert_eq!(sys.with_object(a, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()), 50);
        sys.shutdown();
    }

    #[test]
    fn run_driver_commits_on_ok_and_aborts_on_err() {
        let sys = sys_n(1);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(10)));

        let mut tx = sys.tx(NodeId(0));
        let ha = tx.updates("A", 1);
        let ops_done = tx.run(|t| {
            t.call(ha, ops::deposit(5))?;
            Ok(())
        });
        assert_eq!(ops_done.unwrap(), 1);

        // Fig 9 shape: withdraw then abort when the balance went negative.
        let mut tx = sys.tx(NodeId(0));
        let ha2 = tx.accesses("A", Suprema::new(1, 0, 1));
        let r = tx.run(|t| {
            t.call(ha2, ops::withdraw(100))?;
            if t.call(ha2, ops::balance())?.as_int() < 0 {
                return t.abort();
            }
            Ok(())
        });
        assert_eq!(r.unwrap_err(), TxError::ManualAbort);
        assert_eq!(sys.with_object(a, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()), 15);
        sys.shutdown();
    }

    #[test]
    fn unknown_object_name_fails_begin() {
        let sys = sys_n(1);
        let mut tx = sys.tx(NodeId(0));
        tx.reads("nope", 1);
        assert!(matches!(tx.begin(), Err(TxError::NotDeclared(_))));
    }

    #[test]
    fn versioning_orders_conflicting_transactions() {
        let sys = sys_n(1);
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
        let mut handles = vec![];
        for _ in 0..8 {
            let sys = Arc::clone(&sys);
            handles.push(std::thread::spawn(move || {
                let mut tx = sys.tx(NodeId(0));
                let h = tx.updates("A", 1);
                tx.run(|t| {
                    t.call(h, ops::deposit(1))?;
                    Ok(())
                })
                .unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(sys.with_object(
            sys.cluster().registry.locate("A").unwrap(),
            |o| o.as_any().downcast_ref::<Account>().unwrap().balance()
        ), 8);
        assert_eq!(sys.stats.commits.load(std::sync::atomic::Ordering::Relaxed), 8);
        sys.shutdown();
    }

    #[test]
    fn cascading_abort_dooms_the_reader() {
        let sys = sys_n(1);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(100)));

        // T1 updates A and releases early (supremum reached), then aborts.
        let mut t1 = sys.tx(NodeId(0));
        let h1 = t1.updates("A", 1);
        t1.begin().unwrap();
        t1.call(h1, ops::deposit(900)).unwrap(); // released early (lv := pv1)

        // T2 reads the early-released (dirty) state.
        let mut t2 = sys.tx(NodeId(0));
        let h2 = t2.accesses("A", Suprema::new(1, 0, 1));
        t2.begin().unwrap();
        assert_eq!(t2.call(h2, ops::balance()).unwrap().as_int(), 1000);

        // T1 aborts ⇒ A restored; T2 is doomed and must fail at commit.
        t1.abort().unwrap();
        let r = t2.commit();
        assert!(matches!(r, Err(TxError::ForcedAbort(_))), "got {r:?}");
        assert_eq!(sys.with_object(a, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()), 100);
        sys.shutdown();
    }

    #[test]
    fn irrevocable_transaction_waits_for_termination_not_release() {
        let sys = sys_n(1);
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));

        // T1 updates A and releases early, but does not terminate yet.
        let mut t1 = sys.tx(NodeId(0));
        let h1 = t1.updates("A", 1);
        t1.begin().unwrap();
        t1.call(h1, ops::deposit(1)).unwrap();
        assert!(t1.proxy(h1).released());

        // An irrevocable T2 must NOT accept the early release: its read
        // blocks until T1 terminates.
        let sys2 = Arc::clone(&sys);
        let t2_thread = std::thread::spawn(move || {
            let mut t2 = sys2.tx(NodeId(0)).irrevocable();
            let h2 = t2.accesses("A", Suprema::new(1, 0, 1));
            t2.begin().unwrap();
            let v = t2.call(h2, ops::balance()).unwrap().as_int();
            t2.commit().unwrap();
            v
        });
        std::thread::sleep(Duration::from_millis(50));
        assert!(!t2_thread.is_finished(), "irrevocable read must wait for ltv");
        t1.commit().unwrap();
        assert_eq!(t2_thread.join().unwrap(), 1);
        sys.shutdown();
    }

    #[test]
    fn dropped_running_transaction_rolls_back() {
        let sys = sys_n(1);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(5)));
        {
            let mut tx = sys.tx(NodeId(0));
            let h = tx.updates("A", 2);
            tx.begin().unwrap();
            tx.call(h, ops::deposit(10)).unwrap();
            // dropped without commit/abort
        }
        assert_eq!(sys.with_object(a, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()), 5);
        // A following transaction is not blocked.
        let mut tx = sys.tx(NodeId(0));
        let h = tx.updates("A", 1);
        tx.run(|t| {
            t.call(h, ops::deposit(1))?;
            Ok(())
        })
        .unwrap();
        sys.shutdown();
    }
}
