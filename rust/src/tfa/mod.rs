//! **TFA — the HyFlow2 stand-in** (paper §4.1, [Saad & Ravindran,
//! SBAC-PAD'12; Turcu et al., PPPJ'13]).
//!
//! An optimistic, *data-flow* DTM implementing the Transaction Forwarding
//! Algorithm in the same simulated cluster as the pessimistic frameworks —
//! a fairer comparison than measuring across runtimes (the paper compared
//! its Java system against HyFlow2's Scala runtime).
//!
//! Mechanics reproduced from the TFA papers:
//!
//!   * **node-local clocks** (`lc`), piggybacked on every message;
//!   * each object carries the **commit version** of its last writer;
//!   * on first access a transaction **fetches the whole object** to the
//!     client (data-flow: state migrates; the network pays `state_size`);
//!   * if the fetched version exceeds the transaction's start clock, the
//!     transaction **forwards** its clock after **revalidating** its read
//!     set — failure means an abort + retry;
//!   * all operations run on the **local copies**; writes are lazy
//!     (write-back);
//!   * commit: acquire per-object try-locks on the write set in global
//!     `Oid` order (fail ⇒ abort), revalidate the read set, bump the home
//!     clocks, write back, unlock.
//!
//! TFA is opaque but has no provision for irrevocable operations: aborted
//! transactions re-execute their bodies (Fig 13 counts how often).

use crate::api::{run_with_retries, Dtm, ObjHandle, OpFuture, TxCtx, TxError, TxSpec, TxStats};
use crate::clock::Clock;
use crate::cluster::{Cluster, NodeId, Oid};
use crate::locks::{DistRwLock, LockMode};
use crate::object::{OpCall, SharedObject, Value};
use crate::util::prng::Prng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// Default bound on optimistic re-executions (conflict aborts are TFA's
/// normal operating mode, so the budget is far above the pessimistic
/// frameworks' [`crate::api::DEFAULT_MAX_ATTEMPTS`]).
const OPTIMISTIC_MAX_ATTEMPTS: u64 = 10_000;

/// A hosted object: live state + commit version + commit lock.
struct Slot {
    oid: Oid,
    version: AtomicU64,
    lock: DistRwLock,
    object: Mutex<Box<dyn SharedObject>>,
}

/// The TFA system.
pub struct TfaSystem {
    cluster: Arc<Cluster>,
    slots: Vec<RwLock<Vec<Arc<Slot>>>>,
    /// Node-local clocks.
    clocks: Vec<AtomicU64>,
    /// Committed transactions.
    pub commit_count: AtomicU64,
    /// Aborted attempts (conflict + manual).
    pub abort_count: AtomicU64,
    /// Base backoff between retries.
    pub backoff: Duration,
}

impl TfaSystem {
    /// A TFA system over `cluster` (no objects hosted yet).
    pub fn new(cluster: Arc<Cluster>) -> Arc<Self> {
        let slots = cluster.node_ids().map(|_| RwLock::new(Vec::new())).collect();
        let clocks = cluster.node_ids().map(|_| AtomicU64::new(0)).collect();
        Arc::new(TfaSystem {
            cluster,
            slots,
            clocks,
            commit_count: AtomicU64::new(0),
            abort_count: AtomicU64::new(0),
            backoff: Duration::from_micros(200),
        })
    }

    /// Host `object` on `node` under `name`.
    pub fn host(&self, node: NodeId, name: &str, object: Box<dyn SharedObject>) -> Oid {
        let mut slots = self.slots[node.0 as usize].write().unwrap();
        let oid = Oid::new(node, slots.len() as u32);
        slots.push(Arc::new(Slot {
            oid,
            version: AtomicU64::new(0),
            lock: DistRwLock::new(),
            object: Mutex::new(object),
        }));
        drop(slots);
        self.cluster.registry.bind(name, oid);
        oid
    }

    fn slot(&self, oid: Oid) -> Arc<Slot> {
        let slots = self.slots[oid.node.0 as usize].read().unwrap();
        Arc::clone(&slots[oid.index as usize])
    }

    /// Peek at an object's state (non-transactional test helper).
    pub fn with_object<R>(&self, oid: Oid, f: impl FnOnce(&dyn SharedObject) -> R) -> R {
        let slot = self.slot(oid);
        let obj = slot.object.lock().unwrap();
        f(obj.as_ref())
    }

    /// The cluster this system runs on.
    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    fn clock(&self, node: NodeId) -> &AtomicU64 {
        &self.clocks[node.0 as usize]
    }
}

/// A fetched local copy (data-flow).
struct LocalCopy {
    slot: Arc<Slot>,
    copy: Box<dyn SharedObject>,
    /// Version observed at fetch time.
    read_version: u64,
    dirty: bool,
    ops: u64,
}

/// One optimistic execution attempt.
struct TfaTx<'a> {
    sys: &'a TfaSystem,
    client: NodeId,
    /// Transaction start clock (forwarded on demand).
    wv: u64,
    /// Declared handles, lazily fetched.
    oids: Vec<Oid>,
    copies: Vec<Option<LocalCopy>>,
}

impl TfaTx<'_> {
    /// Validate the read set: every fetched object's home version must
    /// still be what we read. One RPC per fetched object.
    fn validate(&self) -> Result<(), TxError> {
        for c in self.copies.iter().flatten() {
            let ok = self.sys.cluster.rpc(self.client, c.slot.oid.node, 16, || {
                (c.slot.version.load(Ordering::Acquire) == c.read_version, 9)
            });
            if !ok {
                return Err(TxError::Conflict(format!(
                    "read of {} (v{}) invalidated",
                    c.slot.oid, c.read_version
                )));
            }
        }
        Ok(())
    }

    /// Fetch `h`'s object to the client if not yet local, applying
    /// transaction forwarding when the object is newer than our clock.
    fn ensure_local(&mut self, h: ObjHandle) -> Result<(), TxError> {
        if self.copies[h.0].is_some() {
            return Ok(());
        }
        let oid = self.oids[h.0];
        let slot = self.sys.slot(oid);
        // Data-flow: the whole object state crosses the network.
        let (copy, rv) = self.sys.cluster.rpc(self.client, oid.node, 24, || {
            let obj = slot.object.lock().unwrap();
            let snap = obj.snapshot();
            let size = obj.state_size();
            ((snap, slot.version.load(Ordering::Acquire)), size + 9)
        });
        if rv > self.wv {
            // Transaction forwarding: revalidate everything read so far,
            // then advance our clock to the object's version.
            self.validate()?;
            self.wv = rv;
        }
        self.copies[h.0] = Some(LocalCopy { slot, copy, read_version: rv, dirty: false, ops: 0 });
        Ok(())
    }

    /// Commit: lock the write set (try-locks, global order), revalidate,
    /// bump clocks, write back, unlock.
    fn commit(&mut self) -> Result<u64, TxError> {
        // Gather the write set in Oid order.
        let mut write_idx: Vec<usize> = self
            .copies
            .iter()
            .enumerate()
            .filter(|(_, c)| c.as_ref().is_some_and(|c| c.dirty))
            .map(|(i, _)| i)
            .collect();
        write_idx.sort_by_key(|&i| self.oids[i]);

        let mut locked: Vec<usize> = Vec::with_capacity(write_idx.len());
        for &i in &write_idx {
            let c = self.copies[i].as_ref().unwrap();
            let ok = self.sys.cluster.rpc(self.client, c.slot.oid.node, 16, || {
                (c.slot.lock.try_lock(LockMode::Exclusive), 2)
            });
            if !ok {
                for &j in &locked {
                    let cj = self.copies[j].as_ref().unwrap();
                    cj.slot.lock.unlock(LockMode::Exclusive);
                }
                return Err(TxError::Conflict(format!(
                    "commit lock on {} contended",
                    c.slot.oid
                )));
            }
            locked.push(i);
        }

        if let Err(e) = self.validate() {
            for &j in &locked {
                let cj = self.copies[j].as_ref().unwrap();
                cj.slot.lock.unlock(LockMode::Exclusive);
            }
            return Err(e);
        }

        // Write back: new version = home clock + 1 (per home node).
        for &i in &write_idx {
            let c = self.copies[i].as_mut().unwrap();
            let node = c.slot.oid.node;
            let clock = self.sys.clock(node);
            let slot = Arc::clone(&c.slot);
            let copy_ref = &c.copy;
            let size = copy_ref.state_size();
            self.sys.cluster.rpc(self.client, node, size + 16, || {
                let nv = clock.fetch_add(1, Ordering::AcqRel) + 1;
                let mut obj = slot.object.lock().unwrap();
                obj.restore(copy_ref.as_ref());
                slot.version.store(nv, Ordering::Release);
                slot.lock.unlock(LockMode::Exclusive);
                ((), 9)
            });
        }
        Ok(self.copies.iter().flatten().map(|c| c.ops).sum())
    }
}

impl TxCtx for TfaTx<'_> {
    /// TFA executes on local copies (data-flow), so there is nothing to
    /// overlap: `submit` runs the operation inline and returns a resolved
    /// future; `call` (the trait default) is unchanged.
    fn submit(&mut self, h: ObjHandle, call: OpCall) -> Result<OpFuture, TxError> {
        Ok(OpFuture::ready(self.invoke_local(h, call)))
    }

    fn client(&self) -> NodeId {
        self.client
    }
}

impl TfaTx<'_> {
    fn invoke_local(&mut self, h: ObjHandle, call: OpCall) -> Result<Value, TxError> {
        self.ensure_local(h)?;
        let c = self.copies[h.0].as_mut().unwrap();
        // All operations execute on the local copy — reads, writes and
        // updates alike (the CF-vs-DF distinction the paper draws).
        let mode = crate::object::mode_of(c.copy.as_ref(), call.method)?;
        let v = c.copy.invoke(&call)?;
        if mode != crate::object::Mode::Read {
            c.dirty = true;
        }
        c.ops += 1;
        Ok(v)
    }
}

impl Dtm for Arc<TfaSystem> {
    fn framework_name(&self) -> &'static str {
        "hyflow2 (TFA)"
    }

    // TFA has no irrevocable support (§4.1) — the body simply re-executes
    // on abort; the spec's irrevocable/timeout/asynchrony knobs are ignored.
    fn run_tx(
        &self,
        client: NodeId,
        spec: &TxSpec,
        body: &mut dyn FnMut(&mut dyn TxCtx) -> Result<(), TxError>,
    ) -> Result<TxStats, TxError> {
        // Resolve names once.
        let mut oids = Vec::with_capacity(spec.decls.len());
        for d in &spec.decls {
            oids.push(
                self.cluster
                    .registry
                    .locate(&d.name)
                    .ok_or_else(|| TxError::NotDeclared(d.name.clone()))?,
            );
        }
        let mut rng = Prng::seeded(
            0x7FA0_5EED ^ ((client.0 as u64) << 32) ^ self.commit_count.load(Ordering::Relaxed),
        );
        let outcome = run_with_retries(
            // Optimistic conflicts retry routinely: TFA's default budget is
            // an order of magnitude above the pessimistic frameworks'.
            spec.max_attempts.unwrap_or(OPTIMISTIC_MAX_ATTEMPTS),
            || {
                let mut tx = TfaTx {
                    sys: self,
                    client,
                    wv: self.clock(client).load(Ordering::Acquire),
                    oids: oids.clone(),
                    copies: (0..oids.len()).map(|_| None).collect(),
                };
                match body(&mut tx) {
                    Ok(()) => tx.commit(),
                    Err(e) => Err(e),
                }
            },
            |attempt, _e| {
                self.abort_count.fetch_add(1, Ordering::Relaxed);
                // Randomized exponential backoff, capped at 32× base —
                // paid through the cluster clock (virtual-time safe).
                let factor = 1u64 << attempt.min(5);
                let jitter = rng.below(self.backoff.as_micros() as u64 * factor + 1);
                self.cluster.clock().sleep(Duration::from_micros(jitter));
            },
        );
        match outcome {
            Ok(stats) => {
                self.commit_count.fetch_add(1, Ordering::Relaxed);
                Ok(stats)
            }
            Err(e) => {
                self.abort_count.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    fn aborts(&self) -> u64 {
        self.abort_count.load(Ordering::Relaxed)
    }

    fn commits(&self) -> u64 {
        self.commit_count.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{AccessDecl, Suprema};
    use crate::cluster::NetworkModel;
    use crate::object::{account::ops, Account};

    fn sys() -> Arc<TfaSystem> {
        TfaSystem::new(Arc::new(Cluster::new(2, NetworkModel::instant())))
    }

    /// Run a body over a declaration list through the builder front end.
    fn run(
        sys: &Arc<TfaSystem>,
        client: NodeId,
        decls: &[AccessDecl],
        body: impl FnMut(&mut dyn TxCtx) -> Result<(), TxError>,
    ) -> Result<TxStats, TxError> {
        (sys as &dyn Dtm)
            .tx(client)
            .with_decls(decls)
            .run(body)
            .map(|((), stats)| stats)
    }

    #[test]
    fn transfer_commits_and_writes_back() {
        let sys = sys();
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(100)));
        let b = sys.host(NodeId(1), "B", Box::new(Account::with_balance(0)));
        let decls = vec![
            AccessDecl::new("A", Suprema::unknown()),
            AccessDecl::new("B", Suprema::unknown()),
        ];
        run(&sys, NodeId(0), &decls, |t| {
            t.call(ObjHandle(0), ops::withdraw(25))?;
            t.call(ObjHandle(1), ops::deposit(25))?;
            Ok(())
        })
        .unwrap();
        assert_eq!(sys.with_object(a, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()), 75);
        assert_eq!(sys.with_object(b, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()), 25);
        // Versions advanced.
        assert!(sys.slot(a).version.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn conflicting_writers_retry_until_serialized() {
        let sys = sys();
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
        let decls = vec![AccessDecl::new("A", Suprema::unknown())];
        let mut handles = vec![];
        for _ in 0..8 {
            let sys = Arc::clone(&sys);
            let decls = decls.clone();
            handles.push(std::thread::spawn(move || {
                run(&sys, NodeId(0), &decls, |t| {
                    let v = t.call(ObjHandle(0), ops::balance())?.as_int();
                    t.call(ObjHandle(0), ops::deposit(1))?;
                    let _ = v;
                    Ok(())
                })
                .unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let oid = sys.cluster().registry.locate("A").unwrap();
        assert_eq!(
            sys.with_object(oid, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()),
            8,
            "lost update: optimistic validation failed to serialize"
        );
        assert_eq!(sys.commits(), 8);
    }

    #[test]
    fn stale_read_forces_conflict() {
        let sys = sys();
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
        let decls = vec![AccessDecl::new("A", Suprema::unknown())];

        // A transaction reads A, then another commits a write to A before
        // the first commits its own write ⇒ validation must fail once.
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let sys2 = Arc::clone(&sys);
        let d2 = decls.clone();
        let b2 = Arc::clone(&barrier);
        let t = std::thread::spawn(move || {
            let mut first = true;
            run(&sys2, NodeId(1), &d2, |t| {
                let _ = t.call(ObjHandle(0), ops::balance())?;
                if first {
                    first = false;
                    b2.wait(); // let the interferer commit
                    b2.wait();
                }
                t.call(ObjHandle(0), ops::deposit(10))?;
                Ok(())
            })
            .unwrap()
        });
        barrier.wait();
        run(&sys, NodeId(0), &decls, |t| {
            t.call(ObjHandle(0), ops::deposit(1))?;
            Ok(())
        })
        .unwrap();
        barrier.wait();
        let stats = t.join().unwrap();
        assert!(stats.attempts >= 2, "expected a retry, got {}", stats.attempts);
        assert_eq!(sys.with_object(a, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()), 11);
        assert!(sys.aborts() >= 1);
    }

    #[test]
    fn read_only_transactions_do_not_abort_each_other() {
        let sys = sys();
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(5)));
        let decls = vec![AccessDecl::new("A", Suprema::reads(1))];
        let mut handles = vec![];
        for _ in 0..4 {
            let sys = Arc::clone(&sys);
            let decls = decls.clone();
            handles.push(std::thread::spawn(move || {
                run(&sys, NodeId(0), &decls, |t| {
                    assert_eq!(t.call(ObjHandle(0), ops::balance())?.as_int(), 5);
                    Ok(())
                })
                .unwrap()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap().attempts, 1);
        }
        assert_eq!(sys.aborts(), 0);
    }
}
