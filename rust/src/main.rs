//! `atomic-rmi2` — the launcher.
//!
//! ```text
//! atomic-rmi2 eigenbench [--config FILE] [--framework F] [--nodes N] …
//! atomic-rmi2 sweep fig10|fig11|fig12|fig13 [--quick] [--csv]
//! atomic-rmi2 demo
//! atomic-rmi2 list-frameworks
//! ```
//!
//! `eigenbench` runs one scenario (file options overridden by CLI flags);
//! `sweep` regenerates a paper figure (tables on stdout, raw CSV and
//! `BENCH_*.json` under `target/bench-results/`); `demo` runs the Fig 9
//! bank transfer; `bench-gate` compares a fresh `BENCH_*.json` against a
//! committed baseline and exits non-zero on regression (the CI gate —
//! see `docs/BENCHMARKS.md`).

use atomic_rmi2::bench::{gate, BenchReport};
use atomic_rmi2::config::{CliArgs, KvConfig};
use atomic_rmi2::metrics::fmt_throughput;
use atomic_rmi2::object::{Account, AccountRef};
use atomic_rmi2::workload::sweeps::{self, Scale};
use atomic_rmi2::workload::{run_eigenbench, FrameworkKind, ALL_FRAMEWORKS};
use atomic_rmi2::{AtomicRmi2, Cluster, NetworkModel, NodeId, Suprema, TxCtx};
use std::sync::Arc;

const USAGE: &str = "\
atomic-rmi2 — highly parallel pessimistic distributed TM (OptSVA-CF)

USAGE:
  atomic-rmi2 eigenbench [--config FILE] [--framework F] [--nodes N]
              [--clients_per_node C] [--arrays_per_node A] [--read_pct P]
              [--hot_ops H] [--mild_ops M] [--txns_per_client T]
              [--op_delay_us U] [--irrevocable true] [--seed S]
  atomic-rmi2 sweep fig10|fig11|fig12|fig13|all [--quick]
  atomic-rmi2 bench-gate FRESH.json BASELINE.json [--tolerance 0.20]
  atomic-rmi2 demo
  atomic-rmi2 list-frameworks

Set ARMI2_BENCH_GATE_SKIP=1 to make bench-gate report and exit 0 even on
regression (escape hatch for known-noisy runners).
";

fn main() {
    let args = CliArgs::parse(std::env::args().skip(1));
    match args.positional.first().map(String::as_str) {
        Some("eigenbench") => eigenbench(&args),
        Some("sweep") => sweep(&args),
        Some("bench-gate") => bench_gate(&args),
        Some("demo") => demo(),
        Some("list-frameworks") => {
            for k in ALL_FRAMEWORKS {
                println!("{}", k.label());
            }
            println!("{}", FrameworkKind::OptsvaNoAsync.label());
        }
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn eigenbench(args: &CliArgs) {
    let file_kv = match args.option("config") {
        Some(path) => match KvConfig::load(path) {
            Ok(kv) => kv,
            Err(e) => {
                eprintln!("config error: {e}");
                std::process::exit(1);
            }
        },
        None => KvConfig::default(),
    };
    let kv = args.overlay(file_kv);
    let params = match kv.to_eigenbench() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("config error: {e}");
            std::process::exit(1);
        }
    };
    eprintln!(
        "running eigenbench: {} on {} nodes × {} clients, {} arrays/node, {} ({} hot + {} mild ops/txn)",
        params.kind.label(),
        params.nodes,
        params.clients_per_node,
        params.arrays_per_node,
        params.ratio_label(),
        params.hot_ops,
        params.mild_ops,
    );
    let r = run_eigenbench(&params);
    println!("framework          : {}", r.framework);
    println!("throughput         : {} ops/s", fmt_throughput(r.throughput));
    println!("committed txns/ops : {}/{}", r.committed_txns, r.committed_ops);
    println!("aborts             : {} (rate {:.1}%)", r.aborts, r.abort_rate * 100.0);
    println!("wall time          : {} ms", r.wall.as_millis());
    println!("simulated time     : {} ms (virtual_time=false to sleep for real)", r.sim.as_millis());
    println!("txn latency        : {}", r.latency.summary());
}

fn sweep(args: &CliArgs) {
    let scale = if args.flag("quick") { Scale::Quick } else { Scale::Full };
    let which = args.positional.get(1).map(String::as_str).unwrap_or("all");
    let run_one = |name: &str| {
        match name {
            "fig10" => {
                let (tables, results) = sweeps::fig10(scale);
                for t in &tables {
                    println!("{}", t.render());
                }
                report_results("fig10", scale, &results);
            }
            "fig11" => {
                let (tables, results) = sweeps::fig11(scale);
                for t in &tables {
                    println!("{}", t.render());
                }
                report_results("fig11", scale, &results);
            }
            "fig12" => {
                let (tables, results) = sweeps::fig12(scale);
                for t in &tables {
                    println!("{}", t.render());
                }
                report_results("fig12", scale, &results);
            }
            "fig13" => {
                let (table, results) = sweeps::fig13(scale);
                println!("{}", table.render());
                report_results("fig13", scale, &results);
            }
            other => {
                eprintln!("unknown figure {other:?}; use fig10|fig11|fig12|fig13|all");
                std::process::exit(2);
            }
        };
    };
    if which == "all" {
        for name in ["fig10", "fig11", "fig12", "fig13"] {
            run_one(name);
        }
    } else {
        run_one(which);
    }
}

fn report_results(name: &str, scale: Scale, results: &[atomic_rmi2::workload::EigenbenchResult]) {
    match sweeps::write_results_csv(name, results) {
        Ok(path) => eprintln!("raw results: {path}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    match sweeps::write_results_json(name, scale, results) {
        Ok(path) => eprintln!("report: {path}"),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}

fn load_report(path: &str) -> BenchReport {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("bench-gate: cannot read {path}: {e}");
            std::process::exit(2);
        }
    };
    match BenchReport::parse(&text) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("bench-gate: cannot parse {path}: {e}");
            std::process::exit(2);
        }
    }
}

fn bench_gate(args: &CliArgs) {
    let (Some(fresh_path), Some(base_path)) = (args.positional.get(1), args.positional.get(2))
    else {
        eprintln!("usage: atomic-rmi2 bench-gate FRESH.json BASELINE.json [--tolerance 0.20]");
        std::process::exit(2);
    };
    let tolerance = match args.option("tolerance") {
        None => 0.20,
        Some(t) => match t.parse::<f64>() {
            Ok(v) if v >= 0.0 => v,
            _ => {
                eprintln!("bench-gate: --tolerance must be a non-negative number, got {t:?}");
                std::process::exit(2);
            }
        },
    };
    let fresh = load_report(fresh_path);
    let baseline = load_report(base_path);
    let outcome = gate(&fresh, &baseline, tolerance);
    if let Some(reason) = &outcome.skipped {
        println!("bench-gate: SKIPPED — {reason}");
        return;
    }
    println!(
        "bench-gate: compared {} metric(s) of {:?} against {base_path} (tolerance {:.0}%)",
        outcome.compared,
        fresh.bench,
        tolerance * 100.0,
    );
    for f in &outcome.failures {
        println!("  REGRESSION: {f}");
    }
    if outcome.passed() {
        println!("bench-gate: PASS");
    } else if std::env::var_os("ARMI2_BENCH_GATE_SKIP").is_some_and(|v| v == "1") {
        println!("bench-gate: FAIL, ignored (ARMI2_BENCH_GATE_SKIP=1)");
    } else {
        println!("bench-gate: FAIL");
        std::process::exit(1);
    }
}

fn demo() {
    let cluster = Arc::new(Cluster::new(2, NetworkModel::lan()));
    let sys = AtomicRmi2::new(Arc::clone(&cluster));
    sys.host(NodeId(0), "A", Box::new(Account::with_balance(500)));
    sys.host(NodeId(1), "B", Box::new(Account::with_balance(100)));
    let mut tx = sys.tx(NodeId(0));
    let a = AccountRef::new(tx.accesses("A", Suprema::new(1, 0, 1)));
    let b = AccountRef::new(tx.updates("B", 1));
    let r = tx.run(|t| {
        a.withdraw(t, 100)?;
        b.deposit(t, 100)?;
        if a.balance(t)? < 0 {
            return t.abort();
        }
        Ok(())
    });
    println!("demo transfer: {r:?}");
    for name in ["A", "B"] {
        let oid = cluster.registry.locate(name).unwrap();
        let bal = sys.with_object(oid, |o| {
            o.as_any().downcast_ref::<Account>().unwrap().balance()
        });
        println!("{name} = {bal}");
    }
    sys.shutdown();
}
