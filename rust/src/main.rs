//! `atomic-rmi2` — the launcher.
//!
//! ```text
//! atomic-rmi2 eigenbench [--config FILE] [--framework F] [--nodes N] …
//! atomic-rmi2 sweep fig10|fig11|fig11ext|fig12|fig13 [--quick] [--csv]
//! atomic-rmi2 check [--scenario NAME] [--mutation M] [--schedule SID] …
//! atomic-rmi2 demo
//! atomic-rmi2 list-frameworks
//! ```
//!
//! `eigenbench` runs one scenario (file options overridden by CLI flags);
//! `sweep` regenerates a paper figure (tables on stdout, raw CSV and
//! `BENCH_*.json` under `target/bench-results/`); `check` explores
//! transaction schedules deterministically and checks every history for
//! last-use opacity and deadlock-freedom (see `docs/ANALYSIS.md`); `demo`
//! runs the Fig 9 bank transfer; `bench-gate` compares a fresh
//! `BENCH_*.json` against a committed baseline and exits non-zero on
//! regression (the CI gate — see `docs/BENCHMARKS.md`).

use atomic_rmi2::analysis::{self, ExploreConfig, ScheduleId};
use atomic_rmi2::bench::{gate, BenchReport};
use atomic_rmi2::config::{CliArgs, KvConfig};
use atomic_rmi2::metrics::fmt_throughput;
use atomic_rmi2::object::{Account, AccountRef};
use atomic_rmi2::optsva::ProtocolMutation;
use atomic_rmi2::trace::{self, perfetto, TraceSession};
use atomic_rmi2::workload::sweeps::{self, Scale};
use atomic_rmi2::workload::{run_eigenbench, FrameworkKind, ALL_FRAMEWORKS};
use atomic_rmi2::{AtomicRmi2, Cluster, NetworkModel, NodeId, Suprema, TxCtx};
use std::sync::Arc;

const USAGE: &str = "\
atomic-rmi2 — highly parallel pessimistic distributed TM (OptSVA-CF)

USAGE:
  atomic-rmi2 eigenbench [--config FILE] [--framework F] [--nodes N]
              [--clients_per_node C] [--arrays_per_node A] [--read_pct P]
              [--hot_ops H] [--mild_ops M] [--txns_per_client T]
              [--op_delay_us U] [--irrevocable true] [--seed S]
  atomic-rmi2 sweep fig10|fig11|fig11ext|fig12|fig13|all [--quick]
              (fig11ext: megascale node-count sweep on the discrete-event
               engine; not part of `all` — run it explicitly)
  atomic-rmi2 check [--scenario NAME] [--seeds N] [--flip-depth D]
              [--flip-bases B] [--min-distinct K]
              [--mutation none|premature-release|skip-invalidation|bogus-commute]
              [--schedule SID] [--expect-violation] [--timeline]
  atomic-rmi2 trace SCENARIO [--seed N] [--out FILE] [--timeline]
  atomic-rmi2 bench-gate FRESH.json BASELINE.json [--tolerance 0.20]
  atomic-rmi2 demo
  atomic-rmi2 list-frameworks

Set ARMI2_BENCH_GATE_SKIP=1 to make bench-gate report and exit 0 even on
regression (escape hatch for known-noisy runners).
";

fn main() {
    let args = CliArgs::parse(std::env::args().skip(1));
    match args.positional.first().map(String::as_str) {
        Some("eigenbench") => eigenbench(&args),
        Some("sweep") => sweep(&args),
        Some("check") => check(&args),
        Some("trace") => trace_cmd(&args),
        Some("bench-gate") => bench_gate(&args),
        Some("demo") => demo(),
        Some("list-frameworks") => {
            for k in ALL_FRAMEWORKS {
                println!("{}", k.label());
            }
            println!("{}", FrameworkKind::OptsvaNoAsync.label());
        }
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn eigenbench(args: &CliArgs) {
    let file_kv = match args.option("config") {
        Some(path) => match KvConfig::load(path) {
            Ok(kv) => kv,
            Err(e) => {
                eprintln!("config error: {e}");
                std::process::exit(1);
            }
        },
        None => KvConfig::default(),
    };
    let kv = args.overlay(file_kv);
    let params = match kv.to_eigenbench() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("config error: {e}");
            std::process::exit(1);
        }
    };
    eprintln!(
        "running eigenbench: {} on {} nodes × {} clients, {} arrays/node, {} ({} hot + {} mild ops/txn)",
        params.kind.label(),
        params.nodes,
        params.clients_per_node,
        params.arrays_per_node,
        params.ratio_label(),
        params.hot_ops,
        params.mild_ops,
    );
    let r = run_eigenbench(&params);
    println!("framework          : {}", r.framework);
    println!("throughput         : {} ops/s", fmt_throughput(r.throughput));
    println!("committed txns/ops : {}/{}", r.committed_txns, r.committed_ops);
    println!("aborts             : {} (rate {:.1}%)", r.aborts, r.abort_rate * 100.0);
    println!("wall time          : {} ms", r.wall.as_millis());
    println!("simulated time     : {} ms (virtual_time=false to sleep for real)", r.sim.as_millis());
    println!("txn latency        : {}", r.latency.summary());
}

fn sweep(args: &CliArgs) {
    let scale = if args.flag("quick") { Scale::Quick } else { Scale::Full };
    let which = args.positional.get(1).map(String::as_str).unwrap_or("all");
    let run_one = |name: &str| {
        match name {
            "fig10" => {
                let (tables, results) = sweeps::fig10(scale);
                for t in &tables {
                    println!("{}", t.render());
                }
                report_results("fig10", scale, &results);
            }
            "fig11" => {
                let (tables, results) = sweeps::fig11(scale);
                for t in &tables {
                    println!("{}", t.render());
                }
                report_results("fig11", scale, &results);
            }
            "fig11ext" => {
                let (table, results) = sweeps::fig11_extended(scale);
                println!("{}", table.render());
                let (flat_nodes, peak) = sweeps::flattening_point(&results);
                println!(
                    "flattening point: {} nodes (peak {} ops/s)",
                    flat_nodes,
                    fmt_throughput(peak)
                );
                match sweeps::write_megascale_json("fig11ext", scale, &results) {
                    Ok(path) => eprintln!("report: {path}"),
                    Err(e) => eprintln!("json write failed: {e}"),
                }
            }
            "fig12" => {
                let (tables, results) = sweeps::fig12(scale);
                for t in &tables {
                    println!("{}", t.render());
                }
                report_results("fig12", scale, &results);
            }
            "fig13" => {
                let (table, results) = sweeps::fig13(scale);
                println!("{}", table.render());
                report_results("fig13", scale, &results);
            }
            other => {
                eprintln!("unknown figure {other:?}; use fig10|fig11|fig11ext|fig12|fig13|all");
                std::process::exit(2);
            }
        };
    };
    if which == "all" {
        for name in ["fig10", "fig11", "fig12", "fig13"] {
            run_one(name);
        }
    } else {
        run_one(which);
    }
}

fn report_results(name: &str, scale: Scale, results: &[atomic_rmi2::workload::EigenbenchResult]) {
    match sweeps::write_results_csv(name, results) {
        Ok(path) => eprintln!("raw results: {path}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    match sweeps::write_results_json(name, scale, results) {
        Ok(path) => eprintln!("report: {path}"),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}

fn parse_num<T: std::str::FromStr>(args: &CliArgs, key: &str, default: T) -> T {
    match args.option(key) {
        None => default,
        Some(s) => match s.parse() {
            Ok(v) => v,
            Err(_) => {
                eprintln!("check: --{key} must be a number, got {s:?}");
                std::process::exit(2);
            }
        },
    }
}

fn check(args: &CliArgs) {
    let mutation = match args.option("mutation") {
        None => ProtocolMutation::None,
        Some(m) => match ProtocolMutation::parse(m) {
            Some(m) => m,
            None => {
                eprintln!(
                    "check: unknown --mutation {m:?}; use \
                     none|premature-release|skip-invalidation|bogus-commute"
                );
                std::process::exit(2);
            }
        },
    };
    let cfg = ExploreConfig {
        seeds: parse_num(args, "seeds", ExploreConfig::default().seeds),
        flip_depth: parse_num(args, "flip-depth", ExploreConfig::default().flip_depth),
        flip_bases: parse_num(args, "flip-bases", ExploreConfig::default().flip_bases),
        min_distinct: parse_num(args, "min-distinct", ExploreConfig::default().min_distinct),
        max_rounds: parse_num(args, "max-rounds", ExploreConfig::default().max_rounds),
        mutation,
    };
    let expect_violation = args.flag("expect-violation");
    let scenarios: Vec<analysis::Scenario> = match args.option("scenario") {
        None => analysis::scenarios::builtin(),
        Some(name) => match analysis::scenarios::by_name(name) {
            Some(s) => vec![s],
            None => {
                let names: Vec<&str> =
                    analysis::scenarios::builtin().iter().map(|s| s.name).collect();
                eprintln!("check: unknown scenario {name:?}; one of: {}", names.join(", "));
                std::process::exit(2);
            }
        },
    };

    // Single-schedule replay mode: run the named schedule, dump its
    // history, and report the checker verdict for exactly that run.
    if let Some(sid) = args.option("schedule") {
        let Some(id) = ScheduleId::parse(sid) else {
            eprintln!("check: bad --schedule {sid:?}; expected S<seed> or S<seed>~<k>.<a>");
            std::process::exit(2);
        };
        if scenarios.len() != 1 {
            eprintln!("check: --schedule needs an explicit --scenario");
            std::process::exit(2);
        }
        // `--timeline`: record the replay in a trace session and dump the
        // human-readable event timeline of the offending interleaving.
        let session = args.flag("timeline").then(TraceSession::start);
        let out = analysis::run_schedule(&scenarios[0], &id, mutation);
        if let Some(session) = session {
            let events = trace::normalize(&session.finish());
            print!("{}", trace::render_timeline(&events));
        }
        print!("{}", out.history);
        match &out.violation {
            Some(v) => {
                println!("VIOLATION: {v}");
                std::process::exit(if expect_violation { 0 } else { 1 });
            }
            None => {
                println!("schedule {id} is clean ({} op results verified)", out.ops_verified);
                if expect_violation {
                    std::process::exit(1);
                }
                return;
            }
        }
    }

    let mut total_violations = 0usize;
    let mut distinct_shortfall = false;
    for scenario in &scenarios {
        let report = analysis::explore(scenario, &cfg);
        println!("check: scenario {} — {}", scenario.name, scenario.description);
        println!(
            "  mutation  : {}",
            mutation.label()
        );
        println!(
            "  schedules : {} run, {} distinct (floor {})",
            report.runs, report.distinct_schedules, cfg.min_distinct
        );
        println!(
            "  txns      : {} committed, {} aborted; {} op results verified",
            report.committed, report.aborted, report.ops_verified
        );
        if report.violations.is_empty() {
            println!("  violations: none");
        } else {
            println!(
                "  violations: {} schedule(s){}",
                report.violations_total,
                if report.violations_total > report.violations.len() {
                    " (first shown)"
                } else {
                    ""
                }
            );
            for v in &report.violations {
                println!("    {}: {}", v.schedule, v.detail.replace('\n', "\n      "));
            }
            if let Some(first) = report.violations.first() {
                println!(
                    "  replay    : atomic-rmi2 check --scenario {} --schedule {}{}",
                    scenario.name,
                    first.schedule,
                    if mutation == ProtocolMutation::None {
                        String::new()
                    } else {
                        format!(" --mutation {}", mutation.label())
                    }
                );
            }
        }
        if report.lint.is_empty() {
            println!("  lint      : clean");
        } else {
            println!("  lint      : {} warning(s)", report.lint.len());
            for d in &report.lint {
                println!("    {d}");
            }
        }
        total_violations += report.violations_total;
        if report.distinct_schedules < cfg.min_distinct {
            distinct_shortfall = true;
            println!(
                "  WARNING: only {} distinct schedules (< {})",
                report.distinct_schedules, cfg.min_distinct
            );
        }
    }

    if expect_violation {
        if total_violations == 0 {
            println!("check: expected a violation under mutation {}, found none", mutation.label());
            std::process::exit(1);
        }
        println!("check: mutation caught ({total_violations} violating schedule(s)) — as expected");
        return;
    }
    if total_violations > 0 || distinct_shortfall {
        std::process::exit(1);
    }
    println!("check: all scenarios clean");
}

/// `atomic-rmi2 trace SCENARIO`: run one checker scenario under
/// VirtualClock with tracing on, print the aggregate wait/access summary,
/// and write a Perfetto-loadable trace JSON (plus a `BENCH_trace.json`
/// report entry under `target/bench-results/`).
fn trace_cmd(args: &CliArgs) {
    let Some(name) = args.positional.get(1) else {
        eprintln!("usage: atomic-rmi2 trace SCENARIO [--seed N] [--out FILE] [--timeline]");
        std::process::exit(2);
    };
    let Some(scenario) = analysis::scenarios::by_name(name) else {
        let names: Vec<&str> = analysis::scenarios::builtin().iter().map(|s| s.name).collect();
        eprintln!("trace: unknown scenario {name:?}; one of: {}", names.join(", "));
        std::process::exit(2);
    };
    let seed: u64 = parse_num(args, "seed", 0);

    let session = TraceSession::start();
    let out = analysis::run_schedule(&scenario, &ScheduleId::seed(seed), ProtocolMutation::None);
    let events = session.finish();
    let dropped = trace::dropped_events();

    let summary = trace::aggregate::summarize(&events);
    println!("{}", summary.table(format!("trace {name} (schedule {})", out.schedule)).render());
    println!(
        "txns               : {} committed, {} aborted, {} retries",
        summary.commits, summary.aborts, summary.retries
    );
    println!(
        "early releases     : {} (release_shrinkage {:.3})",
        summary.early_releases, summary.release_shrinkage
    );
    println!(
        "events             : {} ({} messages, {} tasks run)",
        summary.events, summary.messages, summary.tasks_run
    );
    if dropped > 0 {
        eprintln!("trace: WARNING — {dropped} event(s) dropped (ring buffer full)");
    }
    if let Some(v) = &out.violation {
        eprintln!("trace: note — checker flagged this schedule: {v}");
    }

    if args.flag("timeline") {
        print!("{}", trace::render_timeline(&trace::normalize(&events)));
    }

    // Perfetto export: render, self-validate with the crate's own parser
    // (the same check CI applies to the artifact), then write.
    let doc = perfetto::export(&events);
    let text = doc.render();
    if let Err(e) = atomic_rmi2::bench::Json::parse(&text) {
        eprintln!("trace: exported document failed to re-parse: {e}");
        std::process::exit(1);
    }
    let out_path = match args.option("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => std::path::PathBuf::from("target/trace").join(format!("{name}.json")),
    };
    if let Some(dir) = out_path.parent() {
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("trace: cannot create {}: {e}", dir.display());
            std::process::exit(1);
        }
    }
    if let Err(e) = std::fs::write(&out_path, &text) {
        eprintln!("trace: cannot write {}: {e}", out_path.display());
        std::process::exit(1);
    }
    println!("perfetto trace     : {} (load at ui.perfetto.dev)", out_path.display());

    let mut report = BenchReport::new("trace")
        .config("scenario", name)
        .config("schedule", &out.schedule);
    report.push(summary.bench_entry(name.as_str()));
    match report.write_to(&atomic_rmi2::bench::default_output_dir()) {
        Ok(path) => println!("report             : {}", path.display()),
        Err(e) => eprintln!("trace: report write failed: {e}"),
    }
}

fn load_report(path: &str) -> BenchReport {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("bench-gate: cannot read {path}: {e}");
            std::process::exit(2);
        }
    };
    match BenchReport::parse(&text) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("bench-gate: cannot parse {path}: {e}");
            std::process::exit(2);
        }
    }
}

/// Append a line to the GitHub Actions job summary, when running in CI
/// (`$GITHUB_STEP_SUMMARY` set). No-op locally.
fn append_step_summary(line: &str) {
    use std::io::Write as _;
    let Some(path) = std::env::var_os("GITHUB_STEP_SUMMARY") else { return };
    if let Ok(mut f) = std::fs::OpenOptions::new().append(true).create(true).open(path) {
        let _ = writeln!(f, "{line}");
    }
}

fn bench_gate(args: &CliArgs) {
    let (Some(fresh_path), Some(base_path)) = (args.positional.get(1), args.positional.get(2))
    else {
        eprintln!("usage: atomic-rmi2 bench-gate FRESH.json BASELINE.json [--tolerance 0.20]");
        std::process::exit(2);
    };
    let tolerance = match args.option("tolerance") {
        None => 0.20,
        Some(t) => match t.parse::<f64>() {
            Ok(v) if v >= 0.0 => v,
            _ => {
                eprintln!("bench-gate: --tolerance must be a non-negative number, got {t:?}");
                std::process::exit(2);
            }
        },
    };
    let fresh = load_report(fresh_path);
    let baseline = load_report(base_path);
    let outcome = gate(&fresh, &baseline, tolerance);
    if let Some(reason) = &outcome.skipped {
        println!("bench-gate: PROVISIONAL BASELINE — gate skipped ({reason})");
        append_step_summary(&format!(
            "> **bench-gate** `{base_path}`: PROVISIONAL BASELINE — gate skipped ({reason}). \
             Refresh the baseline from a CI artifact (see docs/BENCHMARKS.md)."
        ));
        return;
    }
    println!(
        "bench-gate: compared {} metric(s) of {:?} against {base_path} (tolerance {:.0}%)",
        outcome.compared,
        fresh.bench,
        tolerance * 100.0,
    );
    for f in &outcome.failures {
        println!("  REGRESSION: {f}");
    }
    if outcome.passed() {
        println!("bench-gate: PASS");
    } else if std::env::var_os("ARMI2_BENCH_GATE_SKIP").is_some_and(|v| v == "1") {
        println!("bench-gate: FAIL, ignored (ARMI2_BENCH_GATE_SKIP=1)");
    } else {
        println!("bench-gate: FAIL");
        std::process::exit(1);
    }
}

fn demo() {
    let cluster = Arc::new(Cluster::new(2, NetworkModel::lan()));
    let sys = AtomicRmi2::new(Arc::clone(&cluster));
    sys.host(NodeId(0), "A", Box::new(Account::with_balance(500)));
    sys.host(NodeId(1), "B", Box::new(Account::with_balance(100)));
    let mut tx = sys.tx(NodeId(0));
    let a = AccountRef::new(tx.accesses("A", Suprema::new(1, 0, 1)));
    let b = AccountRef::new(tx.updates("B", 1));
    let r = tx.run(|t| {
        a.withdraw(t, 100)?;
        b.deposit(t, 100)?;
        if a.balance(t)? < 0 {
            return t.abort();
        }
        Ok(())
    });
    println!("demo transfer: {r:?}");
    for name in ["A", "B"] {
        let oid = cluster.registry.locate(name).unwrap();
        let bal = sys.with_object(oid, |o| {
            o.as_any().downcast_ref::<Account>().unwrap().balance()
        });
        println!("{name} = {bal}");
    }
    sys.shutdown();
}
