//! Schema-versioned benchmark reports (`BENCH_*.json`) and the regression
//! gate that compares a fresh run against a committed baseline.
//!
//! The offline toolchain has no serde, so this module carries its own
//! minimal JSON document model ([`Json`]): a renderer producing stable,
//! human-diffable output (2-space indent, insertion-ordered keys) and a
//! recursive-descent parser for reading baselines back. The document shape
//! is fixed by [`SCHEMA_VERSION`]; `docs/BENCHMARKS.md` documents every
//! field.
//!
//! ```text
//! {
//!   "schema_version": 1,
//!   "bench": "micro",
//!   "git_sha": "<GITHUB_SHA | ARMI2_GIT_SHA | unknown>",
//!   "provisional": false,
//!   "config": { "nodes": "4", ... },
//!   "entries": [
//!     { "name": "...", "metrics": { "ns_per_op": 123.4, ... } }
//!   ]
//! }
//! ```

use std::fmt;
use std::path::{Path, PathBuf};

/// Version of the `BENCH_*.json` document shape. Bump on any breaking
/// change to the schema; [`BenchReport::parse`] rejects mismatched
/// baselines so the gate fails loudly instead of comparing stale fields.
pub const SCHEMA_VERSION: u64 = 1;

// ---------------------------------------------------------------------
// JSON document model
// ---------------------------------------------------------------------

/// A JSON value. Objects preserve insertion order (they are association
/// lists, not maps) so rendered reports diff cleanly across runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null` (also the rendering of non-finite numbers).
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, insertion-ordered.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Member of an object by key (`None` for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Render as pretty-printed JSON text (2-space indent, trailing
    /// newline-free). Deterministic: same document, same text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out, 0);
        out
    }

    fn render_into(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => render_string(s, out),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    item.render_into(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(members) => {
                if members.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in members.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    render_string(k, out);
                    out.push_str(": ");
                    v.render_into(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
        }
    }

    /// Parse JSON text into a document.
    pub fn parse(text: &str) -> Result<Json, ReportError> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(ReportError::Json { at: pos, msg: "trailing characters" });
        }
        Ok(value)
    }
}

fn push_indent(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn render_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, b: u8, msg: &'static str) -> Result<(), ReportError> {
    if *pos < bytes.len() && bytes[*pos] == b {
        *pos += 1;
        Ok(())
    } else {
        Err(ReportError::Json { at: *pos, msg })
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<Json, ReportError> {
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        Some(b'{') => parse_object(bytes, pos),
        Some(b'[') => parse_array(bytes, pos),
        Some(b'"') => Ok(Json::Str(parse_string(bytes, pos)?)),
        Some(b't') => parse_literal(bytes, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_literal(bytes, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_literal(bytes, pos, "null", Json::Null),
        Some(b'-' | b'0'..=b'9') => parse_number(bytes, pos),
        _ => Err(ReportError::Json { at: *pos, msg: "expected a JSON value" }),
    }
}

fn parse_literal(
    bytes: &[u8],
    pos: &mut usize,
    word: &'static str,
    value: Json,
) -> Result<Json, ReportError> {
    if bytes[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(value)
    } else {
        Err(ReportError::Json { at: *pos, msg: "unknown literal" })
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json, ReportError> {
    let start = *pos;
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    let token = std::str::from_utf8(&bytes[start..*pos])
        .map_err(|_| ReportError::Json { at: start, msg: "invalid number" })?;
    token
        .parse::<f64>()
        .map(Json::Num)
        .map_err(|_| ReportError::Json { at: start, msg: "invalid number" })
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, ReportError> {
    expect(bytes, pos, b'"', "expected string")?;
    let mut s = String::new();
    loop {
        match bytes.get(*pos) {
            None => return Err(ReportError::Json { at: *pos, msg: "unterminated string" }),
            Some(b'"') => {
                *pos += 1;
                return Ok(s);
            }
            Some(b'\\') => {
                *pos += 1;
                let esc = *bytes
                    .get(*pos)
                    .ok_or(ReportError::Json { at: *pos, msg: "unterminated escape" })?;
                *pos += 1;
                match esc {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{0008}'),
                    b'f' => s.push('\u{000C}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let unit = parse_hex4(bytes, pos)?;
                        let c = if (0xD800..0xDC00).contains(&unit) {
                            // High surrogate: a \uXXXX low surrogate must
                            // follow for a valid code point.
                            if bytes.get(*pos) == Some(&b'\\') && bytes.get(*pos + 1) == Some(&b'u')
                            {
                                *pos += 2;
                                let low = parse_hex4(bytes, pos)?;
                                let cp = 0x10000
                                    + ((unit - 0xD800) as u32) * 0x400
                                    + (low.wrapping_sub(0xDC00)) as u32;
                                char::from_u32(cp)
                            } else {
                                None
                            }
                        } else {
                            char::from_u32(unit as u32)
                        };
                        s.push(c.ok_or(ReportError::Json {
                            at: *pos,
                            msg: "invalid \\u escape",
                        })?);
                    }
                    _ => return Err(ReportError::Json { at: *pos, msg: "unknown escape" }),
                }
            }
            Some(_) => {
                // Consume one full UTF-8 scalar (input is a &str, so the
                // byte stream is valid UTF-8).
                let rest = std::str::from_utf8(&bytes[*pos..])
                    .map_err(|_| ReportError::Json { at: *pos, msg: "invalid utf-8" })?;
                let c = rest.chars().next().unwrap();
                s.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_hex4(bytes: &[u8], pos: &mut usize) -> Result<u16, ReportError> {
    if *pos + 4 > bytes.len() {
        return Err(ReportError::Json { at: *pos, msg: "truncated \\u escape" });
    }
    let token = std::str::from_utf8(&bytes[*pos..*pos + 4])
        .map_err(|_| ReportError::Json { at: *pos, msg: "invalid \\u escape" })?;
    let unit = u16::from_str_radix(token, 16)
        .map_err(|_| ReportError::Json { at: *pos, msg: "invalid \\u escape" })?;
    *pos += 4;
    Ok(unit)
}

fn parse_array(bytes: &[u8], pos: &mut usize) -> Result<Json, ReportError> {
    expect(bytes, pos, b'[', "expected array")?;
    let mut items = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(bytes, pos)?);
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(ReportError::Json { at: *pos, msg: "expected ',' or ']'" }),
        }
    }
}

fn parse_object(bytes: &[u8], pos: &mut usize) -> Result<Json, ReportError> {
    expect(bytes, pos, b'{', "expected object")?;
    let mut members = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(members));
    }
    loop {
        skip_ws(bytes, pos);
        let key = parse_string(bytes, pos)?;
        skip_ws(bytes, pos);
        expect(bytes, pos, b':', "expected ':'")?;
        let value = parse_value(bytes, pos)?;
        members.push((key, value));
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(members));
            }
            _ => return Err(ReportError::Json { at: *pos, msg: "expected ',' or '}'" }),
        }
    }
}

// ---------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------

/// Failure reading or validating a `BENCH_*.json` document.
#[derive(Debug, Clone, PartialEq)]
pub enum ReportError {
    /// The text is not valid JSON.
    Json {
        /// Byte offset of the failure.
        at: usize,
        /// What the parser expected.
        msg: &'static str,
    },
    /// Valid JSON, but not a valid report document.
    Malformed(String),
    /// The document's `schema_version` does not match [`SCHEMA_VERSION`].
    SchemaMismatch {
        /// The version found in the document.
        found: u64,
    },
}

impl fmt::Display for ReportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReportError::Json { at, msg } => write!(f, "json error at byte {at}: {msg}"),
            ReportError::Malformed(m) => write!(f, "malformed bench report: {m}"),
            ReportError::SchemaMismatch { found } => write!(
                f,
                "bench report schema version {found} != supported {SCHEMA_VERSION} \
                 (regenerate the baseline)"
            ),
        }
    }
}

impl std::error::Error for ReportError {}

// ---------------------------------------------------------------------
// Report model
// ---------------------------------------------------------------------

/// One benchmarked scenario: a name plus its numeric metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchEntry {
    /// Scenario identifier, unique within the report (e.g. a micro-bench
    /// label or `"optsva/90r"`).
    pub name: String,
    /// Metric key → value, insertion-ordered. Keys ending in `_ops_s` are
    /// throughputs (higher is better); `ns_per_op` is a latency (lower is
    /// better); everything else is informational.
    pub metrics: Vec<(String, f64)>,
}

impl BenchEntry {
    /// A new entry with no metrics.
    pub fn new(name: impl Into<String>) -> Self {
        BenchEntry { name: name.into(), metrics: Vec::new() }
    }

    /// Add a metric (chainable).
    pub fn metric(mut self, key: impl Into<String>, value: f64) -> Self {
        self.metrics.push((key.into(), value));
        self
    }

    /// Value of a metric by key.
    pub fn get(&self, key: &str) -> Option<f64> {
        self.metrics.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }
}

/// A full benchmark report, one per bench target per run.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchReport {
    /// Document shape version (see [`SCHEMA_VERSION`]).
    pub schema_version: u64,
    /// Bench target name (`micro`, `ablation`, `fig10`, …); also names the
    /// output file `BENCH_<bench>.json`.
    pub bench: String,
    /// Commit the run was produced from: `GITHUB_SHA`, else
    /// `ARMI2_GIT_SHA`, else `"unknown"`.
    pub git_sha: String,
    /// A provisional report carries the schema and entry names but numbers
    /// that no CI runner produced (e.g. a hand-seeded baseline). The gate
    /// never fails against a provisional baseline — it reports "skipped"
    /// until CI commits a measured one.
    pub provisional: bool,
    /// Run configuration fingerprint (free-form key → value strings):
    /// scale, node counts, network model — whatever makes two runs
    /// comparable or not.
    pub config: Vec<(String, String)>,
    /// The measured scenarios.
    pub entries: Vec<BenchEntry>,
}

impl BenchReport {
    /// A fresh report for bench target `bench`, stamped with the current
    /// commit (from the environment) and the current [`SCHEMA_VERSION`].
    pub fn new(bench: impl Into<String>) -> Self {
        let git_sha = std::env::var("GITHUB_SHA")
            .or_else(|_| std::env::var("ARMI2_GIT_SHA"))
            .unwrap_or_else(|_| "unknown".to_string());
        BenchReport {
            schema_version: SCHEMA_VERSION,
            bench: bench.into(),
            git_sha,
            provisional: false,
            config: Vec::new(),
            entries: Vec::new(),
        }
    }

    /// Record one configuration fingerprint key (chainable).
    pub fn config(mut self, key: impl Into<String>, value: impl ToString) -> Self {
        self.config.push((key.into(), value.to_string()));
        self
    }

    /// Append a measured scenario.
    pub fn push(&mut self, entry: BenchEntry) {
        self.entries.push(entry);
    }

    /// Entry by name.
    pub fn entry(&self, name: &str) -> Option<&BenchEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// The report as a JSON document.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("schema_version".into(), Json::Num(self.schema_version as f64)),
            ("bench".into(), Json::Str(self.bench.clone())),
            ("git_sha".into(), Json::Str(self.git_sha.clone())),
            ("provisional".into(), Json::Bool(self.provisional)),
            (
                "config".into(),
                Json::Obj(
                    self.config
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                        .collect(),
                ),
            ),
            (
                "entries".into(),
                Json::Arr(
                    self.entries
                        .iter()
                        .map(|e| {
                            Json::Obj(vec![
                                ("name".into(), Json::Str(e.name.clone())),
                                (
                                    "metrics".into(),
                                    Json::Obj(
                                        e.metrics
                                            .iter()
                                            .map(|(k, v)| (k.clone(), Json::Num(*v)))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Render the report as JSON text (with trailing newline, so committed
    /// baselines are POSIX text files).
    pub fn render(&self) -> String {
        let mut s = self.to_json().render();
        s.push('\n');
        s
    }

    /// Parse JSON text back into a report, rejecting schema mismatches.
    pub fn parse(text: &str) -> Result<Self, ReportError> {
        let doc = Json::parse(text)?;
        let version = doc
            .get("schema_version")
            .and_then(Json::as_f64)
            .ok_or_else(|| ReportError::Malformed("missing schema_version".into()))?
            as u64;
        if version != SCHEMA_VERSION {
            return Err(ReportError::SchemaMismatch { found: version });
        }
        let str_field = |key: &str| -> Result<String, ReportError> {
            doc.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| ReportError::Malformed(format!("missing string field {key:?}")))
        };
        let mut report = BenchReport {
            schema_version: version,
            bench: str_field("bench")?,
            git_sha: str_field("git_sha")?,
            provisional: doc
                .get("provisional")
                .and_then(Json::as_bool)
                .ok_or_else(|| ReportError::Malformed("missing provisional flag".into()))?,
            config: Vec::new(),
            entries: Vec::new(),
        };
        if let Some(Json::Obj(members)) = doc.get("config") {
            for (k, v) in members {
                let v = v
                    .as_str()
                    .ok_or_else(|| ReportError::Malformed(format!("config {k:?} not a string")))?;
                report.config.push((k.clone(), v.to_string()));
            }
        } else {
            return Err(ReportError::Malformed("missing config object".into()));
        }
        let entries = doc
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| ReportError::Malformed("missing entries array".into()))?;
        for e in entries {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| ReportError::Malformed("entry without name".into()))?;
            let mut entry = BenchEntry::new(name);
            match e.get("metrics") {
                Some(Json::Obj(members)) => {
                    for (k, v) in members {
                        let v = v.as_f64().ok_or_else(|| {
                            ReportError::Malformed(format!("metric {k:?} not a number"))
                        })?;
                        entry.metrics.push((k.clone(), v));
                    }
                }
                _ => return Err(ReportError::Malformed("entry without metrics".into())),
            }
            report.entries.push(entry);
        }
        Ok(report)
    }

    /// The canonical output path for this report under `dir`.
    pub fn path_in(&self, dir: &Path) -> PathBuf {
        dir.join(format!("BENCH_{}.json", self.bench))
    }

    /// Write the report to `dir/BENCH_<bench>.json`, creating `dir` as
    /// needed. Returns the written path.
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = self.path_in(dir);
        std::fs::write(&path, self.render())?;
        Ok(path)
    }
}

/// The default output directory for bench reports (`target/bench-results`),
/// shared with the CSV writers.
pub fn default_output_dir() -> PathBuf {
    PathBuf::from("target").join("bench-results")
}

// ---------------------------------------------------------------------
// Regression gate
// ---------------------------------------------------------------------

/// Outcome of gating a fresh report against a baseline.
#[derive(Debug, Clone, PartialEq)]
pub struct GateOutcome {
    /// The whole comparison was skipped (provisional baseline, or nothing
    /// comparable); carries the reason.
    pub skipped: Option<String>,
    /// Human-readable regression descriptions; empty means the gate passed.
    pub failures: Vec<String>,
    /// Number of (entry, metric) pairs actually compared.
    pub compared: usize,
}

impl GateOutcome {
    /// Did the gate pass (no regressions; skipped counts as passing)?
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Compare `new` against `baseline`: for every baseline entry and every
/// directional metric in it — keys ending in `_ops_s` (higher is better)
/// and `ns_per_op` (lower is better) — fail if the fresh value is worse by
/// more than `tolerance` (e.g. `0.20` = 20 %). Non-directional metrics are
/// ignored. A provisional baseline skips the comparison entirely.
pub fn gate(new: &BenchReport, baseline: &BenchReport, tolerance: f64) -> GateOutcome {
    if baseline.provisional {
        return GateOutcome {
            skipped: Some("baseline is provisional (no CI-measured numbers yet)".into()),
            failures: Vec::new(),
            compared: 0,
        };
    }
    let mut failures = Vec::new();
    let mut compared = 0;
    for base_entry in &baseline.entries {
        let Some(new_entry) = new.entry(&base_entry.name) else {
            failures.push(format!("entry {:?} missing from the fresh report", base_entry.name));
            continue;
        };
        for (key, base) in &base_entry.metrics {
            let higher_is_better = key.ends_with("_ops_s");
            let lower_is_better = key == "ns_per_op";
            if !higher_is_better && !lower_is_better {
                continue;
            }
            let Some(fresh) = new_entry.get(key) else {
                failures.push(format!(
                    "metric {key:?} of entry {:?} missing from the fresh report",
                    base_entry.name
                ));
                continue;
            };
            compared += 1;
            let regressed = if higher_is_better {
                fresh < base * (1.0 - tolerance)
            } else {
                fresh > base * (1.0 + tolerance)
            };
            if regressed {
                failures.push(format!(
                    "{}/{}: {:.3} vs baseline {:.3} (tolerance {:.0}%)",
                    base_entry.name,
                    key,
                    fresh,
                    base,
                    tolerance * 100.0
                ));
            }
        }
    }
    let skipped = if compared == 0 && failures.is_empty() {
        Some("no comparable directional metrics".into())
    } else {
        None
    };
    GateOutcome { skipped, failures, compared }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BenchReport {
        let mut r = BenchReport::new("micro")
            .config("scale", "full")
            .config("network", "instant");
        r.push(
            BenchEntry::new("versioning handoff")
                .metric("ns_per_op", 812.0)
                .metric("p95_ns", 1190.0),
        );
        r.push(
            BenchEntry::new("optsva/90r")
                .metric("throughput_ops_s", 15234.5)
                .metric("aborts", 0.0),
        );
        r
    }

    #[test]
    fn report_round_trips_through_json_text() {
        let r = sample();
        let text = r.render();
        let back = BenchReport::parse(&text).unwrap();
        assert_eq!(back, r);
        // Render → parse → render is a fixed point.
        assert_eq!(back.render(), text);
    }

    #[test]
    fn schema_version_bump_is_detected() {
        let mut r = sample();
        r.schema_version = SCHEMA_VERSION + 1;
        let err = BenchReport::parse(&r.render()).unwrap_err();
        assert_eq!(err, ReportError::SchemaMismatch { found: SCHEMA_VERSION + 1 });
    }

    #[test]
    fn malformed_documents_are_rejected() {
        assert!(matches!(BenchReport::parse("{not json"), Err(ReportError::Json { .. })));
        assert!(matches!(
            BenchReport::parse("{\"schema_version\": 1}"),
            Err(ReportError::Malformed(_))
        ));
    }

    #[test]
    fn string_escapes_round_trip() {
        let cases = ["plain", "with \"quotes\"", "tab\there", "line\nbreak", "uni: µs → ok"];
        for case in cases {
            let doc = Json::Obj(vec![("k".into(), Json::Str(case.into()))]);
            let back = Json::parse(&doc.render()).unwrap();
            assert_eq!(back.get("k").and_then(Json::as_str), Some(case));
        }
        // Parse-side escapes the renderer never emits.
        let doc = Json::parse(r#"{"k": "a\/bA😀"}"#).unwrap();
        assert_eq!(doc.get("k").and_then(Json::as_str), Some("a/bA😀"));
    }

    #[test]
    fn gate_passes_within_tolerance() {
        let base = sample();
        let mut fresh = sample();
        // 10 % worse on both directional metrics: inside a 20 % tolerance.
        fresh.entries[0].metrics[0].1 = 812.0 * 1.10;
        fresh.entries[1].metrics[0].1 = 15234.5 * 0.90;
        let outcome = gate(&fresh, &base, 0.20);
        assert!(outcome.passed(), "{:?}", outcome.failures);
        assert_eq!(outcome.compared, 2);
        assert_eq!(outcome.skipped, None);
    }

    #[test]
    fn gate_fails_on_regression_beyond_tolerance() {
        let base = sample();
        let mut fresh = sample();
        fresh.entries[1].metrics[0].1 = 15234.5 * 0.5; // halved throughput
        let outcome = gate(&fresh, &base, 0.20);
        assert!(!outcome.passed());
        assert_eq!(outcome.failures.len(), 1);
        assert!(outcome.failures[0].contains("optsva/90r"), "{:?}", outcome.failures);
        // Improvements never fail, whatever the direction convention.
        let mut better = sample();
        better.entries[0].metrics[0].1 = 10.0; // far lower ns_per_op
        better.entries[1].metrics[0].1 = 1e9; // far higher throughput
        assert!(gate(&better, &base, 0.20).passed());
    }

    #[test]
    fn gate_skips_provisional_baselines_and_missing_entries_fail() {
        let mut base = sample();
        base.provisional = true;
        let mut fresh = sample();
        fresh.entries[1].metrics[0].1 = 1.0; // would be a huge regression
        let outcome = gate(&fresh, &base, 0.20);
        assert!(outcome.passed());
        assert!(outcome.skipped.is_some());

        let base = sample();
        let mut renamed = sample();
        renamed.entries[0].name = "something else".into();
        let outcome = gate(&renamed, &base, 0.20);
        assert!(!outcome.passed());
        assert!(outcome.failures[0].contains("missing"), "{:?}", outcome.failures);
    }

    #[test]
    fn numbers_render_compactly_and_round_trip() {
        let doc = Json::Arr(vec![
            Json::Num(1.0),
            Json::Num(0.5),
            Json::Num(-3.25),
            Json::Num(15234.5),
            Json::Num(f64::NAN), // rendered as null
        ]);
        let text = doc.render();
        assert!(text.contains('1') && text.contains("0.5") && text.contains("null"));
        let back = Json::parse(&text).unwrap();
        let items = back.as_arr().unwrap();
        assert_eq!(items[0], Json::Num(1.0));
        assert_eq!(items[3], Json::Num(15234.5));
        assert_eq!(items[4], Json::Null);
    }
}
