//! Benchmark reporting infrastructure.
//!
//! Every bench target (`micro`, `ablation`, `fig10`–`fig13`) emits, next to
//! its human-readable table, a machine-readable `BENCH_<name>.json` via
//! [`report::BenchReport`]. CI uploads these as artifacts on every PR and
//! gates merges on the committed baselines at the repository root (see
//! `docs/BENCHMARKS.md` for the schema and workflow).

pub mod report;

pub use report::{
    default_output_dir, gate, BenchEntry, BenchReport, GateOutcome, Json, SCHEMA_VERSION,
};
