//! Transaction-local buffers for complex objects (paper §2.6).
//!
//! * **Copy buffer** — a full snapshot of the object's state. Requires the
//!   access condition before creation (it observes the state), but can then
//!   serve local reads after the object is released. Also used as the
//!   abort checkpoint `st_i(x)`.
//! * **Log buffer** — records write-mode method invocations *without*
//!   observing the object. Pure writes can therefore execute before any
//!   synchronization. Applying the log replays the recorded calls against
//!   the live object.
//!
//! Both buffers live on the same node as the object (CF requirement: side
//! effects must happen at the object's home, §2.6) — structurally enforced
//! here by the buffers being owned by the server-side proxy.
//!
//! This module also hosts [`ArgList`], the small-buffer argument container
//! of [`OpCall`]: nearly every message and log entry in the system carries
//! zero, one or two argument [`Value`]s, and the buffers (log entries in
//! particular) store calls by the thousands — so the arguments live inline
//! in the call instead of behind a heap `Vec` allocation.

use crate::object::{ObjectError, OpCall, SharedObject, Value};
use std::ops::Index;

/// Argument list of an [`OpCall`], stored inline for arity ≤ 2.
///
/// Every method in the repository's object zoo takes zero, one or two
/// arguments, and calls are cloned into log buffers and shipped in
/// (simulated) messages on the per-operation hot path. The inline
/// representation makes an `OpCall` clone allocation-free for those
/// arities; longer argument lists spill to a `Vec`.
///
/// Construct via [`ArgList::new`]/[`ArgList::one`]/[`ArgList::pair`], from
/// a `Vec<Value>`, or by collecting an iterator of [`Value`]s; consume as a
/// slice ([`ArgList::as_slice`], [`ArgList::iter`], indexing).
#[derive(Clone)]
pub enum ArgList {
    /// Up to two arguments inline; the first field is the arity, unused
    /// slots hold `Value::Unit`.
    Inline(u8, [Value; 2]),
    /// Three or more arguments, spilled to the heap.
    Heap(Vec<Value>),
}

impl ArgList {
    /// Largest arity stored without a heap allocation.
    pub const INLINE_CAP: usize = 2;

    /// The empty argument list (nullary calls).
    pub fn new() -> Self {
        ArgList::Inline(0, [Value::Unit, Value::Unit])
    }

    /// A single-argument list (unary calls).
    pub fn one(v: Value) -> Self {
        ArgList::Inline(1, [v, Value::Unit])
    }

    /// A two-argument list (binary calls).
    pub fn pair(a: Value, b: Value) -> Self {
        ArgList::Inline(2, [a, b])
    }

    /// The arguments as a slice, whatever the representation.
    pub fn as_slice(&self) -> &[Value] {
        match self {
            ArgList::Inline(n, vals) => &vals[..*n as usize],
            ArgList::Heap(v) => v,
        }
    }

    /// Number of arguments.
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    /// Is the list nullary?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `i`-th argument, if present.
    pub fn get(&self, i: usize) -> Option<&Value> {
        self.as_slice().get(i)
    }

    /// The first argument, if present.
    pub fn first(&self) -> Option<&Value> {
        self.get(0)
    }

    /// Iterate over the arguments.
    pub fn iter(&self) -> std::slice::Iter<'_, Value> {
        self.as_slice().iter()
    }
}

impl Default for ArgList {
    fn default() -> Self {
        Self::new()
    }
}

impl From<Vec<Value>> for ArgList {
    fn from(mut v: Vec<Value>) -> Self {
        match v.len() {
            0 => ArgList::new(),
            1 => ArgList::one(v.pop().expect("len checked")),
            2 => {
                let b = v.pop().expect("len checked");
                let a = v.pop().expect("len checked");
                ArgList::pair(a, b)
            }
            _ => ArgList::Heap(v),
        }
    }
}

impl FromIterator<Value> for ArgList {
    fn from_iter<I: IntoIterator<Item = Value>>(iter: I) -> Self {
        iter.into_iter().collect::<Vec<_>>().into()
    }
}

impl Index<usize> for ArgList {
    type Output = Value;
    fn index(&self, i: usize) -> &Value {
        &self.as_slice()[i]
    }
}

impl<'a> IntoIterator for &'a ArgList {
    type Item = &'a Value;
    type IntoIter = std::slice::Iter<'a, Value>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter()
    }
}

impl PartialEq for ArgList {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Vec<Value>> for ArgList {
    fn eq(&self, other: &Vec<Value>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl std::fmt::Debug for ArgList {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list().entries(self.as_slice()).finish()
    }
}

/// A snapshot of an object's state, usable for local reads and restores.
pub struct CopyBuffer {
    copy: Box<dyn SharedObject>,
}

impl CopyBuffer {
    /// Snapshot `obj`. Caller must have satisfied the access condition.
    pub fn capture(obj: &dyn SharedObject) -> Self {
        CopyBuffer { copy: obj.snapshot() }
    }

    /// Execute a (read) operation against the buffered state.
    pub fn invoke(&mut self, call: &OpCall) -> Result<Value, ObjectError> {
        self.copy.invoke(call)
    }

    /// Restore the live object from this buffer (abort path).
    pub fn restore_into(&self, obj: &mut dyn SharedObject) {
        obj.restore(self.copy.as_ref());
    }

    /// Bytes this buffer occupies (cost accounting).
    pub fn state_size(&self) -> usize {
        self.copy.state_size()
    }
}

/// A log of write-mode invocations awaiting application.
#[derive(Default)]
pub struct LogBuffer {
    entries: Vec<OpCall>,
}

impl LogBuffer {
    /// An empty log.
    pub fn new() -> Self {
        LogBuffer { entries: Vec::new() }
    }

    /// Record a write. Pure writes return no state-derived value, so the
    /// caller gets `Unit` immediately.
    pub fn record(&mut self, call: OpCall) -> Value {
        self.entries.push(call);
        Value::Unit
    }

    /// Number of recorded, unapplied writes.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the log empty?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Replay all recorded writes against the live object, draining the
    /// log. Any error aborts the replay and is surfaced to the caller.
    pub fn apply(&mut self, obj: &mut dyn SharedObject) -> Result<(), ObjectError> {
        for call in self.entries.drain(..) {
            obj.invoke(&call)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::object::{account::ops, Account, KvStore, QueueObject};

    #[test]
    fn arglist_stays_inline_up_to_two_args_and_spills_after() {
        let empty = ArgList::new();
        let one = ArgList::one(Value::Int(1));
        let two = ArgList::from(vec![Value::Int(1), Value::Int(2)]);
        let three = ArgList::from(vec![Value::Int(1), Value::Int(2), Value::Int(3)]);
        assert!(matches!(empty, ArgList::Inline(0, _)));
        assert!(matches!(one, ArgList::Inline(1, _)));
        assert!(matches!(two, ArgList::Inline(2, _)));
        assert!(matches!(three, ArgList::Heap(_)));
        assert_eq!(empty.len(), 0);
        assert!(empty.is_empty());
        assert_eq!(three.len(), 3);
    }

    #[test]
    fn arglist_slice_views_agree_across_representations() {
        for n in 0..5usize {
            let vals: Vec<Value> = (0..n as i64).map(Value::Int).collect();
            let args = ArgList::from(vals.clone());
            assert_eq!(args, vals, "arity {n}");
            assert_eq!(args.as_slice(), &vals[..]);
            assert_eq!(args.first(), vals.first());
            assert_eq!(args.get(1), vals.get(1));
            assert_eq!(args.iter().count(), n);
            let collected: ArgList = vals.clone().into_iter().collect();
            assert_eq!(collected, args);
            if n > 0 {
                assert_eq!(args[n - 1], vals[n - 1]);
            }
        }
    }

    #[test]
    fn copy_buffer_reads_do_not_touch_live_object() {
        let mut live = Account::with_balance(100);
        let mut buf = CopyBuffer::capture(&live);
        live.invoke(&ops::deposit(900)).unwrap();
        // buffer still sees the snapshot
        assert_eq!(buf.invoke(&ops::balance()).unwrap().as_int(), 100);
        assert_eq!(live.balance(), 1000);
    }

    #[test]
    fn copy_buffer_restores_checkpoint() {
        let mut live = Account::with_balance(50);
        let st = CopyBuffer::capture(&live);
        live.invoke(&ops::withdraw(40)).unwrap();
        st.restore_into(&mut live);
        assert_eq!(live.balance(), 50);
    }

    #[test]
    fn log_buffer_defers_writes_then_applies_in_order() {
        let mut q = QueueObject::new();
        let mut log = LogBuffer::new();
        log.record(OpCall::unary("push", 1i64));
        log.record(OpCall::unary("push", 2i64));
        assert!(q.is_empty(), "log writes must not touch the object");
        log.apply(&mut q).unwrap();
        assert_eq!(q.len(), 2);
        assert!(log.is_empty(), "apply drains the log");
        assert_eq!(q.invoke(&OpCall::nullary("pop")).unwrap().as_int(), 1);
    }

    #[test]
    fn log_apply_preserves_overwrite_semantics() {
        // Last write wins after replay, like direct execution.
        let mut kv = KvStore::from_pairs(&[("k", 0)]);
        let mut log = LogBuffer::new();
        log.record(OpCall::new("put", vec![Value::from("k"), Value::from(1i64)]));
        log.record(OpCall::new("put", vec![Value::from("k"), Value::from(2i64)]));
        log.apply(&mut kv).unwrap();
        assert_eq!(kv.invoke(&OpCall::unary("get", "k")).unwrap().as_int(), 2);
    }

    #[test]
    fn log_apply_surfaces_errors() {
        let mut q = QueueObject::new();
        let mut log = LogBuffer::new();
        log.record(OpCall::nullary("push")); // missing arg
        assert!(log.apply(&mut q).is_err());
    }
}
