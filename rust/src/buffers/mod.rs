//! Transaction-local buffers for complex objects (paper §2.6).
//!
//! * **Copy buffer** — a full snapshot of the object's state. Requires the
//!   access condition before creation (it observes the state), but can then
//!   serve local reads after the object is released. Also used as the
//!   abort checkpoint `st_i(x)`.
//! * **Log buffer** — records write-mode method invocations *without*
//!   observing the object. Pure writes can therefore execute before any
//!   synchronization. Applying the log replays the recorded calls against
//!   the live object.
//!
//! Both buffers live on the same node as the object (CF requirement: side
//! effects must happen at the object's home, §2.6) — structurally enforced
//! here by the buffers being owned by the server-side proxy.

use crate::object::{ObjectError, OpCall, SharedObject, Value};

/// A snapshot of an object's state, usable for local reads and restores.
pub struct CopyBuffer {
    copy: Box<dyn SharedObject>,
}

impl CopyBuffer {
    /// Snapshot `obj`. Caller must have satisfied the access condition.
    pub fn capture(obj: &dyn SharedObject) -> Self {
        CopyBuffer { copy: obj.snapshot() }
    }

    /// Execute a (read) operation against the buffered state.
    pub fn invoke(&mut self, call: &OpCall) -> Result<Value, ObjectError> {
        self.copy.invoke(call)
    }

    /// Restore the live object from this buffer (abort path).
    pub fn restore_into(&self, obj: &mut dyn SharedObject) {
        obj.restore(self.copy.as_ref());
    }

    /// Bytes this buffer occupies (cost accounting).
    pub fn state_size(&self) -> usize {
        self.copy.state_size()
    }
}

/// A log of write-mode invocations awaiting application.
#[derive(Default)]
pub struct LogBuffer {
    entries: Vec<OpCall>,
}

impl LogBuffer {
    pub fn new() -> Self {
        LogBuffer { entries: Vec::new() }
    }

    /// Record a write. Pure writes return no state-derived value, so the
    /// caller gets `Unit` immediately.
    pub fn record(&mut self, call: OpCall) -> Value {
        self.entries.push(call);
        Value::Unit
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Replay all recorded writes against the live object, draining the
    /// log. Any error aborts the replay and is surfaced to the caller.
    pub fn apply(&mut self, obj: &mut dyn SharedObject) -> Result<(), ObjectError> {
        for call in self.entries.drain(..) {
            obj.invoke(&call)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::object::{account::ops, Account, KvStore, QueueObject};

    #[test]
    fn copy_buffer_reads_do_not_touch_live_object() {
        let mut live = Account::with_balance(100);
        let mut buf = CopyBuffer::capture(&live);
        live.invoke(&ops::deposit(900)).unwrap();
        // buffer still sees the snapshot
        assert_eq!(buf.invoke(&ops::balance()).unwrap().as_int(), 100);
        assert_eq!(live.balance(), 1000);
    }

    #[test]
    fn copy_buffer_restores_checkpoint() {
        let mut live = Account::with_balance(50);
        let st = CopyBuffer::capture(&live);
        live.invoke(&ops::withdraw(40)).unwrap();
        st.restore_into(&mut live);
        assert_eq!(live.balance(), 50);
    }

    #[test]
    fn log_buffer_defers_writes_then_applies_in_order() {
        let mut q = QueueObject::new();
        let mut log = LogBuffer::new();
        log.record(OpCall::unary("push", 1i64));
        log.record(OpCall::unary("push", 2i64));
        assert!(q.is_empty(), "log writes must not touch the object");
        log.apply(&mut q).unwrap();
        assert_eq!(q.len(), 2);
        assert!(log.is_empty(), "apply drains the log");
        assert_eq!(q.invoke(&OpCall::nullary("pop")).unwrap().as_int(), 1);
    }

    #[test]
    fn log_apply_preserves_overwrite_semantics() {
        // Last write wins after replay, like direct execution.
        let mut kv = KvStore::from_pairs(&[("k", 0)]);
        let mut log = LogBuffer::new();
        log.record(OpCall::new("put", vec![Value::from("k"), Value::from(1i64)]));
        log.record(OpCall::new("put", vec![Value::from("k"), Value::from(2i64)]));
        log.apply(&mut kv).unwrap();
        assert_eq!(kv.invoke(&OpCall::unary("get", "k")).unwrap().as_int(), 2);
    }

    #[test]
    fn log_apply_surfaces_errors() {
        let mut q = QueueObject::new();
        let mut log = LogBuffer::new();
        log.record(OpCall::nullary("push")); // missing arg
        assert!(log.apply(&mut q).is_err());
    }
}
