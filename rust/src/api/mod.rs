//! Public transactional API, common to every framework in the repo.
//!
//! Mirrors the paper's `Transaction` interface (Fig 8): a preamble declares
//! the access set with optional *suprema* (upper bounds on read / write /
//! update counts per object), then `run` executes the transaction body.
//! The same API drives OptSVA-CF (Atomic RMI 2), SVA (Atomic RMI), TFA
//! (HyFlow2 stand-in), and the lock-based baselines, so Eigenbench and the
//! examples are framework-agnostic.

use crate::cluster::{NodeId, Oid};
use crate::object::{ObjectError, OpCall, Value};
use crate::versioning::WaitTimeout;
use std::fmt;

/// Upper bounds on the number of operations a transaction will perform on
/// one object, by mode. `u64::MAX` means "unknown" (paper: "If suprema are
/// not given, infinity is assumed (and the system maintains guarantees)").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Suprema {
    pub reads: u64,
    pub writes: u64,
    pub updates: u64,
}

impl Suprema {
    /// No a-priori knowledge: all bounds infinite.
    pub fn unknown() -> Self {
        Suprema { reads: u64::MAX, writes: u64::MAX, updates: u64::MAX }
    }

    pub fn new(reads: u64, writes: u64, updates: u64) -> Self {
        Suprema { reads, writes, updates }
    }

    /// `t.reads(obj, n)` — read-only access (paper Fig 8).
    pub fn reads(n: u64) -> Self {
        Suprema { reads: n, writes: 0, updates: 0 }
    }

    /// `t.writes(obj, n)` — write-only access.
    pub fn writes(n: u64) -> Self {
        Suprema { reads: 0, writes: n, updates: 0 }
    }

    /// `t.updates(obj, n)` — update access.
    pub fn updates(n: u64) -> Self {
        Suprema { reads: 0, writes: 0, updates: n }
    }

    /// Is the object read-only for this transaction (§2.7)?
    pub fn read_only(&self) -> bool {
        self.writes == 0 && self.updates == 0
    }

    /// Will the transaction never read this object's state directly
    /// (pure-write access)?
    pub fn write_only(&self) -> bool {
        self.reads == 0 && self.updates == 0
    }

    /// Total operation bound, saturating (SVA's single supremum).
    pub fn total(&self) -> u64 {
        self.reads
            .saturating_add(self.writes)
            .saturating_add(self.updates)
    }
}

/// Why a transaction terminated abnormally.
#[derive(Debug, Clone, PartialEq)]
pub enum TxError {
    /// The programmer called `abort()` (paper Fig 9).
    ManualAbort,
    /// The programmer called `retry()`: abort and re-execute the body.
    Retry,
    /// Cascading abort: the transaction observed state released early by a
    /// transaction that later aborted (§2.3).
    ForcedAbort(String),
    /// An object was accessed more times than its declared supremum (§2.2).
    SupremaExceeded { oid: Oid, mode: &'static str, count: u64, bound: u64 },
    /// Optimistic conflict (TFA only): retry the transaction.
    Conflict(String),
    /// The object suffered a crash-stop failure (§3.4).
    ObjectCrashed(Oid),
    /// A versioning wait exceeded the failure-suspicion deadline (§3.4).
    Timeout(WaitTimeout),
    /// The body touched an object that was not declared in the preamble.
    NotDeclared(String),
    /// The object's method raised an application error.
    Object(ObjectError),
    /// The transaction was used after completion.
    Completed,
}

impl fmt::Display for TxError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TxError::ManualAbort => write!(f, "transaction aborted manually"),
            TxError::Retry => write!(f, "transaction requested retry"),
            TxError::ForcedAbort(why) => write!(f, "transaction forcibly aborted: {why}"),
            TxError::SupremaExceeded { oid, mode, count, bound } => write!(
                f,
                "supremum exceeded on {oid}: {mode} count {count} > bound {bound}"
            ),
            TxError::Conflict(why) => write!(f, "optimistic conflict: {why}"),
            TxError::ObjectCrashed(oid) => write!(f, "remote object {oid} crashed"),
            TxError::Timeout(t) => write!(f, "wait timed out: {t}"),
            TxError::NotDeclared(name) => {
                write!(f, "object {name:?} not declared in transaction preamble")
            }
            TxError::Object(e) => write!(f, "object error: {e}"),
            TxError::Completed => write!(f, "transaction already completed"),
        }
    }
}

impl std::error::Error for TxError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TxError::Timeout(t) => Some(t),
            TxError::Object(e) => Some(e),
            _ => None,
        }
    }
}

impl From<WaitTimeout> for TxError {
    fn from(t: WaitTimeout) -> Self {
        TxError::Timeout(t)
    }
}

impl From<ObjectError> for TxError {
    fn from(e: ObjectError) -> Self {
        TxError::Object(e)
    }
}

impl TxError {
    /// Should the driver re-execute the transaction body?
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            TxError::Retry | TxError::Conflict(_) | TxError::ForcedAbort(_)
        )
    }
}

/// Handle to a declared object within a running transaction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ObjHandle(pub usize);

/// A transaction body's view: invoke operations, abort, or retry.
/// Implemented by every framework.
pub trait TxCtx {
    /// Invoke `call` on the declared object `h`. The mode is derived from
    /// the object's interface annotations.
    fn call(&mut self, h: ObjHandle, call: OpCall) -> Result<Value, TxError>;

    /// Manual rollback (paper Fig 9): returns `Err(ManualAbort)` so the
    /// body can `return t.abort()` / `?`-propagate out; the framework
    /// performs the actual rollback when the body returns.
    fn abort(&mut self) -> Result<(), TxError> {
        Err(TxError::ManualAbort)
    }

    /// Abort and re-execute the body from scratch.
    fn retry(&mut self) -> Result<(), TxError> {
        Err(TxError::Retry)
    }

    /// Client node executing this transaction.
    fn client(&self) -> NodeId;
}

/// Outcome statistics for one committed transaction.
#[derive(Debug, Clone, Copy, Default)]
pub struct TxStats {
    /// Operations executed on shared objects.
    pub ops: u64,
    /// Times the body was (re-)executed before commit (1 = no retries).
    pub attempts: u64,
}

/// A framework: creates and runs transactions over a shared cluster.
/// `AccessDecl` names an object and its suprema.
#[derive(Debug, Clone)]
pub struct AccessDecl {
    pub name: String,
    pub suprema: Suprema,
}

impl AccessDecl {
    pub fn new(name: impl Into<String>, suprema: Suprema) -> Self {
        AccessDecl { name: name.into(), suprema }
    }
}

/// Framework-polymorphic transaction runner: executes `body` with
/// at-most-`max_attempts` retries (manual `retry()`, optimistic conflicts,
/// forced aborts). Returns the body's value and stats.
pub trait Dtm: Send + Sync {
    fn framework_name(&self) -> &'static str;

    /// Run a transaction from `client` over the declared access set.
    /// The implementation handles start/commit/abort and retries.
    fn run(
        &self,
        client: NodeId,
        decls: &[AccessDecl],
        irrevocable: bool,
        body: &mut dyn FnMut(&mut dyn TxCtx) -> Result<(), TxError>,
    ) -> Result<TxStats, TxError>;

    /// Total transactions forcibly or optimistically aborted so far
    /// (for the Fig 13 abort-rate table).
    fn aborts(&self) -> u64;

    /// Total commits so far.
    fn commits(&self) -> u64;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suprema_classification() {
        assert!(Suprema::reads(3).read_only());
        assert!(!Suprema::reads(3).write_only());
        assert!(Suprema::writes(2).write_only());
        assert!(!Suprema::new(1, 0, 1).read_only());
        assert!(Suprema::unknown().total() == u64::MAX);
        assert_eq!(Suprema::new(1, 2, 3).total(), 6);
    }

    #[test]
    fn retryable_classification() {
        assert!(TxError::Retry.is_retryable());
        assert!(TxError::Conflict("v".into()).is_retryable());
        assert!(TxError::ForcedAbort("cascade".into()).is_retryable());
        assert!(!TxError::ManualAbort.is_retryable());
        assert!(!TxError::Completed.is_retryable());
    }
}
