//! Public transactional API, common to every framework in the repo.
//!
//! Mirrors the paper's `Transaction` interface (Fig 8) with one addition:
//! remote operations are **asynchronous by default**. A *preamble* —
//! expressed through [`TxBuilder`] — declares the access set with optional
//! *suprema* (upper bounds on read / write / update counts per object,
//! §2.2) and per-transaction knobs (irrevocability §2.4, failure-suspicion
//! timeout §3.4, the asynchrony ablation switch), then [`TxBuilder::run`]
//! executes the transaction body with the framework's retry policy and
//! returns the body's value together with [`TxStats`].
//!
//! Inside the body, [`TxCtx::submit`] dispatches an operation to the
//! object's home node and returns an [`OpFuture`] immediately — buffered
//! writes resolve without any synchronization (§2.6) and reads resolve as
//! soon as the copy buffer or the access condition is ready (§2.7, §2.8) —
//! while [`TxCtx::call`] remains the blocking `submit(..).wait()`
//! convenience. The same API drives OptSVA-CF (Atomic RMI 2), SVA
//! (Atomic RMI), TFA (the HyFlow2 stand-in) and the lock-based baselines,
//! so Eigenbench and the examples stay framework-agnostic.
//!
//! # Migration from the pre-futures API
//!
//! | pre-redesign                                           | now |
//! |--------------------------------------------------------|-----|
//! | `dtm.run(client, &[AccessDecl], irrevocable, body)`    | `dtm.tx(client).with_decls(&decls).irrevocable_if(b).run(body)` |
//! | `tx.reads("x", 2)` only on the concrete OptSVA builder | `dtm.tx(client).reads("x", 2).writes("y", 1)` on any framework |
//! | body smuggles results through captured `&mut` outvars  | body returns `Result<R, TxError>`; `run` yields `(R, TxStats)` |
//! | `TxCtx::call` (always blocks for the round trip)       | `TxCtx::submit -> OpFuture` + [`OpFuture::wait`]; `call` still works |
//! | timeout/asynchrony fixed system-wide in `OptsvaConfig` | per-transaction `.timeout(..)` / `.no_timeout()` / `.asynchronous(..)` |
//! | hand-rolled `OpCall` / `Value` casts in user code      | typed facades ([`crate::object::refs`]: `AccountRef`, `KvRef`, …) |
//!
//! Paper map: preamble/suprema — Fig 8 & §2.2; `submit` for writes — §2.6
//! (buffering, no synchronization); read-only asynchrony — §2.7;
//! irrevocability — §2.4; the retry driver's cascading-abort handling —
//! §2.3.

use crate::cluster::{NameId, NodeId, Oid, Registry};
use crate::object::{ObjectError, OpCall, Value};
use crate::versioning::WaitTimeout;
use std::fmt;
use std::time::Duration;

/// Upper bounds on the number of operations a transaction will perform on
/// one object, by mode. `u64::MAX` means "unknown" (paper: "If suprema are
/// not given, infinity is assumed (and the system maintains guarantees)").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Suprema {
    /// Maximum read operations (methods that observe but never modify).
    pub reads: u64,
    /// Maximum write operations (methods that modify but never observe).
    pub writes: u64,
    /// Maximum update operations (methods that both observe and modify).
    pub updates: u64,
}

impl Suprema {
    /// No a-priori knowledge: all bounds infinite.
    pub fn unknown() -> Self {
        Suprema { reads: u64::MAX, writes: u64::MAX, updates: u64::MAX }
    }

    /// Explicit per-mode bounds, e.g. `Suprema::new(2, 0, 1)` for a
    /// transaction that reads twice and updates once.
    pub fn new(reads: u64, writes: u64, updates: u64) -> Self {
        Suprema { reads, writes, updates }
    }

    /// `t.reads(obj, n)` — read-only access (paper Fig 8).
    pub fn reads(n: u64) -> Self {
        Suprema { reads: n, writes: 0, updates: 0 }
    }

    /// `t.writes(obj, n)` — write-only access.
    pub fn writes(n: u64) -> Self {
        Suprema { reads: 0, writes: n, updates: 0 }
    }

    /// `t.updates(obj, n)` — update access.
    pub fn updates(n: u64) -> Self {
        Suprema { reads: 0, writes: 0, updates: n }
    }

    /// Is the object read-only for this transaction (§2.7)?
    pub fn read_only(&self) -> bool {
        self.writes == 0 && self.updates == 0
    }

    /// Will the transaction never read this object's state directly
    /// (pure-write access)?
    pub fn write_only(&self) -> bool {
        self.reads == 0 && self.updates == 0
    }

    /// Total operation bound, saturating (SVA's single supremum).
    pub fn total(&self) -> u64 {
        self.reads
            .saturating_add(self.writes)
            .saturating_add(self.updates)
    }
}

/// Why a transaction terminated abnormally.
#[derive(Debug, Clone, PartialEq)]
pub enum TxError {
    /// The programmer called `abort()` (paper Fig 9).
    ManualAbort,
    /// The programmer called `retry()`: abort and re-execute the body.
    Retry,
    /// Cascading abort: the transaction observed state released early by a
    /// transaction that later aborted (§2.3).
    ForcedAbort(String),
    /// An object was accessed more times than its declared supremum (§2.2).
    SupremaExceeded { oid: Oid, mode: &'static str, count: u64, bound: u64 },
    /// Optimistic conflict (TFA only): retry the transaction.
    Conflict(String),
    /// The object suffered a crash-stop failure (§3.4).
    ObjectCrashed(Oid),
    /// A versioning wait exceeded the failure-suspicion deadline (§3.4).
    Timeout(WaitTimeout),
    /// The body touched an object that was not declared in the preamble.
    NotDeclared(String),
    /// The object's method raised an application error.
    Object(ObjectError),
    /// The transaction was used after completion.
    Completed,
}

impl fmt::Display for TxError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TxError::ManualAbort => write!(f, "transaction aborted manually"),
            TxError::Retry => write!(f, "transaction requested retry"),
            TxError::ForcedAbort(why) => write!(f, "transaction forcibly aborted: {why}"),
            TxError::SupremaExceeded { oid, mode, count, bound } => write!(
                f,
                "supremum exceeded on {oid}: {mode} count {count} > bound {bound}"
            ),
            TxError::Conflict(why) => write!(f, "optimistic conflict: {why}"),
            TxError::ObjectCrashed(oid) => write!(f, "remote object {oid} crashed"),
            TxError::Timeout(t) => write!(f, "wait timed out: {t}"),
            TxError::NotDeclared(name) => {
                write!(f, "object {name:?} not declared in transaction preamble")
            }
            TxError::Object(e) => write!(f, "object error: {e}"),
            TxError::Completed => write!(f, "transaction already completed"),
        }
    }
}

impl std::error::Error for TxError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TxError::Timeout(t) => Some(t),
            TxError::Object(e) => Some(e),
            _ => None,
        }
    }
}

impl From<WaitTimeout> for TxError {
    fn from(t: WaitTimeout) -> Self {
        TxError::Timeout(t)
    }
}

impl From<ObjectError> for TxError {
    fn from(e: ObjectError) -> Self {
        TxError::Object(e)
    }
}

impl TxError {
    /// Could the driver re-execute the transaction body? Note that
    /// cascading aborts ([`TxError::ForcedAbort`]) are retryable only up
    /// to [`FORCED_ABORT_RETRY_CAP`] — the shared driver enforces the cap.
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            TxError::Retry | TxError::Conflict(_) | TxError::ForcedAbort(_)
        )
    }
}

/// Handle to a declared object within a running transaction. Handles are
/// assigned in declaration order, starting at 0.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ObjHandle(pub usize);

// ---------------------------------------------------------------------------
// Operation futures
// ---------------------------------------------------------------------------

/// Framework hook behind a pending [`OpFuture`]: a poll/wait handle for an
/// operation dispatched to its object's home node.
pub trait PendingOp: Send {
    /// Has the operation executed (wait would not block)?
    fn is_ready(&self) -> bool;
    /// Block until the result is available, paying any remaining simulated
    /// response latency, and return it.
    fn wait(self: Box<Self>) -> Result<Value, TxError>;
}

/// Handle to one submitted operation (paper §2.6/§2.8: buffered writes
/// return without synchronization; reads resolve when the buffer or the
/// access condition is ready).
///
/// Dropping an `OpFuture` does **not** cancel the operation: it still
/// executes, still counts toward the declared suprema, and a failure
/// surfaces at commit. `wait()` only observes the result earlier.
#[must_use = "the operation still runs if dropped, but its result is only observed via wait()"]
pub enum OpFuture {
    /// Already resolved (synchronous frameworks, ablation mode, writes).
    Ready(Result<Value, TxError>),
    /// In flight on the home node.
    Pending(Box<dyn PendingOp>),
}

impl OpFuture {
    /// A future that resolved at submission time.
    pub fn ready(r: Result<Value, TxError>) -> Self {
        OpFuture::Ready(r)
    }

    /// Wrap a framework-specific pending operation.
    pub fn pending(p: Box<dyn PendingOp>) -> Self {
        OpFuture::Pending(p)
    }

    /// Non-blocking: would `wait` return immediately?
    pub fn is_ready(&self) -> bool {
        match self {
            OpFuture::Ready(_) => true,
            OpFuture::Pending(p) => p.is_ready(),
        }
    }

    /// Block until the operation has executed and its response arrived,
    /// then return the operation's result.
    pub fn wait(self) -> Result<Value, TxError> {
        match self {
            OpFuture::Ready(r) => r,
            OpFuture::Pending(p) => p.wait(),
        }
    }

    /// Wait on a batch in order, failing fast on the first error.
    pub fn wait_all(futures: impl IntoIterator<Item = OpFuture>) -> Result<Vec<Value>, TxError> {
        futures.into_iter().map(OpFuture::wait).collect()
    }
}

impl fmt::Debug for OpFuture {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OpFuture::Ready(r) => write!(f, "OpFuture::Ready({r:?})"),
            OpFuture::Pending(p) => write!(f, "OpFuture::Pending(ready={})", p.is_ready()),
        }
    }
}

/// A transaction body's view: submit operations, abort, or retry.
/// Implemented by every framework.
pub trait TxCtx {
    /// Dispatch `call` on the declared object `h` without waiting for the
    /// result. Frameworks without asynchronous machinery (and OptSVA-CF in
    /// the `asynchrony = false` ablation) execute the operation inline and
    /// return an already-resolved future, which preserves the sequential
    /// semantics exactly.
    ///
    /// OptSVA-CF additionally guarantees that a future dropped unresolved
    /// surfaces its failure at commit; on the synchronous frameworks
    /// (SVA, TFA, locks) an unobserved inline error is lost with the
    /// dropped future — `wait()` (or `call`) to observe errors there.
    fn submit(&mut self, h: ObjHandle, call: OpCall) -> Result<OpFuture, TxError>;

    /// Blocking convenience: `submit(h, call)?.wait()`.
    fn call(&mut self, h: ObjHandle, call: OpCall) -> Result<Value, TxError> {
        self.submit(h, call)?.wait()
    }

    /// Manual rollback (paper Fig 9): returns `Err(ManualAbort)` so the
    /// body can `return t.abort()` / `?`-propagate out; the framework
    /// performs the actual rollback when the body returns.
    fn abort(&mut self) -> Result<(), TxError> {
        Err(TxError::ManualAbort)
    }

    /// Abort and re-execute the body from scratch.
    fn retry(&mut self) -> Result<(), TxError> {
        Err(TxError::Retry)
    }

    /// Client node executing this transaction.
    fn client(&self) -> NodeId;
}

/// Outcome statistics for one committed transaction.
#[derive(Debug, Clone, Copy, Default)]
pub struct TxStats {
    /// Operations executed on shared objects (final attempt).
    pub ops: u64,
    /// Times the body was (re-)executed before commit (1 = no retries).
    /// Counted by the shared retry driver, so an attempt that aborts
    /// before its first operation still counts.
    pub attempts: u64,
}

/// One preamble entry: an object name and its suprema.
///
/// The `interned` id is the hot-path fast lane: when present, frameworks
/// resolve the object through [`Registry::resolve`] — one atomic load —
/// instead of hashing `name` on every transaction attempt. [`TxBuilder`]
/// fills it in automatically when the target [`Dtm`] exposes its registry;
/// workloads that pre-generate declarations can intern once up front via
/// [`AccessDecl::interned`].
#[derive(Debug, Clone)]
pub struct AccessDecl {
    /// Global object name, as bound in the cluster registry.
    pub name: String,
    /// Declared per-mode operation bounds for this object.
    pub suprema: Suprema,
    /// Interned registry id of `name`, if known. Invariant: when `Some`,
    /// the id was produced by the registry of the cluster this declaration
    /// is used against — ids are meaningless across registries.
    pub interned: Option<NameId>,
}

impl AccessDecl {
    /// Declaration by name only; the id is filled in by [`TxBuilder`] (or
    /// stays `None`, keeping the stringly-keyed `locate` path).
    pub fn new(name: impl Into<String>, suprema: Suprema) -> Self {
        AccessDecl { name: name.into(), suprema, interned: None }
    }

    /// Declaration with a pre-interned id (see [`Registry::intern`]) —
    /// lets benchmark drivers intern each object name exactly once.
    pub fn interned(name: impl Into<String>, id: NameId, suprema: Suprema) -> Self {
        AccessDecl { name: name.into(), suprema, interned: Some(id) }
    }
}

/// Default bound on body re-executions (manual retries) for the
/// pessimistic frameworks. Optimistic TFA defaults to a higher bound
/// (conflict-retries are its normal operating mode); a [`TxSpec`] /
/// [`TxBuilder::max_attempts`] override beats either default.
pub const DEFAULT_MAX_ATTEMPTS: u64 = 1000;

/// Bound on *cascading-abort* retries: a transaction forced to abort
/// because it observed early-released state of an aborter (§2.3) is
/// re-executed at most this many times. An unbounded cascade (e.g. an
/// aborter stuck in a crash loop) would otherwise retry forever, since
/// every [`TxError::ForcedAbort`] looks retryable in isolation.
pub const FORCED_ABORT_RETRY_CAP: u64 = 64;

/// The complete, framework-agnostic transaction preamble: access
/// declarations plus per-transaction knobs. Built by [`TxBuilder`] and
/// consumed by [`Dtm::run_tx`].
#[derive(Debug, Clone, Default)]
pub struct TxSpec {
    /// Declared access set; handle `i` is `decls[i]`.
    pub decls: Vec<AccessDecl>,
    /// Run irrevocably (§2.4): never observe early-released state, never
    /// abort. Frameworks without the distinction ignore it.
    pub irrevocable: bool,
    /// Failure-suspicion deadline override: `None` keeps the framework
    /// default, `Some(None)` disables suspicion (unbounded waits),
    /// `Some(Some(t))` suspects after `t`.
    pub wait_timeout: Option<Option<Duration>>,
    /// Asynchrony override for OptSVA-CF (`None` keeps the system
    /// configuration): `Some(false)` is the ablation mode in which
    /// `submit` degrades to the sequential blocking path.
    pub asynchrony: Option<bool>,
    /// Bound on body re-executions; `None` keeps the framework default
    /// ([`DEFAULT_MAX_ATTEMPTS`] for the pessimistic frameworks, a higher
    /// bound for optimistic TFA whose conflicts retry routinely).
    pub max_attempts: Option<u64>,
}

/// Framework-polymorphic transaction runner.
pub trait Dtm: Send + Sync {
    /// Stable display name, e.g. `"atomic-rmi2 (OptSVA-CF)"`.
    fn framework_name(&self) -> &'static str;

    /// The name registry this framework resolves objects against, if any.
    /// [`TxBuilder`] uses it to intern declared names once at build time so
    /// per-attempt resolution never hashes a string; returning `None`
    /// (the default) keeps the stringly-keyed path.
    fn registry(&self) -> Option<&Registry> {
        None
    }

    /// Run a transaction from `client` over the preamble in `spec`,
    /// handling start/commit/abort and the retry policy. Prefer the
    /// [`TxBuilder`] front end (`dtm.tx(client)`), which also carries the
    /// body's return value.
    fn run_tx(
        &self,
        client: NodeId,
        spec: &TxSpec,
        body: &mut dyn FnMut(&mut dyn TxCtx) -> Result<(), TxError>,
    ) -> Result<TxStats, TxError>;

    /// Total transactions forcibly or optimistically aborted so far
    /// (for the Fig 13 abort-rate table).
    fn aborts(&self) -> u64;

    /// Total commits so far.
    fn commits(&self) -> u64;
}

impl<'a> dyn Dtm + 'a {
    /// Begin building a transaction from `client` (the Fig 8 preamble).
    pub fn tx(&self, client: NodeId) -> TxBuilder<'_> {
        TxBuilder::new(self, client)
    }
}

/// Chainable transaction preamble over any [`Dtm`] (paper Fig 8):
///
/// ```ignore
/// let (sum, stats) = dtm
///     .tx(client)
///     .reads("x", 2)
///     .writes("y", 1)
///     .irrevocable()
///     .run(|t| { /* body using ObjHandle(0), ObjHandle(1) */ Ok(0i64) })?;
/// ```
///
/// Declarations yield handles in order: the first declared object is
/// `ObjHandle(0)`, the second `ObjHandle(1)`, … — or use
/// [`TxBuilder::declare`] to capture the handle directly, and
/// [`TxBuilder::handle`] to look one up by name.
pub struct TxBuilder<'d> {
    dtm: &'d (dyn Dtm + 'd),
    client: NodeId,
    spec: TxSpec,
}

impl<'d> TxBuilder<'d> {
    /// An empty preamble targeting `dtm`, executed from `client`.
    pub fn new(dtm: &'d (dyn Dtm + 'd), client: NodeId) -> Self {
        TxBuilder { dtm, client, spec: TxSpec::default() }
    }

    /// Preamble: declare read-only access with supremum `n` (Fig 8).
    pub fn reads(mut self, name: &str, n: u64) -> Self {
        self.declare(name, Suprema::reads(n));
        self
    }

    /// Preamble: declare write-only access with supremum `n`.
    pub fn writes(mut self, name: &str, n: u64) -> Self {
        self.declare(name, Suprema::writes(n));
        self
    }

    /// Preamble: declare update access with supremum `n`.
    pub fn updates(mut self, name: &str, n: u64) -> Self {
        self.declare(name, Suprema::updates(n));
        self
    }

    /// Preamble: declare mixed access with full per-mode suprema.
    pub fn accesses(mut self, name: &str, sup: Suprema) -> Self {
        self.declare(name, sup);
        self
    }

    /// Declare and return the object's handle (incremental style). Interns
    /// the name against the framework's registry (when exposed) so later
    /// attempts resolve it without hashing the string.
    pub fn declare(&mut self, name: &str, sup: Suprema) -> ObjHandle {
        let mut decl = AccessDecl::new(name, sup);
        decl.interned = self.dtm.registry().map(|r| r.intern(name));
        self.spec.decls.push(decl);
        ObjHandle(self.spec.decls.len() - 1)
    }

    /// Append a pre-built declaration list (handles follow list order).
    /// Declarations without an interned id are interned here, once, rather
    /// than on every transaction attempt.
    pub fn with_decls(mut self, decls: &[AccessDecl]) -> Self {
        let registry = self.dtm.registry();
        self.spec.decls.extend(decls.iter().map(|d| {
            let mut d = d.clone();
            if d.interned.is_none() {
                d.interned = registry.map(|r| r.intern(&d.name));
            }
            d
        }));
        self
    }

    /// Mark the transaction irrevocable (§2.4).
    pub fn irrevocable(mut self) -> Self {
        self.spec.irrevocable = true;
        self
    }

    /// Conditionally mark the transaction irrevocable.
    pub fn irrevocable_if(mut self, on: bool) -> Self {
        self.spec.irrevocable |= on;
        self
    }

    /// Per-transaction failure-suspicion deadline (§3.4).
    pub fn timeout(mut self, t: Duration) -> Self {
        self.spec.wait_timeout = Some(Some(t));
        self
    }

    /// Disable failure suspicion for this transaction: waits are unbounded.
    pub fn no_timeout(mut self) -> Self {
        self.spec.wait_timeout = Some(None);
        self
    }

    /// Per-transaction asynchrony override (OptSVA-CF ablation switch).
    pub fn asynchronous(mut self, on: bool) -> Self {
        self.spec.asynchrony = Some(on);
        self
    }

    /// Bound body re-executions (retries / conflicts), overriding the
    /// framework default.
    pub fn max_attempts(mut self, n: u64) -> Self {
        self.spec.max_attempts = Some(n.max(1));
        self
    }

    /// Handle of a previously declared object, by name.
    pub fn handle(&self, name: &str) -> Option<ObjHandle> {
        self.spec.decls.iter().position(|d| d.name == name).map(ObjHandle)
    }

    /// The accumulated preamble.
    pub fn spec(&self) -> &TxSpec {
        &self.spec
    }

    /// Execute the transaction body: begin, run, commit — with the
    /// framework's retry policy. Returns the body's value (from the
    /// attempt that committed) and the run's statistics.
    pub fn run<R>(
        self,
        mut body: impl FnMut(&mut dyn TxCtx) -> Result<R, TxError>,
    ) -> Result<(R, TxStats), TxError> {
        let mut out: Option<R> = None;
        let stats = self.dtm.run_tx(self.client, &self.spec, &mut |ctx| {
            out = Some(body(ctx)?);
            Ok(())
        })?;
        let r = out.expect("committed transaction ran its body");
        Ok((r, stats))
    }
}

/// Shared retry driver used by every framework's [`Dtm::run_tx`]:
/// re-executes `attempt` (one full begin/body/commit cycle returning the
/// attempt's operation count) while the error is retryable — at most
/// `max_attempts` executions, with a dedicated cap on cascading-abort
/// retries — and counts **every** body execution in
/// [`TxStats::attempts`], including attempts that abort before their
/// first operation.
///
/// `on_retry(attempt_no, err)` runs before each re-execution (TFA uses it
/// for abort accounting and randomized backoff).
pub fn run_with_retries(
    max_attempts: u64,
    mut attempt: impl FnMut() -> Result<u64, TxError>,
    mut on_retry: impl FnMut(u64, &TxError),
) -> Result<TxStats, TxError> {
    let mut attempts = 0u64;
    let mut forced = 0u64;
    loop {
        attempts += 1;
        match attempt() {
            Ok(ops) => return Ok(TxStats { ops, attempts }),
            Err(e) => {
                if matches!(e, TxError::ForcedAbort(_)) {
                    forced += 1;
                    if forced >= FORCED_ABORT_RETRY_CAP {
                        return Err(e);
                    }
                }
                if e.is_retryable() && attempts < max_attempts {
                    on_retry(attempts, &e);
                    continue;
                }
                return Err(e);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suprema_classification() {
        assert!(Suprema::reads(3).read_only());
        assert!(!Suprema::reads(3).write_only());
        assert!(Suprema::writes(2).write_only());
        assert!(!Suprema::new(1, 0, 1).read_only());
        assert!(Suprema::unknown().total() == u64::MAX);
        assert_eq!(Suprema::new(1, 2, 3).total(), 6);
    }

    #[test]
    fn retryable_classification() {
        assert!(TxError::Retry.is_retryable());
        assert!(TxError::Conflict("v".into()).is_retryable());
        assert!(TxError::ForcedAbort("cascade".into()).is_retryable());
        assert!(!TxError::ManualAbort.is_retryable());
        assert!(!TxError::Completed.is_retryable());
    }

    #[test]
    fn ready_futures_resolve_immediately() {
        let f = OpFuture::ready(Ok(Value::Int(7)));
        assert!(f.is_ready());
        assert_eq!(f.wait().unwrap(), Value::Int(7));
        let f = OpFuture::ready(Err(TxError::ManualAbort));
        assert_eq!(f.wait().unwrap_err(), TxError::ManualAbort);
        let vals = OpFuture::wait_all([
            OpFuture::ready(Ok(Value::Int(1))),
            OpFuture::ready(Ok(Value::Int(2))),
        ])
        .unwrap();
        assert_eq!(vals, vec![Value::Int(1), Value::Int(2)]);
    }

    #[test]
    fn builder_assigns_handles_in_declaration_order() {
        struct Nop;
        impl Dtm for Nop {
            fn framework_name(&self) -> &'static str {
                "nop"
            }
            fn run_tx(
                &self,
                _client: NodeId,
                spec: &TxSpec,
                body: &mut dyn FnMut(&mut dyn TxCtx) -> Result<(), TxError>,
            ) -> Result<TxStats, TxError> {
                struct Ctx(NodeId);
                impl TxCtx for Ctx {
                    fn submit(&mut self, _h: ObjHandle, _c: OpCall) -> Result<OpFuture, TxError> {
                        Ok(OpFuture::ready(Ok(Value::Unit)))
                    }
                    fn client(&self) -> NodeId {
                        self.0
                    }
                }
                assert!(spec.irrevocable);
                assert_eq!(spec.wait_timeout, Some(None));
                let mut ctx = Ctx(NodeId(0));
                body(&mut ctx)?;
                Ok(TxStats { ops: 0, attempts: 1 })
            }
            fn aborts(&self) -> u64 {
                0
            }
            fn commits(&self) -> u64 {
                0
            }
        }
        let dtm: &dyn Dtm = &Nop;
        let mut b = dtm.tx(NodeId(0)).reads("x", 2).writes("y", 1);
        assert_eq!(b.handle("x"), Some(ObjHandle(0)));
        assert_eq!(b.handle("y"), Some(ObjHandle(1)));
        assert_eq!(b.handle("z"), None);
        let h = b.declare("z", Suprema::updates(1));
        assert_eq!(h, ObjHandle(2));
        let (v, stats) = b.irrevocable().no_timeout().run(|t| {
            t.call(ObjHandle(0), OpCall::nullary("get"))?;
            Ok(42i64)
        })
        .unwrap();
        assert_eq!(v, 42);
        assert_eq!(stats.attempts, 1);
    }

    #[test]
    fn retry_driver_counts_zero_op_attempts() {
        let mut calls = 0u64;
        let stats = run_with_retries(
            DEFAULT_MAX_ATTEMPTS,
            || {
                calls += 1;
                if calls < 3 {
                    Err(TxError::Retry) // aborts before any op
                } else {
                    Ok(5)
                }
            },
            |_, _| {},
        )
        .unwrap();
        assert_eq!(stats.attempts, 3);
        assert_eq!(stats.ops, 5);
    }

    #[test]
    fn retry_driver_caps_cascading_aborts() {
        let mut calls = 0u64;
        let err = run_with_retries(
            DEFAULT_MAX_ATTEMPTS,
            || {
                calls += 1;
                Err(TxError::ForcedAbort("cascade".into()))
            },
            |_, _| {},
        )
        .unwrap_err();
        assert!(matches!(err, TxError::ForcedAbort(_)));
        assert_eq!(calls, FORCED_ABORT_RETRY_CAP, "cascades must be capped");
    }

    #[test]
    fn retry_driver_respects_max_attempts_and_terminal_errors() {
        let mut calls = 0u64;
        let mut retries = 0u64;
        let err = run_with_retries(
            4,
            || {
                calls += 1;
                Err(TxError::Conflict("v".into()))
            },
            |_, _| retries += 1,
        )
        .unwrap_err();
        assert!(matches!(err, TxError::Conflict(_)));
        assert_eq!(calls, 4);
        assert_eq!(retries, 3);

        let mut calls = 0u64;
        let err = run_with_retries(
            4,
            || {
                calls += 1;
                Err(TxError::ManualAbort)
            },
            |_, _| {},
        )
        .unwrap_err();
        assert_eq!(err, TxError::ManualAbort);
        assert_eq!(calls, 1, "manual aborts are not retried");
    }
}
