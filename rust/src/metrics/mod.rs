//! Result tables and CSV emission for the benchmark harness.
//!
//! The paper reports throughput (shared-data operations per second) as a
//! function of client count (Fig 10), node count (Figs 11–12), and an
//! abort-rate table (Fig 13). [`Table`] renders the same rows/series both
//! as an aligned console table and as CSV for plotting.

use std::fmt::Write as _;

/// A simple column-aligned results table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with a title row and column headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (cells must match the header count).
    pub fn add_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Does the table have no data rows?
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Number of data rows.
    pub fn row_count(&self) -> usize {
        self.rows.len()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Render as CSV (headers + rows), RFC 4180-escaped: cells containing
    /// a comma, a double quote, or a line break are quoted, with internal
    /// quotes doubled. Plain cells are emitted verbatim.
    pub fn to_csv(&self) -> String {
        let csv_line = |cells: &[String]| -> String {
            cells.iter().map(|c| csv_escape(c)).collect::<Vec<_>>().join(",")
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", csv_line(&self.headers));
        for row in &self.rows {
            let _ = writeln!(out, "{}", csv_line(row));
        }
        out
    }

    /// Write the CSV next to the bench outputs.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// RFC 4180 cell escaping: quote only when the cell contains a comma, a
/// double quote, or a CR/LF, doubling any internal quotes.
fn csv_escape(cell: &str) -> String {
    if cell.contains(['"', ',', '\n', '\r']) {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// Human-readable ops/s.
pub fn fmt_throughput(ops_per_s: f64) -> String {
    if ops_per_s >= 10_000.0 {
        format!("{:.1}k", ops_per_s / 1000.0)
    } else {
        format!("{ops_per_s:.1}")
    }
}

/// `a` relative to `b` as the paper quotes it: "+47%" / "-10%".
pub fn fmt_speedup(a: f64, b: f64) -> String {
    if b == 0.0 {
        return "n/a".into();
    }
    let pct = (a / b - 1.0) * 100.0;
    format!("{pct:+.0}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned_and_csv() {
        let mut t = Table::new("demo", &["fw", "tput"]);
        t.add_row(vec!["atomic-rmi2".into(), "123.4".into()]);
        t.add_row(vec!["glock".into(), "7.0".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("atomic-rmi2"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("fw,tput"));
        assert_eq!(t.row_count(), 2);
    }

    #[test]
    fn csv_escapes_commas_quotes_and_newlines() {
        let mut t = Table::new("esc", &["label", "note"]);
        t.add_row(vec!["a,b".into(), "plain".into()]);
        t.add_row(vec!["say \"hi\"".into(), "line1\nline2".into()]);
        let csv = t.to_csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("label,note"), "plain headers stay unquoted");
        assert_eq!(lines.next(), Some("\"a,b\",plain"));
        // The embedded newline keeps the quoted cell open across physical
        // lines — exactly RFC 4180 field folding.
        assert_eq!(lines.next(), Some("\"say \"\"hi\"\"\",\"line1"));
        assert_eq!(lines.next(), Some("line2\""));
        assert_eq!(csv_escape("plain"), "plain");
        assert_eq!(csv_escape("cr\rcell"), "\"cr\rcell\"");
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.add_row(vec!["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_throughput(25_000.0), "25.0k");
        assert_eq!(fmt_throughput(99.95), "100.0");
        assert_eq!(fmt_speedup(1.47, 1.0), "+47%");
        assert_eq!(fmt_speedup(0.9, 1.0), "-10%");
        assert_eq!(fmt_speedup(1.0, 0.0), "n/a");
    }
}
