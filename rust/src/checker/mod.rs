//! Safety checkers: serializability by replay, plus invariant helpers.
//!
//! OptSVA-CF is last-use opaque (§2.10.1), which implies serializability:
//! every concurrent execution must be equivalent to *some* serial one. The
//! versioning algorithms serialize committed transactions in commit-
//! completion order (commit conditions are satisfied in consistent pv
//! order across objects), so the checker replays the recorded committed
//! transactions serially, in commit order, against fresh objects and
//! compares every operation's return value. Any divergence is a
//! serializability violation.
//!
//! Used by the integration and property tests; exposed publicly so
//! downstream users can check their own workloads.

pub mod opacity;
pub mod waitgraph;

pub use opacity::{
    check_last_use_opacity, FinalProbe, HistoryTx, OpacityStats, OpacityViolation, TxOutcome,
};
pub use waitgraph::{WaitEdge, WaitGraph};

use crate::object::{OpCall, SharedObject, Value};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One operation as observed by a committed transaction.
#[derive(Debug, Clone)]
pub struct OpRecord {
    /// Registry name of the object.
    pub object: String,
    /// The invocation as issued.
    pub call: OpCall,
    /// The value the live run returned.
    pub result: Value,
}

/// A committed transaction's observation record.
#[derive(Debug, Clone)]
pub struct TxRecord {
    /// Client-chosen tag (thread id, tx number…) for diagnostics.
    pub tag: String,
    /// The operations in program order, with observed results.
    pub ops: Vec<OpRecord>,
    /// Global commit-completion sequence number.
    pub commit_seq: u64,
}

/// Thread-safe collector of committed-transaction records.
#[derive(Default)]
pub struct Recorder {
    seq: AtomicU64,
    records: Mutex<Vec<TxRecord>>,
}

impl Recorder {
    /// An empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a committed transaction. Call *after* commit succeeds; the
    /// sequence number captures commit-completion order.
    pub fn commit(&self, tag: impl Into<String>, ops: Vec<OpRecord>) {
        let seq = self.seq.fetch_add(1, Ordering::SeqCst);
        self.records.lock().unwrap().push(TxRecord {
            tag: tag.into(),
            ops,
            commit_seq: seq,
        });
    }

    /// All records, sorted by commit order.
    pub fn take(&self) -> Vec<TxRecord> {
        let mut v = std::mem::take(&mut *self.records.lock().unwrap());
        v.sort_by_key(|r| r.commit_seq);
        v
    }
}

/// A serializability violation found by replay.
#[derive(Debug, Clone, PartialEq)]
pub enum CheckError {
    /// An operation's live result differs from the serial replay — no
    /// serial order matching commit-completion order explains the run.
    Divergence {
        /// Client-chosen transaction tag.
        tag: String,
        /// Index of the diverging operation within the transaction.
        index: usize,
        /// Registry name of the object.
        object: String,
        /// What the live run observed.
        live: String,
        /// What the serial replay produced.
        replayed: String,
    },
    /// A record references an object the checker was not given.
    UnknownObject {
        /// Client-chosen transaction tag.
        tag: String,
        /// The unknown object's name.
        object: String,
    },
    /// Replaying a recorded call failed outright.
    ReplayFailed {
        /// Registry name of the object.
        object: String,
        /// The object-level error.
        error: String,
    },
}

impl std::fmt::Display for CheckError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckError::Divergence { tag, index, object, live, replayed } => write!(
                f,
                "tx {tag} op #{index} on {object}: live run saw {live}, serial replay got {replayed}"
            ),
            CheckError::UnknownObject { tag, object } => {
                write!(f, "tx {tag} references unknown object {object}")
            }
            CheckError::ReplayFailed { object, error } => {
                write!(f, "replay error on {object}: {error}")
            }
        }
    }
}

impl std::error::Error for CheckError {}

/// Replay `records` (in commit order) against `initial` object states and
/// verify every recorded return value. On success returns the number of
/// operations verified.
pub fn check_serializable(
    initial: BTreeMap<String, Box<dyn SharedObject>>,
    records: &[TxRecord],
) -> Result<u64, CheckError> {
    let mut objects = initial;
    let mut verified = 0u64;
    let mut ordered: Vec<&TxRecord> = records.iter().collect();
    ordered.sort_by_key(|r| r.commit_seq);
    for rec in ordered {
        for (i, op) in rec.ops.iter().enumerate() {
            let obj = objects
                .get_mut(&op.object)
                .ok_or_else(|| CheckError::UnknownObject {
                    tag: rec.tag.clone(),
                    object: op.object.clone(),
                })?;
            let replayed = obj
                .invoke(&op.call)
                .map_err(|e| CheckError::ReplayFailed {
                    object: op.object.clone(),
                    error: e.to_string(),
                })?;
            if replayed != op.result {
                return Err(CheckError::Divergence {
                    tag: rec.tag.clone(),
                    index: i,
                    object: op.object.clone(),
                    live: op.result.to_string(),
                    replayed: replayed.to_string(),
                });
            }
            verified += 1;
        }
    }
    Ok(verified)
}

/// Replay `records` in commit order and return the final object states —
/// order-independent workloads (commutative operations) can compare these
/// against the live system's final states even when the recorded commit
/// order is only an approximation of the serialization order.
pub fn replay_final(
    initial: BTreeMap<String, Box<dyn SharedObject>>,
    records: &[TxRecord],
) -> Result<BTreeMap<String, Box<dyn SharedObject>>, CheckError> {
    let mut objects = initial;
    let mut ordered: Vec<&TxRecord> = records.iter().collect();
    ordered.sort_by_key(|r| r.commit_seq);
    for rec in ordered {
        for op in &rec.ops {
            let obj = objects
                .get_mut(&op.object)
                .ok_or_else(|| CheckError::UnknownObject {
                    tag: rec.tag.clone(),
                    object: op.object.clone(),
                })?;
            obj.invoke(&op.call).map_err(|e| CheckError::ReplayFailed {
                object: op.object.clone(),
                error: e.to_string(),
            })?;
        }
    }
    Ok(objects)
}

/// Invariant helper: the sum of account balances must be conserved by
/// transfer-only workloads.
pub fn total_balance(balances: impl IntoIterator<Item = i64>) -> i64 {
    balances.into_iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::object::{account::ops, Account};

    fn acct(v: i64) -> Box<dyn SharedObject> {
        Box::new(Account::with_balance(v))
    }

    #[test]
    fn serial_history_verifies() {
        let rec = Recorder::new();
        rec.commit(
            "t1",
            vec![
                OpRecord { object: "A".into(), call: ops::deposit(10), result: Value::Unit },
                OpRecord { object: "A".into(), call: ops::balance(), result: Value::Int(110) },
            ],
        );
        rec.commit(
            "t2",
            vec![OpRecord { object: "A".into(), call: ops::balance(), result: Value::Int(110) }],
        );
        let mut init = BTreeMap::new();
        init.insert("A".to_string(), acct(100));
        let n = check_serializable(init, &rec.take()).unwrap();
        assert_eq!(n, 3);
    }

    #[test]
    fn divergence_is_detected() {
        let rec = Recorder::new();
        // Claims to have read 999 — inconsistent with any serial order.
        rec.commit(
            "bad",
            vec![OpRecord { object: "A".into(), call: ops::balance(), result: Value::Int(999) }],
        );
        let mut init = BTreeMap::new();
        init.insert("A".to_string(), acct(100));
        let err = check_serializable(init, &rec.take()).unwrap_err();
        assert!(matches!(err, CheckError::Divergence { .. }));
    }

    #[test]
    fn unknown_object_is_reported() {
        let rec = Recorder::new();
        rec.commit(
            "t",
            vec![OpRecord { object: "ghost".into(), call: ops::balance(), result: Value::Int(0) }],
        );
        let err = check_serializable(BTreeMap::new(), &rec.take()).unwrap_err();
        assert!(matches!(err, CheckError::UnknownObject { .. }));
    }

    #[test]
    fn commit_order_is_respected() {
        let rec = Recorder::new();
        rec.commit(
            "first",
            vec![OpRecord { object: "A".into(), call: ops::deposit(5), result: Value::Unit }],
        );
        rec.commit(
            "second",
            vec![OpRecord { object: "A".into(), call: ops::balance(), result: Value::Int(105) }],
        );
        let mut init = BTreeMap::new();
        init.insert("A".to_string(), acct(100));
        check_serializable(init, &rec.take()).unwrap();
    }

    #[test]
    fn balance_conservation_helper() {
        assert_eq!(total_balance([100, -30, 30]), 100);
    }
}
