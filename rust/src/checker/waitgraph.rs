//! Wait-for-graph deadlock/livelock detection over versioning waits.
//!
//! Supremum versioning orders transactions per object by private version
//! `pv`: a transaction waiting at the access condition (`lv == pv - 1`) or
//! the commit condition (`ltv == pv - 1`) is blocked by exactly the
//! transactions holding earlier versions of that object that have not yet
//! released (respectively terminated). The schedule explorer materializes
//! those edges whenever no transaction can take a step; a cycle is a
//! deadlock (impossible under correct SVA start-lock ordering — §2.10.2
//! acquires all private versions atomically in global `Oid` order — so any
//! cycle is a protocol bug), and an acyclic stuck graph is a lost wakeup
//! or livelock.

use std::collections::{BTreeMap, BTreeSet};

/// One blocked-on relationship between two transactions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WaitEdge {
    /// Tag of the blocked transaction.
    pub waiter: String,
    /// Tag of a transaction it waits for.
    pub holder: String,
    /// Registry name of the contended object.
    pub object: String,
    /// Which condition blocks: `"access"` (`lv == pv - 1`) or `"commit"`
    /// (`ltv == pv - 1`).
    pub condition: &'static str,
}

impl std::fmt::Display for WaitEdge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} waits for {} on {} ({} condition)",
            self.waiter, self.holder, self.object, self.condition
        )
    }
}

/// A wait-for graph snapshot taken when no transaction could progress.
#[derive(Debug, Clone, Default)]
pub struct WaitGraph {
    /// Every blocked-on edge observed in the snapshot.
    pub edges: Vec<WaitEdge>,
}

impl WaitGraph {
    /// An empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one blocked-on edge.
    pub fn add(
        &mut self,
        waiter: impl Into<String>,
        holder: impl Into<String>,
        object: impl Into<String>,
        condition: &'static str,
    ) {
        self.edges.push(WaitEdge {
            waiter: waiter.into(),
            holder: holder.into(),
            object: object.into(),
            condition,
        });
    }

    /// Find a cycle, if any, as the list of transaction tags along it
    /// (first tag repeated at the end). Deterministic: adjacency is
    /// explored in sorted order.
    pub fn find_cycle(&self) -> Option<Vec<String>> {
        let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
        for e in &self.edges {
            adj.entry(&e.waiter).or_default().insert(&e.holder);
        }
        // Iterative DFS with an explicit stack; `state` is 1 = on the
        // current path, 2 = fully explored.
        let mut state: BTreeMap<&str, u8> = BTreeMap::new();
        for &start in adj.keys() {
            if state.contains_key(start) {
                continue;
            }
            let mut path: Vec<&str> = vec![start];
            let mut iters: Vec<Vec<&str>> = vec![adj
                .get(start)
                .map(|s| s.iter().rev().copied().collect())
                .unwrap_or_default()];
            state.insert(start, 1);
            while let Some(succs) = iters.last_mut() {
                match succs.pop() {
                    Some(next) => match state.get(next).copied() {
                        Some(1) => {
                            // Found a back edge: slice the cycle out of the path.
                            let from = path.iter().position(|&n| n == next).unwrap();
                            let mut cycle: Vec<String> =
                                path[from..].iter().map(|s| s.to_string()).collect();
                            cycle.push(next.to_string());
                            return Some(cycle);
                        }
                        Some(_) => {}
                        None => {
                            state.insert(next, 1);
                            path.push(next);
                            iters.push(
                                adj.get(next)
                                    .map(|s| s.iter().rev().copied().collect())
                                    .unwrap_or_default(),
                            );
                        }
                    },
                    None => {
                        let done = path.pop().unwrap();
                        state.insert(done, 2);
                        iters.pop();
                    }
                }
            }
        }
        None
    }

    /// Render the whole graph (violation reports).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for e in &self.edges {
            out.push_str(&format!("  {e}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_graph_has_no_cycle() {
        assert_eq!(WaitGraph::new().find_cycle(), None);
    }

    #[test]
    fn chain_is_acyclic() {
        let mut g = WaitGraph::new();
        g.add("t2", "t1", "x", "access");
        g.add("t3", "t2", "x", "access");
        assert_eq!(g.find_cycle(), None);
    }

    #[test]
    fn two_cycle_is_found() {
        let mut g = WaitGraph::new();
        g.add("t1", "t2", "x", "access");
        g.add("t2", "t1", "y", "commit");
        let cycle = g.find_cycle().expect("cycle");
        assert_eq!(cycle.first(), cycle.last());
        assert!(cycle.len() == 3, "t-a-t shape, got {cycle:?}");
    }

    #[test]
    fn self_wait_is_a_cycle() {
        let mut g = WaitGraph::new();
        g.add("t1", "t1", "x", "commit");
        assert!(g.find_cycle().is_some());
    }

    #[test]
    fn cycle_behind_a_tail_is_found() {
        let mut g = WaitGraph::new();
        g.add("t0", "t1", "x", "access");
        g.add("t1", "t2", "y", "access");
        g.add("t2", "t1", "z", "access");
        let cycle = g.find_cycle().expect("cycle");
        assert!(cycle.contains(&"t1".to_string()) && cycle.contains(&"t2".to_string()));
        assert!(!cycle[..cycle.len() - 1].contains(&"t0".to_string()));
    }
}
