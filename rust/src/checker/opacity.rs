//! Last-use opacity checking over full (committed + aborted) histories.
//!
//! Last-use opacity (§2.10.1) permits a transaction to read another's
//! writes *after the writer's last use* of the object (early release) —
//! but only if the writer then commits. Concretely, over a recorded
//! history this means:
//!
//!   1. every value observed by a **committed** transaction must be
//!      explained by the serial replay of committed transactions in
//!      commit-completion order (reads come from a committed-or-
//!      will-commit writer at or before its last use), and
//!   2. writes of **aborted** transactions must never leak: the live
//!      system's final object states must equal the committed-only
//!      replay's final states (an aborted write that escaped past early
//!      release and survived rollback shows up here, as does a consumed
//!      dirty read that was laundered into a committed write).
//!
//! Both checks run against the same replay, so a single pass over a
//! history decides last-use opacity for the observable behaviours the
//! recorded operations and final-state probes can distinguish.
//!
//! **Group grants.** Commuting operations admitted through a group grant
//! (`versioning::ObjectCc`, docs/COMMUTATIVITY.md) execute without a
//! fixed chain position, so commit-completion order may interleave group
//! members arbitrarily. No special casing is needed here: a valid
//! commuting declaration is *blind* (the result is independent of object
//! state — enforced by the `commuting-observer` lint), so replaying
//! group members in any serial order yields identical results and an
//! identical final state. Every intra-group order is therefore accepted
//! by construction, and a mis-declared "commuting" observer (the
//! `bogus-commute` mutation) still surfaces as [`OpacityViolation::
//! InconsistentRead`] because its recorded result *does* depend on the
//! order it ran in.

use crate::object::{OpCall, SharedObject, Value};
use std::collections::BTreeMap;

use super::{OpRecord, TxRecord};

/// How a transaction in a recorded history ended.
#[derive(Debug, Clone, PartialEq)]
pub enum TxOutcome {
    /// Commit completed; `seq` is the global commit-completion sequence.
    Committed {
        /// Global commit-completion sequence number.
        seq: u64,
    },
    /// The transaction aborted (voluntarily or by cascade/force).
    Aborted {
        /// Human-readable abort reason for diagnostics.
        reason: String,
    },
}

/// One transaction's observations in a full history — unlike
/// [`TxRecord`], aborted transactions are first-class here because
/// last-use opacity constrains them too.
#[derive(Debug, Clone)]
pub struct HistoryTx {
    /// Client-chosen tag for diagnostics.
    pub tag: String,
    /// Operations in program order with observed results.
    pub ops: Vec<OpRecord>,
    /// Commit or abort.
    pub outcome: TxOutcome,
}

impl HistoryTx {
    /// The commit sequence, if committed.
    pub fn commit_seq(&self) -> Option<u64> {
        match self.outcome {
            TxOutcome::Committed { seq } => Some(seq),
            TxOutcome::Aborted { .. } => None,
        }
    }
}

/// A read of the live system's final state: invoke `call` on `object`
/// after all transactions are done and record what came back. The
/// checker repeats the probe against the committed-only replay.
#[derive(Debug, Clone)]
pub struct FinalProbe {
    /// Registry name of the probed object.
    pub object: String,
    /// The probing invocation (a read-mode method).
    pub call: OpCall,
    /// What the live system returned.
    pub live: Value,
}

/// Counts from a successful opacity check.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpacityStats {
    /// Committed transactions replayed.
    pub committed: usize,
    /// Aborted transactions in the history (constrain final state only).
    pub aborted: usize,
    /// Operation results compared against the replay.
    pub ops_verified: u64,
    /// Final-state probes compared.
    pub probes_verified: usize,
}

/// A last-use-opacity violation.
#[derive(Debug, Clone, PartialEq)]
pub enum OpacityViolation {
    /// A committed transaction observed a value no committed-order serial
    /// replay explains — a read served from an aborted writer's leaked
    /// state, or from a committed writer out of commit order.
    InconsistentRead {
        /// Tag of the observing transaction.
        tag: String,
        /// Index of the operation within the transaction.
        index: usize,
        /// Registry name of the object.
        object: String,
        /// What the live run observed.
        live: String,
        /// What the committed-only replay produced.
        replayed: String,
    },
    /// The live final state differs from the committed-only replay —
    /// an aborted transaction's write leaked past early release and
    /// survived rollback (or a committed write was lost).
    AbortedWriteLeak {
        /// Registry name of the object.
        object: String,
        /// The probe method used.
        probe: String,
        /// Final value observed on the live system.
        live: String,
        /// Final value after committed-only replay.
        replayed: String,
    },
    /// A record references an object the checker was not given.
    UnknownObject {
        /// Tag of the referencing transaction (or `"<probe>"`).
        tag: String,
        /// The unknown object's name.
        object: String,
    },
    /// Replaying a recorded call failed outright.
    ReplayFailed {
        /// Registry name of the object.
        object: String,
        /// The object-level error.
        error: String,
    },
}

impl std::fmt::Display for OpacityViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OpacityViolation::InconsistentRead { tag, index, object, live, replayed } => write!(
                f,
                "inconsistent read: tx {tag} op #{index} on {object} observed {live}, \
                 committed-order replay says {replayed}"
            ),
            OpacityViolation::AbortedWriteLeak { object, probe, live, replayed } => write!(
                f,
                "aborted-write leak: final {probe} on {object} is {live} live but {replayed} \
                 after committed-only replay"
            ),
            OpacityViolation::UnknownObject { tag, object } => {
                write!(f, "tx {tag} references unknown object {object}")
            }
            OpacityViolation::ReplayFailed { object, error } => {
                write!(f, "replay error on {object}: {error}")
            }
        }
    }
}

impl std::error::Error for OpacityViolation {}

/// Check last-use opacity of a full history against `initial` object
/// states and final-state `probes` taken on the live system.
pub fn check_last_use_opacity(
    initial: BTreeMap<String, Box<dyn SharedObject>>,
    history: &[HistoryTx],
    probes: &[FinalProbe],
) -> Result<OpacityStats, OpacityViolation> {
    let mut objects = initial;
    let mut stats = OpacityStats::default();

    let mut committed: Vec<&HistoryTx> =
        history.iter().filter(|t| t.commit_seq().is_some()).collect();
    committed.sort_by_key(|t| t.commit_seq());
    stats.committed = committed.len();
    stats.aborted = history.len() - committed.len();

    for tx in committed {
        for (i, op) in tx.ops.iter().enumerate() {
            let obj = objects
                .get_mut(&op.object)
                .ok_or_else(|| OpacityViolation::UnknownObject {
                    tag: tx.tag.clone(),
                    object: op.object.clone(),
                })?;
            let replayed =
                obj.invoke(&op.call)
                    .map_err(|e| OpacityViolation::ReplayFailed {
                        object: op.object.clone(),
                        error: e.to_string(),
                    })?;
            if replayed != op.result {
                return Err(OpacityViolation::InconsistentRead {
                    tag: tx.tag.clone(),
                    index: i,
                    object: op.object.clone(),
                    live: op.result.to_string(),
                    replayed: replayed.to_string(),
                });
            }
            stats.ops_verified += 1;
        }
    }

    for probe in probes {
        let obj = objects
            .get_mut(&probe.object)
            .ok_or_else(|| OpacityViolation::UnknownObject {
                tag: "<probe>".into(),
                object: probe.object.clone(),
            })?;
        let replayed = obj
            .invoke(&probe.call)
            .map_err(|e| OpacityViolation::ReplayFailed {
                object: probe.object.clone(),
                error: e.to_string(),
            })?;
        if replayed != probe.live {
            return Err(OpacityViolation::AbortedWriteLeak {
                object: probe.object.clone(),
                probe: probe.call.method.to_string(),
                live: probe.live.to_string(),
                replayed: replayed.to_string(),
            });
        }
        stats.probes_verified += 1;
    }

    Ok(stats)
}

/// Adapt a full history's committed transactions into [`TxRecord`]s for
/// the plain serializability checker.
pub fn committed_records(history: &[HistoryTx]) -> Vec<TxRecord> {
    history
        .iter()
        .filter_map(|t| {
            t.commit_seq().map(|seq| TxRecord {
                tag: t.tag.clone(),
                ops: t.ops.clone(),
                commit_seq: seq,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::object::{account::ops, Account};

    fn acct(v: i64) -> Box<dyn SharedObject> {
        Box::new(Account::with_balance(v))
    }

    fn rec(object: &str, call: OpCall, result: Value) -> OpRecord {
        OpRecord { object: object.into(), call, result }
    }

    #[test]
    fn clean_history_passes() {
        let history = vec![
            HistoryTx {
                tag: "t0".into(),
                ops: vec![
                    rec("a", ops::deposit(10), Value::Unit),
                    rec("a", ops::balance(), Value::Int(110)),
                ],
                outcome: TxOutcome::Committed { seq: 0 },
            },
            HistoryTx {
                tag: "t1".into(),
                ops: vec![rec("a", ops::deposit(500), Value::Unit)],
                outcome: TxOutcome::Aborted { reason: "voluntary".into() },
            },
        ];
        let probes = vec![FinalProbe {
            object: "a".into(),
            call: ops::balance(),
            live: Value::Int(110),
        }];
        let mut init = BTreeMap::new();
        init.insert("a".to_string(), acct(100));
        let stats = check_last_use_opacity(init, &history, &probes).unwrap();
        assert_eq!(stats.committed, 1);
        assert_eq!(stats.aborted, 1);
        assert_eq!(stats.ops_verified, 2);
        assert_eq!(stats.probes_verified, 1);
    }

    #[test]
    fn dirty_read_from_aborted_writer_is_caught() {
        // t1 aborted after deposit(500); t0 committed having read 600 —
        // a value only explainable by the aborted write.
        let history = vec![
            HistoryTx {
                tag: "t1".into(),
                ops: vec![rec("a", ops::deposit(500), Value::Unit)],
                outcome: TxOutcome::Aborted { reason: "voluntary".into() },
            },
            HistoryTx {
                tag: "t0".into(),
                ops: vec![rec("a", ops::balance(), Value::Int(600))],
                outcome: TxOutcome::Committed { seq: 0 },
            },
        ];
        let mut init = BTreeMap::new();
        init.insert("a".to_string(), acct(100));
        let err = check_last_use_opacity(init, &history, &[]).unwrap_err();
        assert!(matches!(err, OpacityViolation::InconsistentRead { .. }), "{err}");
    }

    #[test]
    fn aborted_write_leak_in_final_state_is_caught() {
        // No committed tx touched `a`, yet the live final balance shows
        // the aborted deposit: rollback failed to restore.
        let history = vec![HistoryTx {
            tag: "t1".into(),
            ops: vec![rec("a", ops::deposit(500), Value::Unit)],
            outcome: TxOutcome::Aborted { reason: "forced".into() },
        }];
        let probes = vec![FinalProbe {
            object: "a".into(),
            call: ops::balance(),
            live: Value::Int(600),
        }];
        let mut init = BTreeMap::new();
        init.insert("a".to_string(), acct(100));
        let err = check_last_use_opacity(init, &history, &probes).unwrap_err();
        assert!(matches!(err, OpacityViolation::AbortedWriteLeak { .. }), "{err}");
    }

    #[test]
    fn any_intra_group_commit_order_passes() {
        // Two commuting deposits that shared a group grant: both blind,
        // both committed. Whichever commit-completion order the run
        // produced, the replay explains it — the checker accepts every
        // intra-group order.
        for (seq0, seq1) in [(0, 1), (1, 0)] {
            let history = vec![
                HistoryTx {
                    tag: "t0".into(),
                    ops: vec![rec("a", ops::deposit(100), Value::Unit)],
                    outcome: TxOutcome::Committed { seq: seq0 },
                },
                HistoryTx {
                    tag: "t1".into(),
                    ops: vec![rec("a", ops::deposit(10), Value::Unit)],
                    outcome: TxOutcome::Committed { seq: seq1 },
                },
            ];
            let probes = vec![FinalProbe {
                object: "a".into(),
                call: ops::balance(),
                live: Value::Int(210),
            }];
            let mut init = BTreeMap::new();
            init.insert("a".to_string(), acct(100));
            let stats = check_last_use_opacity(init, &history, &probes).unwrap();
            assert_eq!(stats.committed, 2);
            assert_eq!(stats.probes_verified, 1);
        }
    }

    #[test]
    fn committed_records_adapter_drops_aborts() {
        let history = vec![
            HistoryTx {
                tag: "c".into(),
                ops: vec![],
                outcome: TxOutcome::Committed { seq: 3 },
            },
            HistoryTx {
                tag: "a".into(),
                ops: vec![],
                outcome: TxOutcome::Aborted { reason: "x".into() },
            },
        ];
        let recs = committed_records(&history);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].tag, "c");
        assert_eq!(recs[0].commit_seq, 3);
    }
}
