//! Distributed Eigenbench (paper §4.2) and the framework registry.
//!
//! Eigenbench [Hong et al., IISWC'10] drives each TM through a synthetic
//! transactional application with orthogonally tunable characteristics:
//!
//!   * a **hot** array per node — objects shared by every client,
//!     TM-controlled (the contention knob);
//!   * a **mild** array per client — TM-controlled but partitioned so no
//!     two transactions ever conflict on them;
//!   * a **cold** array per client — accessed non-transactionally.
//!
//! Every object is a reference cell ([`RegisterObject`]) whose operations
//! take a configurable time (~3 ms in the paper — "fairly long, which
//! represents the complex computations"). Transactions access
//! semi-randomly selected objects in random order with a configured
//! read-to-write ratio and locality (probability of re-picking from the
//! client's recent-access history).

pub mod eigenbench;
pub mod frameworks;
pub mod megascale;
pub mod sweeps;

pub use eigenbench::{run_eigenbench, EigenbenchParams, EigenbenchResult};
pub use frameworks::{Framework, FrameworkKind, ALL_FRAMEWORKS};
pub use megascale::{run_megascale, MegascaleParams, MegascaleResult};
pub use sweeps::Scale;
