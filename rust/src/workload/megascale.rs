//! Megascale Eigenbench: a deterministic discrete-event engine that
//! drives the sharded transport at 10⁵–10⁶ simulated clients over
//! 10²–10³ nodes — two orders of magnitude past the paper's 16-node
//! evaluation — in virtual time, single-threaded.
//!
//! The paper-faithful harness ([`super::eigenbench`]) runs every client
//! on an OS thread through the full OptSVA-CF stack; that is the right
//! fidelity at paper scale but cannot instantiate 10⁵ threads. This
//! engine keeps the *transport* real — every cross-node message is
//! posted through [`ShardedInboxes`] with FIFO-per-pair arrival
//! deadlines and drained in due batches, exactly the structures the
//! blocking paths use — and models the protocol above it with the
//! supremum-versioning core reduced to its essentials: per-object
//! `pv`-dispenser and `lv` counter, the access condition `lv == pv − 1`,
//! atomic private-version acquisition in global object order at start,
//! and release at last use (each object is used once per transaction, so
//! last use is first use — the OptSVA early-release special case).
//! Transactions are pessimistic and abort-free, ops on distinct objects
//! proceed fully in parallel (the asynchronous buffering claim), and the
//! client commits when every response has arrived back at its home node.
//!
//! Contention shape (the fig11-extension knob): each node hosts a local
//! array, and a *fixed-size global hot set* — independent of node count —
//! is touched with configurable probability. Total throughput therefore
//! rises with node count until the hot set's service capacity
//! (`hot_objects / op_delay`) saturates, which is the flattening point
//! the extended sweep records.
//!
//! [`ShardedInboxes`]: crate::cluster::ShardedInboxes

use crate::cluster::{NetworkModel, NodeId, ShardedInboxes};
use crate::util::prng::Prng;
use std::collections::{BTreeMap, BinaryHeap};
use std::time::{Duration, Instant};

/// Request payload size on the simulated wire (operation id + argument).
const REQ_BYTES: usize = 96;
/// Response payload size (result value + versioning piggyback).
const RESP_BYTES: usize = 64;
/// Tag bit marking a response envelope (requests carry `client << 8 | op`).
const RESP_FLAG: u64 = 1 << 63;

/// Parameters for a megascale run.
#[derive(Debug, Clone, Copy)]
pub struct MegascaleParams {
    /// Simulated node count.
    pub nodes: u16,
    /// Clients per node (total clients = `nodes × clients_per_node`).
    pub clients_per_node: u32,
    /// Transactions each client commits before finishing.
    pub txns_per_client: u32,
    /// Operations per transaction (distinct objects; duplicates re-drawn
    /// into fewer ops).
    pub ops_per_txn: u32,
    /// Percent of ops that target the global hot set (the contention and
    /// flattening knob).
    pub hot_pct: u8,
    /// Size of the global hot set — fixed as nodes scale, spread
    /// round-robin over the nodes.
    pub global_hot_objects: u32,
    /// Local (per-node) array size for non-hot ops.
    pub locals_per_node: u32,
    /// Percent of non-hot ops that stay on the client's home node.
    pub locality_pct: u8,
    /// Simulated duration of one operation body (~3 ms in the paper).
    pub op_delay: Duration,
    /// Client think time between transactions (closed-loop rate limit).
    pub think: Duration,
    /// Interconnect model for cross-node request/response legs.
    pub net: NetworkModel,
    /// Root seed; every client derives an independent stream.
    pub seed: u64,
}

impl Default for MegascaleParams {
    fn default() -> Self {
        MegascaleParams {
            nodes: 25,
            clients_per_node: 1000,
            txns_per_client: 1,
            ops_per_txn: 4,
            hot_pct: 25,
            global_hot_objects: 128,
            locals_per_node: 32,
            locality_pct: 80,
            op_delay: Duration::from_millis(3),
            think: Duration::from_secs(1),
            net: NetworkModel::lan(),
            seed: 42,
        }
    }
}

/// Measurements from one megascale run.
#[derive(Debug, Clone)]
pub struct MegascaleResult {
    /// Node count of the run.
    pub nodes: u16,
    /// Total simulated clients.
    pub clients: u64,
    /// Committed transactions (every transaction commits — pessimistic,
    /// abort-free).
    pub committed_txns: u64,
    /// Operations executed inside committed transactions.
    pub committed_ops: u64,
    /// Simulated elapsed time at the last commit.
    pub sim: Duration,
    /// Wall-clock time the engine took.
    pub wall: Duration,
    /// Committed shared ops per simulated second.
    pub throughput: f64,
    /// Cross-node messages posted through the inboxes.
    pub messages: u64,
    /// Messages delivered per non-empty inbox drain (batching factor of
    /// the sharded transport; 1.0 means no batching ever happened).
    pub batch_factor: f64,
}

/// Engine event. `Begin` starts a client's next transaction, `Arrive`
/// drains one node's due inbox batch, `OpDone` completes one operation
/// body at its object's home node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    Begin { client: u32 },
    Arrive { node: u16 },
    OpDone { obj: u32, client: u32, idx: u8 },
}

/// Min-heap entry ordered by `(at, seq)` — `seq` is the scheduling order,
/// so the event order (and the whole run) is fully deterministic.
#[derive(Debug)]
struct HeapEv {
    at: Duration,
    seq: u64,
    ev: Ev,
}

impl PartialEq for HeapEv {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for HeapEv {}
impl Ord for HeapEv {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other.at.cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for HeapEv {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Per-object supremum-versioning core: `next_pv` dispenser, `lv`
/// counter, and the arrived-but-waiting requests keyed by their `pv`.
#[derive(Debug, Default)]
struct ObjState {
    next_pv: u64,
    lv: u64,
    waiting: BTreeMap<u64, (u32, u8)>,
}

struct ClientState {
    home: NodeId,
    rng: Prng,
    txns_left: u32,
    pending: u32,
    /// This transaction's accesses: `(object, pv)` in global object order.
    ops: Vec<(u32, u64)>,
}

struct Engine<'p> {
    p: &'p MegascaleParams,
    inboxes: ShardedInboxes,
    objs: Vec<ObjState>,
    clients: Vec<ClientState>,
    heap: BinaryHeap<HeapEv>,
    next_seq: u64,
    messages: u64,
    committed_txns: u64,
    committed_ops: u64,
    end: Duration,
}

impl Engine<'_> {
    fn node_of(&self, obj: u32) -> NodeId {
        let hots = self.p.global_hot_objects;
        if obj < hots {
            NodeId((obj % self.p.nodes as u32) as u16)
        } else {
            NodeId(((obj - hots) / self.p.locals_per_node) as u16)
        }
    }

    fn schedule(&mut self, at: Duration, ev: Ev) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(HeapEv { at, seq, ev });
    }

    /// Post one message leg and schedule the destination's drain at its
    /// effective (FIFO-clamped) arrival. Same-node legs are free.
    fn post(&mut self, from: NodeId, to: NodeId, bytes: usize, at: Duration, tag: u64) {
        let delay = if from == to { Duration::ZERO } else { self.p.net.delay(bytes) };
        if from != to {
            self.messages += 1;
        }
        let arrival = self.inboxes.post(from, to, bytes, at, delay, tag);
        self.schedule(arrival, Ev::Arrive { node: to.0 });
    }

    /// Begin a client's next transaction: draw the access set, acquire
    /// private versions atomically in global object order (§2.10.2 —
    /// deadlock-free by construction), and dispatch every request
    /// asynchronously (the OptSVA submit-then-wait shape).
    fn begin(&mut self, client: u32, at: Duration) {
        let p = *self.p;
        let hots = p.global_hot_objects;
        let mut picks: Vec<u32> = Vec::with_capacity(p.ops_per_txn as usize);
        {
            let c = &mut self.clients[client as usize];
            for _ in 0..p.ops_per_txn {
                let obj = if c.rng.below(100) < p.hot_pct as u64 {
                    c.rng.below(hots as u64) as u32
                } else {
                    let node = if c.rng.below(100) < p.locality_pct as u64 {
                        c.home.0 as u32
                    } else {
                        c.rng.below(p.nodes as u64) as u32
                    };
                    hots + node * p.locals_per_node + c.rng.below(p.locals_per_node as u64) as u32
                };
                if !picks.contains(&obj) {
                    picks.push(obj);
                }
            }
            picks.sort_unstable();
        }
        let mut ops = Vec::with_capacity(picks.len());
        for &obj in &picks {
            let o = &mut self.objs[obj as usize];
            let pv = o.next_pv;
            o.next_pv += 1;
            ops.push((obj, pv));
        }
        let home = self.clients[client as usize].home;
        self.clients[client as usize].pending = ops.len() as u32;
        self.clients[client as usize].ops = ops.clone();
        for (idx, &(obj, _pv)) in ops.iter().enumerate() {
            let to = self.node_of(obj);
            self.post(home, to, REQ_BYTES, at, ((client as u64) << 8) | idx as u64);
        }
    }

    /// A request has arrived at its object's home node: start the
    /// operation body if the access condition `lv == pv − 1` holds, else
    /// park it keyed by `pv` (woken by the predecessor's release).
    fn request(&mut self, client: u32, idx: u8, at: Duration) {
        let (obj, pv) = self.clients[client as usize].ops[idx as usize];
        let o = &mut self.objs[obj as usize];
        if o.lv == pv - 1 {
            self.schedule(at + self.p.op_delay, Ev::OpDone { obj, client, idx });
        } else {
            o.waiting.insert(pv, (client, idx));
        }
    }

    /// An operation body finished: release at last use (`lv := pv`),
    /// wake the next waiter if its request already arrived, and send the
    /// response back to the client's home node.
    fn op_done(&mut self, obj: u32, client: u32, idx: u8, at: Duration) {
        let pv = self.clients[client as usize].ops[idx as usize].1;
        let o = &mut self.objs[obj as usize];
        o.lv = pv;
        let next = o.waiting.remove(&(pv + 1));
        if let Some((c2, i2)) = next {
            self.schedule(at + self.p.op_delay, Ev::OpDone { obj, client: c2, idx: i2 });
        }
        self.committed_ops += 1;
        let home = self.clients[client as usize].home;
        let from = self.node_of(obj);
        self.post(from, home, RESP_BYTES, at, RESP_FLAG | client as u64);
    }

    /// A response reached the client: commit once all ops responded, then
    /// think and begin the next transaction.
    fn response(&mut self, client: u32, at: Duration) {
        let c = &mut self.clients[client as usize];
        c.pending -= 1;
        if c.pending > 0 {
            return;
        }
        self.committed_txns += 1;
        c.txns_left -= 1;
        if c.txns_left > 0 {
            let think = self.p.think;
            self.schedule(at + think, Ev::Begin { client });
        }
    }

    fn drain(&mut self, node: u16, at: Duration) {
        let due = self.inboxes.drain_due(NodeId(node), at);
        for env in &due {
            if env.tag & RESP_FLAG != 0 {
                self.response((env.tag & !RESP_FLAG) as u32, at);
            } else {
                self.request((env.tag >> 8) as u32, (env.tag & 0xff) as u8, at);
            }
        }
        self.inboxes.recycle(NodeId(node), due);
    }
}

/// Run the engine to completion (every client commits all its
/// transactions) and report throughput over simulated time.
pub fn run_megascale(p: &MegascaleParams) -> MegascaleResult {
    assert!(p.nodes > 0 && p.clients_per_node > 0 && p.ops_per_txn > 0);
    assert!(p.ops_per_txn <= 256, "op index must fit the request tag byte");
    let wall_start = Instant::now();
    let total_clients = p.nodes as u64 * p.clients_per_node as u64;
    let n_objs = p.global_hot_objects + p.nodes as u32 * p.locals_per_node;
    let root = Prng::seeded(p.seed);
    let think_us = p.think.as_micros().max(1) as u64;
    let mut engine = Engine {
        p,
        inboxes: ShardedInboxes::new(p.nodes),
        objs: (0..n_objs)
            .map(|_| ObjState { next_pv: 1, lv: 0, waiting: BTreeMap::new() })
            .collect(),
        clients: Vec::with_capacity(total_clients as usize),
        heap: BinaryHeap::new(),
        next_seq: 0,
        messages: 0,
        committed_txns: 0,
        committed_ops: 0,
        end: Duration::ZERO,
    };
    for c in 0..total_clients {
        let mut rng = root.split(c);
        // Stagger first transactions across one think window so the run
        // measures steady state, not a thundering herd at t = 0.
        let stagger = Duration::from_micros(rng.below(think_us));
        engine.clients.push(ClientState {
            home: NodeId((c / p.clients_per_node as u64) as u16),
            rng,
            txns_left: p.txns_per_client,
            pending: 0,
            ops: Vec::new(),
        });
        engine.schedule(stagger, Ev::Begin { client: c as u32 });
    }
    while let Some(HeapEv { at, ev, .. }) = engine.heap.pop() {
        engine.end = engine.end.max(at);
        match ev {
            Ev::Begin { client } => engine.begin(client, at),
            Ev::Arrive { node } => engine.drain(node, at),
            Ev::OpDone { obj, client, idx } => engine.op_done(obj, client, idx, at),
        }
    }
    let (delivered, drains) = engine.inboxes.delivery_stats();
    let sim = engine.end;
    MegascaleResult {
        nodes: p.nodes,
        clients: total_clients,
        committed_txns: engine.committed_txns,
        committed_ops: engine.committed_ops,
        sim,
        wall: wall_start.elapsed(),
        throughput: engine.committed_ops as f64 / sim.as_secs_f64().max(1e-9),
        messages: engine.messages,
        batch_factor: delivered as f64 / drains.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> MegascaleParams {
        MegascaleParams {
            nodes: 4,
            clients_per_node: 10,
            txns_per_client: 2,
            ops_per_txn: 3,
            global_hot_objects: 8,
            locals_per_node: 8,
            think: Duration::from_millis(50),
            ..Default::default()
        }
    }

    #[test]
    fn every_client_commits_every_transaction() {
        let p = tiny();
        let r = run_megascale(&p);
        assert_eq!(r.clients, 40);
        assert_eq!(r.committed_txns, 40 * 2, "pessimistic: no aborts, all commit");
        assert!(r.committed_ops >= r.committed_txns, "≥1 op per txn after dedup");
        assert!(r.committed_ops <= r.committed_txns * 3);
        assert!(r.sim > Duration::ZERO);
        assert!(r.throughput > 0.0);
        assert!(r.batch_factor >= 1.0);
    }

    #[test]
    fn runs_are_deterministic_for_a_seed() {
        let p = tiny();
        let a = run_megascale(&p);
        let b = run_megascale(&p);
        assert_eq!(a.committed_ops, b.committed_ops);
        assert_eq!(a.sim, b.sim, "identical virtual end time");
        assert_eq!(a.messages, b.messages);
        let c = run_megascale(&MegascaleParams { seed: 7, ..p });
        assert!(
            c.sim != a.sim || c.committed_ops != a.committed_ops || c.messages != a.messages,
            "a different seed must change the schedule"
        );
    }

    /// The versioning core honors the access condition: with every op on
    /// one hot object, transactions serialize — total simulated time is
    /// at least `total_ops × op_delay` (no two bodies overlap).
    #[test]
    fn single_hot_object_serializes_operation_bodies() {
        let p = MegascaleParams {
            nodes: 2,
            clients_per_node: 5,
            txns_per_client: 1,
            ops_per_txn: 1,
            hot_pct: 100,
            global_hot_objects: 1,
            locals_per_node: 1,
            op_delay: Duration::from_millis(10),
            think: Duration::from_millis(1),
            ..Default::default()
        };
        let r = run_megascale(&p);
        assert_eq!(r.committed_ops, 10);
        assert!(
            r.sim >= Duration::from_millis(100),
            "10 serialized 10 ms bodies need ≥100 ms of simulated time, got {:?}",
            r.sim
        );
    }
}
