//! The distributed Eigenbench driver (paper §4.2–§4.3).
//!
//! Builds the hot/mild/cold arrays over a simulated cluster, spawns client
//! threads, and drives any [`Framework`] through the configured mix of
//! transactional reads and writes. Reports throughput in *operations on
//! shared data per second* — the paper's y-axis.

use super::frameworks::FrameworkKind;
use crate::api::{AccessDecl, Dtm, ObjHandle, OpFuture, Suprema, TxCtx, TxError};
use crate::bench::BenchEntry;
use crate::clock::Clock;
use crate::cluster::{Cluster, NetworkModel};
use crate::object::{OpCall, RegisterObject};
use crate::util::hist::Histogram;
use crate::util::prng::Prng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Eigenbench scenario parameters. Defaults are the paper's Fig 10 setup
/// scaled to a single evaluation box (see DESIGN.md §2).
#[derive(Debug, Clone)]
pub struct EigenbenchParams {
    /// Which concurrency-control framework to drive.
    pub kind: FrameworkKind,
    /// Cluster size (paper: 16).
    pub nodes: u16,
    /// Client threads per node (paper: 4–64).
    pub clients_per_node: u32,
    /// Hot-array objects per node (paper: 5 or 10).
    pub arrays_per_node: u32,
    /// Consecutive transactions per client (paper: 10).
    pub txns_per_client: u32,
    /// Operations on the hot array per transaction (paper: 10).
    pub hot_ops: u32,
    /// Operations on the client's own mild array per transaction
    /// (paper: 0 in Figs 10–11, 10 in Fig 12).
    pub mild_ops: u32,
    /// Non-transactional cold-array operations per transaction.
    pub cold_ops: u32,
    /// Percentage of reads among shared-array operations (90 / 50 / 10
    /// for the paper's 9÷1, 5÷5, 1÷9 ratios).
    pub read_pct: u8,
    /// Probability of re-selecting an object from the client's history.
    pub locality: f64,
    /// Length of the per-client access history (paper: 5).
    pub history: usize,
    /// Operation body duration (paper: ~3 ms).
    pub op_delay: Duration,
    /// Simulated interconnect.
    pub net: NetworkModel,
    /// Run irrevocable transactions instead of ordinary ones.
    pub irrevocable: bool,
    /// Issue each transaction's operations through the asynchronous
    /// `submit` API (all submits first, then wait the futures in order)
    /// instead of blocking `call`s — the submit-then-wait pipelining the
    /// API redesign exposes. Per-object program order is preserved by the
    /// framework, so committed results are identical; only the blocking
    /// structure (and therefore simulated time) changes.
    pub pipeline_ops: bool,
    /// Run on a [`crate::clock::VirtualClock`]: operation delays and
    /// network latency are accounted in simulated time (no real sleeping)
    /// and throughput is reported against simulated elapsed time. The
    /// default; set `false` to measure wall-clock blocking for real.
    pub virtual_time: bool,
    /// Record a [`crate::trace`] session over the run and fill
    /// [`EigenbenchResult::wait`] with the wait-at-version distribution.
    /// Off by default: the run then pays only one relaxed atomic load per
    /// would-be event.
    pub trace: bool,
    /// PRNG seed; every client derives its stream by splitting this.
    pub seed: u64,
}

impl Default for EigenbenchParams {
    fn default() -> Self {
        EigenbenchParams {
            kind: FrameworkKind::Optsva,
            nodes: 4,
            clients_per_node: 4,
            arrays_per_node: 10,
            txns_per_client: 10,
            hot_ops: 10,
            mild_ops: 0,
            cold_ops: 0,
            read_pct: 90,
            locality: 0.5,
            history: 5,
            op_delay: Duration::from_millis(3),
            net: NetworkModel::lan(),
            irrevocable: false,
            pipeline_ops: false,
            virtual_time: true,
            trace: false,
            seed: 0xE16E_5EED,
        }
    }
}

impl EigenbenchParams {
    /// Total client threads across the cluster (`nodes × clients_per_node`).
    pub fn total_clients(&self) -> u32 {
        self.nodes as u32 * self.clients_per_node
    }

    /// Paper ratio label, e.g. "9÷1".
    pub fn ratio_label(&self) -> String {
        format!("{}÷{}", self.read_pct / 10, 10 - self.read_pct / 10)
    }
}

/// Outcome of one Eigenbench run.
#[derive(Debug, Clone)]
pub struct EigenbenchResult {
    /// Compact scenario label, e.g. `4n/16c/10a/9÷1`.
    pub params_label: String,
    /// Framework name as reported by [`Dtm::framework_name`].
    pub framework: &'static str,
    /// Committed shared-data operations per second (the paper's metric).
    pub throughput: f64,
    /// Transactions that ran to commit.
    pub committed_txns: u64,
    /// Shared-data operations inside committed transactions.
    pub committed_ops: u64,
    /// Framework-level abort count (0 for the pessimistic frameworks).
    pub aborts: u64,
    /// Total execution attempts across committed transactions (≥
    /// `committed_txns`; the excess is retries after aborts).
    pub attempts: u64,
    /// Fraction of transactions that aborted ≥ once (Fig 13).
    pub abort_rate: f64,
    /// Real elapsed time of the run.
    pub wall: Duration,
    /// Simulated elapsed time (equals `wall` on a real clock).
    pub sim: Duration,
    /// Per-transaction latency distribution (µs, simulated time).
    pub latency: Histogram,
    /// Wait-at-version distribution (µs spent blocked in access/commit
    /// conditions), from the run's [`crate::trace`] session. Empty unless
    /// [`EigenbenchParams::trace`] was set.
    pub wait: Histogram,
}

impl EigenbenchResult {
    /// One CSV row: `framework,clients,nodes,ratio,throughput,aborts,...`,
    /// ending with the wait-at-version p50/p99 (µs; 0 when untraced).
    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{:.1},{},{},{},{:.3},{},{},{},{}",
            self.framework,
            self.params_label,
            self.throughput,
            self.committed_txns,
            self.committed_ops,
            self.aborts,
            self.abort_rate,
            self.wall.as_millis(),
            self.sim.as_millis(),
            self.wait.quantile(0.5),
            self.wait.quantile(0.99),
        )
    }

    /// This result as a [`BenchEntry`] for a `BENCH_*.json` report.
    ///
    /// `throughput_ops_s` is directional (higher is better) and gated by
    /// CI; the rest are context. Latency quantiles come from the simulated
    /// per-transaction [`Histogram`].
    pub fn bench_entry(&self, name: impl Into<String>) -> BenchEntry {
        BenchEntry::new(name)
            .metric("throughput_ops_s", self.throughput)
            .metric("committed_txns", self.committed_txns as f64)
            .metric("committed_ops", self.committed_ops as f64)
            .metric("aborts", self.aborts as f64)
            .metric("attempts", self.attempts as f64)
            .metric("abort_rate", self.abort_rate)
            .metric("wall_ms", self.wall.as_secs_f64() * 1e3)
            .metric("sim_ms", self.sim.as_secs_f64() * 1e3)
            .metric("latency_p50_us", self.latency.quantile(0.5) as f64)
            .metric("latency_p99_us", self.latency.quantile(0.99) as f64)
            .metric("wait_p50_us", self.wait.quantile(0.5) as f64)
            .metric("wait_p99_us", self.wait.quantile(0.99) as f64)
    }
}

/// One randomly generated transaction program: the access declarations and
/// the operation sequence over them.
struct TxProgram {
    decls: Vec<AccessDecl>,
    ops: Vec<(usize, OpCall)>,
    shared_ops: u64,
}

/// Generate one transaction: pick objects with locality, interleave hot and
/// mild accesses in random order, derive exact per-mode suprema.
fn gen_tx(
    rng: &mut Prng,
    params: &EigenbenchParams,
    hot_names: &[String],
    mild_names: &[String],
    history: &mut Vec<String>,
) -> TxProgram {
    // (name, is_read) picks, hot then mild, then shuffled together.
    let mut picks: Vec<(String, bool)> = Vec::new();
    for _ in 0..params.hot_ops {
        let name = if !history.is_empty() && rng.chance(params.locality) {
            rng.pick(history).clone()
        } else {
            rng.pick(hot_names).clone()
        };
        if history.len() >= params.history {
            history.remove(0);
        }
        history.push(name.clone());
        picks.push((name, rng.below(100) < params.read_pct as u64));
    }
    for _ in 0..params.mild_ops {
        let name = rng.pick(mild_names).clone();
        picks.push((name, rng.below(100) < params.read_pct as u64));
    }
    rng.shuffle(&mut picks);

    // Aggregate exact suprema per distinct object (perfect a-priori
    // knowledge, as the paper's preamble provides).
    let mut decls: Vec<AccessDecl> = Vec::new();
    let mut ops: Vec<(usize, OpCall)> = Vec::with_capacity(picks.len());
    for (name, is_read) in picks {
        let idx = match decls.iter().position(|d| d.name == name) {
            Some(i) => i,
            None => {
                decls.push(AccessDecl::new(name.clone(), Suprema::new(0, 0, 0)));
                decls.len() - 1
            }
        };
        if is_read {
            decls[idx].suprema.reads += 1;
            ops.push((idx, OpCall::nullary("get")));
        } else {
            decls[idx].suprema.writes += 1;
            ops.push((idx, OpCall::unary("set", rng.next_u64() as i64 & 0xFFFF)));
        }
    }
    let shared = ops.len() as u64;
    TxProgram { decls, ops, shared_ops: shared }
}

/// Run one Eigenbench scenario end to end. Builds a fresh cluster and
/// framework, hosts the arrays, spawns `total_clients` threads, runs
/// `txns_per_client` transactions each, and aggregates the results.
pub fn run_eigenbench(params: &EigenbenchParams) -> EigenbenchResult {
    let cluster = Arc::new(if params.virtual_time {
        Cluster::new_virtual(params.nodes, params.net)
    } else {
        Cluster::new(params.nodes, params.net)
    });
    let clock = Arc::clone(cluster.clock());
    // The session (one per process at a time) must open before the
    // framework is built so node executors label themselves while tracing
    // is already on; it stamps events with this run's clock.
    let session = params.trace.then(|| {
        let s = crate::trace::TraceSession::start();
        crate::trace::set_session_clock(Arc::clone(&clock));
        s
    });
    let fw = Arc::new(params.kind.build(Arc::clone(&cluster)));

    // Hot arrays: `arrays_per_node` objects on every node, shared by all.
    // Operation bodies burn their ~3 ms on the cluster's clock.
    let mut hot_names = Vec::new();
    for node in cluster.node_ids() {
        for i in 0..params.arrays_per_node {
            let name = format!("hot-{}-{}", node.0, i);
            fw.host(
                node,
                &name,
                Box::new(RegisterObject::with_delay_on(0, params.op_delay, Arc::clone(&clock))),
            );
            hot_names.push(name);
        }
    }
    let hot_names = Arc::new(hot_names);

    // Mild arrays: `arrays_per_node` objects per client on the client's
    // node — TM-controlled but conflict-free by partitioning.
    let mut mild_per_client: Vec<Arc<Vec<String>>> = Vec::new();
    for node in cluster.node_ids() {
        for c in 0..params.clients_per_node {
            let mut names = Vec::new();
            if params.mild_ops > 0 {
                for i in 0..params.arrays_per_node {
                    let name = format!("mild-{}-{}-{}", node.0, c, i);
                    fw.host(
                        node,
                        &name,
                        Box::new(RegisterObject::with_delay_on(
                            0,
                            params.op_delay,
                            Arc::clone(&clock),
                        )),
                    );
                    names.push(name);
                }
            }
            mild_per_client.push(Arc::new(names));
        }
    }

    let committed_txns = Arc::new(AtomicU64::new(0));
    let committed_ops = Arc::new(AtomicU64::new(0));
    let latency = Arc::new(Mutex::new(Histogram::new()));
    let txns_with_retry = Arc::new(AtomicU64::new(0));
    let total_attempts = Arc::new(AtomicU64::new(0));

    let t0 = Instant::now();
    let sim0 = clock.now();
    let mut handles = Vec::new();
    let mut client_id = 0usize;
    for node in cluster.node_ids() {
        for _ in 0..params.clients_per_node {
            let fw = Arc::clone(&fw);
            let params = params.clone();
            let clock = Arc::clone(&clock);
            let hot_names = Arc::clone(&hot_names);
            let mild_names = Arc::clone(&mild_per_client[client_id]);
            let committed_txns = Arc::clone(&committed_txns);
            let committed_ops = Arc::clone(&committed_ops);
            let latency = Arc::clone(&latency);
            let txns_with_retry = Arc::clone(&txns_with_retry);
            let total_attempts = Arc::clone(&total_attempts);
            let mut rng = Prng::seeded(params.seed).split(client_id as u64);
            client_id += 1;
            // Named threads with a small fixed stack: client bodies are
            // shallow (no recursion), and the default 2 MiB per thread is
            // what caps how many clients fit in one process. The truly
            // huge client counts run on the megascale engine instead
            // ([`super::megascale`]), but this keeps the faithful
            // thread-per-client harness usable well past paper scale.
            let builder = std::thread::Builder::new()
                .name(format!("eigen-client-{}", client_id - 1))
                .stack_size(256 * 1024);
            let builder_handle = builder.spawn(move || {
                let mut history: Vec<String> = Vec::new();
                // Cold array: client-local, non-transactional.
                let mut cold: Vec<i64> = vec![0; params.arrays_per_node as usize];
                let mut local_hist = Histogram::new();
                for _ in 0..params.txns_per_client {
                    let prog = gen_tx(&mut rng, &params, &hot_names, &mild_names, &mut history);
                    let t_tx = clock.now();
                    let r = fw
                        .dtm()
                        .tx(node)
                        .with_decls(&prog.decls)
                        .irrevocable_if(params.irrevocable)
                        .run(|t| {
                            if params.pipeline_ops {
                                // Submit-then-wait: fan every operation out,
                                // then collect; per-object order is kept by
                                // the framework.
                                let mut futures = Vec::with_capacity(prog.ops.len());
                                for (idx, call) in &prog.ops {
                                    futures.push(t.submit(ObjHandle(*idx), call.clone())?);
                                }
                                OpFuture::wait_all(futures)?;
                            } else {
                                for (idx, call) in &prog.ops {
                                    t.call(ObjHandle(*idx), call.clone())?;
                                }
                            }
                            Ok(())
                        })
                        .map(|((), stats)| stats);
                    local_hist.record_duration(clock.now().saturating_sub(t_tx));
                    match r {
                        Ok(stats) => {
                            committed_txns.fetch_add(1, Ordering::Relaxed);
                            committed_ops.fetch_add(prog.shared_ops, Ordering::Relaxed);
                            total_attempts.fetch_add(stats.attempts, Ordering::Relaxed);
                            if stats.attempts > 1 {
                                txns_with_retry.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Err(TxError::ManualAbort) => {}
                        Err(e) => panic!("eigenbench transaction failed: {e}"),
                    }
                    // Cold accesses: outside any transaction.
                    for _ in 0..params.cold_ops {
                        let i = rng.index(cold.len());
                        if rng.below(100) < params.read_pct as u64 {
                            std::hint::black_box(cold[i]);
                        } else {
                            cold[i] = rng.next_u64() as i64;
                        }
                    }
                }
                latency.lock().unwrap().merge(&local_hist);
            });
            handles.push(builder_handle.expect("spawn eigenbench client thread"));
        }
    }
    for h in handles {
        h.join().expect("eigenbench client panicked");
    }
    let wall = t0.elapsed();
    let sim = clock.now().saturating_sub(sim0);
    fw.shutdown();
    let wait = match session {
        Some(s) => crate::trace::aggregate::summarize(&s.finish()).wait_all,
        None => Histogram::new(),
    };

    let txns = committed_txns.load(Ordering::Relaxed);
    let ops = committed_ops.load(Ordering::Relaxed);
    let aborts = fw.dtm().aborts();
    let retried = txns_with_retry.load(Ordering::Relaxed);
    // Throughput is measured against the time base the run blocked on:
    // simulated time under a virtual clock (falling back to wall time if
    // the scenario injected no delays at all), wall time otherwise.
    let elapsed = if params.virtual_time && !sim.is_zero() { sim } else { wall };
    EigenbenchResult {
        params_label: format!(
            "{}n/{}c/{}a/{}{}",
            params.nodes,
            params.total_clients(),
            params.arrays_per_node,
            params.ratio_label(),
            if params.pipeline_ops { "/pipe" } else { "" },
        ),
        framework: fw.dtm().framework_name(),
        throughput: ops as f64 / elapsed.as_secs_f64().max(f64::MIN_POSITIVE),
        committed_txns: txns,
        committed_ops: ops,
        aborts,
        attempts: total_attempts.load(Ordering::Relaxed),
        abort_rate: if txns == 0 { 0.0 } else { retried as f64 / txns as f64 },
        wall,
        sim,
        latency: Arc::try_unwrap(latency).map(|m| m.into_inner().unwrap()).unwrap_or_default(),
        wait,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(kind: FrameworkKind, read_pct: u8) -> EigenbenchResult {
        run_eigenbench(&EigenbenchParams {
            kind,
            nodes: 2,
            clients_per_node: 2,
            arrays_per_node: 4,
            txns_per_client: 3,
            hot_ops: 4,
            mild_ops: 0,
            cold_ops: 2,
            read_pct,
            op_delay: Duration::from_micros(200),
            net: NetworkModel::instant(),
            ..Default::default()
        })
    }

    #[test]
    fn every_framework_completes_the_benchmark() {
        for kind in super::super::ALL_FRAMEWORKS {
            let r = quick(*kind, 50);
            assert_eq!(r.committed_txns, 2 * 2 * 3, "{}", r.framework);
            assert_eq!(r.committed_ops, r.committed_txns * 4);
            assert!(r.throughput > 0.0);
            assert!(r.attempts >= r.committed_txns, "{}", r.framework);
            let entry = r.bench_entry("probe");
            assert_eq!(entry.get("throughput_ops_s"), Some(r.throughput));
            assert_eq!(entry.get("attempts"), Some(r.attempts as f64));
        }
    }

    #[test]
    fn pessimistic_frameworks_never_abort() {
        for kind in [FrameworkKind::Optsva, FrameworkKind::Sva] {
            let r = quick(kind, 10);
            assert_eq!(r.aborts, 0, "{} must be abort-free", r.framework);
            assert_eq!(r.abort_rate, 0.0);
        }
    }

    #[test]
    fn deterministic_program_generation() {
        let params = EigenbenchParams::default();
        let hot: Vec<String> = (0..8).map(|i| format!("hot-{i}")).collect();
        let mild: Vec<String> = vec![];
        let mut h1 = Vec::new();
        let mut h2 = Vec::new();
        let mut r1 = Prng::seeded(7);
        let mut r2 = Prng::seeded(7);
        let p1 = gen_tx(&mut r1, &params, &hot, &mild, &mut h1);
        let p2 = gen_tx(&mut r2, &params, &hot, &mild, &mut h2);
        assert_eq!(p1.ops.len(), p2.ops.len());
        for (a, b) in p1.ops.iter().zip(&p2.ops) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.method, b.1.method);
        }
        assert_eq!(p1.shared_ops, 10);
    }

    #[test]
    fn suprema_exactly_cover_the_ops() {
        let params = EigenbenchParams { hot_ops: 20, ..Default::default() };
        let hot: Vec<String> = (0..4).map(|i| format!("hot-{i}")).collect();
        let mut hist = Vec::new();
        let mut rng = Prng::seeded(42);
        let prog = gen_tx(&mut rng, &params, &hot, &[], &mut hist);
        let mut reads = vec![0u64; prog.decls.len()];
        let mut writes = vec![0u64; prog.decls.len()];
        for (idx, call) in &prog.ops {
            if call.method == "get" {
                reads[*idx] += 1;
            } else {
                writes[*idx] += 1;
            }
        }
        for (i, d) in prog.decls.iter().enumerate() {
            assert_eq!(d.suprema.reads, reads[i]);
            assert_eq!(d.suprema.writes, writes[i]);
            assert_eq!(d.suprema.updates, 0);
        }
    }

    #[test]
    fn virtual_time_accounts_latency_without_wall_clock_cost() {
        // 50 ms per op × 4 ops × 2 txns per client would cost seconds of
        // real sleeping; under the virtual clock it must be near-instant
        // while still accounting at least one client's serial chain.
        let r = run_eigenbench(&EigenbenchParams {
            kind: FrameworkKind::Optsva,
            nodes: 2,
            clients_per_node: 2,
            arrays_per_node: 4,
            txns_per_client: 2,
            hot_ops: 4,
            op_delay: Duration::from_millis(50),
            net: NetworkModel::lan(),
            ..Default::default()
        });
        assert_eq!(r.committed_txns, 2 * 2 * 2);
        assert!(
            r.sim >= Duration::from_millis(400),
            "one client's serial chain is ≥ 400 ms simulated, got {:?}",
            r.sim
        );
        assert!(
            r.wall < Duration::from_secs(10),
            "virtual run must not sleep for real, took {:?}",
            r.wall
        );
        assert!(r.throughput > 0.0);
    }

    #[test]
    fn pipelined_mode_commits_identically_to_blocking() {
        // The futures API must not change *what* commits — only the
        // blocking structure. (Final-state equality across random wait
        // interleavings is covered by the `async_api` property suite.)
        for kind in [
            FrameworkKind::Optsva,
            FrameworkKind::OptsvaNoAsync,
            FrameworkKind::Tfa,
        ] {
            let base = EigenbenchParams {
                kind,
                nodes: 2,
                clients_per_node: 2,
                arrays_per_node: 4,
                txns_per_client: 3,
                hot_ops: 6,
                read_pct: 50,
                op_delay: Duration::from_micros(100),
                net: NetworkModel::instant(),
                ..Default::default()
            };
            let blocking = run_eigenbench(&base);
            let pipelined =
                run_eigenbench(&EigenbenchParams { pipeline_ops: true, ..base.clone() });
            assert_eq!(pipelined.committed_txns, blocking.committed_txns, "{}", kind.label());
            assert_eq!(pipelined.committed_ops, blocking.committed_ops, "{}", kind.label());
            assert!(pipelined.params_label.ends_with("/pipe"));
        }
    }

    #[test]
    fn traced_run_fills_wait_histogram_and_csv_columns() {
        let r = run_eigenbench(&EigenbenchParams {
            kind: FrameworkKind::Optsva,
            nodes: 2,
            clients_per_node: 2,
            arrays_per_node: 2,
            txns_per_client: 2,
            hot_ops: 4,
            read_pct: 10,
            op_delay: Duration::from_millis(2),
            net: NetworkModel::instant(),
            trace: true,
            ..Default::default()
        });
        assert_eq!(r.committed_txns, 2 * 2 * 2);
        // 11 columns: the base 9 plus wait_p50_us / wait_p99_us.
        assert_eq!(r.csv_row().matches(',').count(), 10);
        // An untraced run reports an empty wait distribution.
        let quiet = quick(FrameworkKind::Optsva, 50);
        assert_eq!(quiet.wait.count(), 0);
    }

    #[test]
    fn irrevocable_mode_runs_clean() {
        let r = run_eigenbench(&EigenbenchParams {
            kind: FrameworkKind::Optsva,
            nodes: 1,
            clients_per_node: 2,
            arrays_per_node: 2,
            txns_per_client: 2,
            hot_ops: 3,
            op_delay: Duration::from_micros(100),
            net: NetworkModel::instant(),
            irrevocable: true,
            ..Default::default()
        });
        assert_eq!(r.committed_txns, 4);
        assert_eq!(r.aborts, 0);
    }
}
