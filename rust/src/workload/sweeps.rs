//! Paper-figure sweep drivers (§4.3): one function per table/figure,
//! shared by the `cargo bench` targets and the CLI (`atomic-rmi2 sweep`).
//!
//! The paper ran on a 16-node 1 GbE cluster with ~3 ms operations; the
//! sweeps below run the same *structure* scaled to one box (see DESIGN.md
//! §2 and §5): 2–8 simulated nodes, 0.8 ms operations, LAN-model latency.
//! Absolute throughput differs from the paper's; the comparisons —
//! who wins, by roughly what factor, where the crossovers are — are what
//! the harness regenerates.
//!
//! All sweeps run in **virtual time** ([`EigenbenchParams::virtual_time`],
//! on by default): injected operation and network latency is accounted on
//! a [`crate::clock::VirtualClock`], so regenerating a figure costs
//! seconds of CPU instead of minutes of sleeping, and throughput is
//! reported against simulated elapsed time.

use super::eigenbench::{run_eigenbench, EigenbenchParams, EigenbenchResult};
use super::frameworks::FrameworkKind;
use crate::bench::BenchReport;
use crate::metrics::{fmt_throughput, Table};
use crate::NetworkModel;
use std::time::Duration;

/// Scale factor for sweep duration: `quick` runs a fraction of the work
/// for smoke-testing; full runs regenerate the figures properly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Smoke-test fraction of the sweep (CI default, `ARMI2_BENCH_QUICK`).
    Quick,
    /// The full figure-regenerating sweep.
    Full,
}

impl Scale {
    fn txns(&self) -> u32 {
        match self {
            Scale::Quick => 2,
            Scale::Full => 6,
        }
    }

    fn op_delay(&self) -> Duration {
        match self {
            Scale::Quick => Duration::from_micros(100),
            Scale::Full => Duration::from_micros(800),
        }
    }
}

/// The frameworks each figure compares (paper §4.1 set).
pub const FIGURE_FRAMEWORKS: &[FrameworkKind] = &[
    FrameworkKind::Optsva,
    FrameworkKind::Sva,
    FrameworkKind::Tfa,
    FrameworkKind::MutexS2pl,
    FrameworkKind::Mutex2pl,
    FrameworkKind::RwS2pl,
    FrameworkKind::Rw2pl,
    FrameworkKind::GLock,
];

/// The paper's three read percentages (9÷1, 5÷5, 1÷9 ratios).
pub const RATIOS: &[u8] = &[90, 50, 10];

fn base(scale: Scale) -> EigenbenchParams {
    EigenbenchParams {
        txns_per_client: scale.txns(),
        hot_ops: 10,
        op_delay: scale.op_delay(),
        net: NetworkModel::lan(),
        locality: 0.5,
        history: 5,
        ..Default::default()
    }
}

/// Fig 10: throughput vs client count (contention sweep), 3 ratios.
/// Paper: 16 nodes, 64→1024 clients; here 4 nodes, 8→64 clients.
pub fn fig10(scale: Scale) -> (Vec<Table>, Vec<EigenbenchResult>) {
    let clients_per_node: &[u32] = match scale {
        Scale::Quick => &[2, 4],
        Scale::Full => &[2, 4, 8, 16],
    };
    let mut tables = Vec::new();
    let mut all = Vec::new();
    for &read_pct in RATIOS {
        let mut t = Table::new(
            format!(
                "Fig 10 ({}÷{}): throughput [ops/s] vs clients, 4 nodes, 10 arrays/node",
                read_pct / 10,
                10 - read_pct / 10
            ),
            &std::iter::once("framework")
                .chain(clients_per_node.iter().map(|c| {
                    Box::leak(format!("{}cl", c * 4).into_boxed_str()) as &str
                }))
                .collect::<Vec<_>>(),
        );
        for &kind in FIGURE_FRAMEWORKS {
            let mut row = vec![kind.label().to_string()];
            for &cpn in clients_per_node {
                let r = run_eigenbench(&EigenbenchParams {
                    kind,
                    nodes: 4,
                    clients_per_node: cpn,
                    arrays_per_node: 10,
                    read_pct,
                    ..base(scale)
                });
                row.push(fmt_throughput(r.throughput));
                all.push(r);
            }
            t.add_row(row);
        }
        tables.push(t);
    }
    (tables, all)
}

/// Figs 11a–c: throughput vs node count at constant per-node load,
/// 5 and 10 arrays/node (higher and lower contention).
/// Paper: 4→16 nodes, 16 clients/node; here 2→8 nodes, 4 clients/node.
pub fn fig11(scale: Scale) -> (Vec<Table>, Vec<EigenbenchResult>) {
    fig_nodes(scale, 0, "Fig 11")
}

/// Fig 12: as Fig 11 but each transaction adds 10 mild-array operations
/// (conflict-free), lowering average contention.
pub fn fig12(scale: Scale) -> (Vec<Table>, Vec<EigenbenchResult>) {
    fig_nodes(scale, 10, "Fig 12")
}

fn fig_nodes(scale: Scale, mild_ops: u32, tag: &str) -> (Vec<Table>, Vec<EigenbenchResult>) {
    let nodes: &[u16] = match scale {
        Scale::Quick => &[2, 4],
        Scale::Full => &[2, 4, 8],
    };
    let arrays: &[u32] = if mild_ops == 0 { &[5, 10] } else { &[10] };
    let mut tables = Vec::new();
    let mut all = Vec::new();
    for &arrays_per_node in arrays {
        for &read_pct in RATIOS {
            let mut t = Table::new(
                format!(
                    "{tag} ({}÷{}, {arrays_per_node} arrays/node{}): throughput [ops/s] vs nodes",
                    read_pct / 10,
                    10 - read_pct / 10,
                    if mild_ops > 0 { ", +10 mild ops" } else { "" },
                ),
                &std::iter::once("framework")
                    .chain(nodes.iter().map(|n| {
                        Box::leak(format!("{n}n").into_boxed_str()) as &str
                    }))
                    .collect::<Vec<_>>(),
            );
            for &kind in FIGURE_FRAMEWORKS {
                let mut row = vec![kind.label().to_string()];
                for &n in nodes {
                    let r = run_eigenbench(&EigenbenchParams {
                        kind,
                        nodes: n,
                        clients_per_node: 4,
                        arrays_per_node,
                        mild_ops,
                        read_pct,
                        ..base(scale)
                    });
                    row.push(fmt_throughput(r.throughput));
                    all.push(r);
                }
                t.add_row(row);
            }
            tables.push(t);
        }
    }
    (tables, all)
}

/// Fig 13: abort-rate table — fraction of transactions that abort and
/// retry at least once, per client count, for TFA (HyFlow2) vs the
/// pessimistic frameworks (which must stay at exactly 0).
pub fn fig13(scale: Scale) -> (Table, Vec<EigenbenchResult>) {
    let clients_per_node: &[u32] = match scale {
        Scale::Quick => &[2, 4],
        Scale::Full => &[2, 4, 8, 16],
    };
    let mut t = Table::new(
        "Fig 13: % transactions aborted ≥once (5÷5 ratio) vs clients",
        &std::iter::once("framework")
            .chain(clients_per_node.iter().map(|c| {
                Box::leak(format!("{}cl", c * 4).into_boxed_str()) as &str
            }))
            .collect::<Vec<_>>(),
    );
    let mut all = Vec::new();
    for kind in [FrameworkKind::Tfa, FrameworkKind::Optsva, FrameworkKind::Sva] {
        let mut row = vec![kind.label().to_string()];
        for &cpn in clients_per_node {
            let r = run_eigenbench(&EigenbenchParams {
                kind,
                nodes: 4,
                clients_per_node: cpn,
                arrays_per_node: 10,
                read_pct: 50,
                ..base(scale)
            });
            row.push(format!("{:.0}%", r.abort_rate * 100.0));
            all.push(r);
        }
        t.add_row(row);
    }
    (t, all)
}

/// Append raw results to a CSV file under `target/bench-results/`.
pub fn write_results_csv(name: &str, results: &[EigenbenchResult]) -> std::io::Result<String> {
    let dir = std::path::Path::new("target/bench-results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.csv"));
    let mut out = String::from(
        "framework,label,throughput_ops_s,committed_txns,committed_ops,aborts,abort_rate,wall_ms,sim_ms,wait_p50_us,wait_p99_us\n",
    );
    for r in results {
        out.push_str(&r.csv_row());
        out.push('\n');
    }
    std::fs::write(&path, out)?;
    Ok(path.display().to_string())
}

/// Write a sweep's results as `target/bench-results/BENCH_<name>.json`
/// (one [`crate::bench::BenchEntry`] per scenario, named
/// `<framework>/<params_label>`). Returns the written path.
pub fn write_results_json(
    name: &str,
    scale: Scale,
    results: &[EigenbenchResult],
) -> std::io::Result<String> {
    let mut report = BenchReport::new(name).config("scale", format!("{scale:?}"));
    for r in results {
        let entry_name = format!("{}/{}", r.framework, r.params_label);
        report.push(r.bench_entry(entry_name));
    }
    let path = report.write_to(&crate::bench::default_output_dir())?;
    Ok(path.display().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig13_reports_zero_aborts_for_pessimistic() {
        let (table, results) = fig13(Scale::Quick);
        assert!(!table.is_empty());
        for r in results {
            if r.framework.contains("OptSVA") || r.framework.contains("SVA") {
                assert_eq!(r.abort_rate, 0.0, "{}", r.framework);
            }
        }
    }

    #[test]
    fn csv_writer_produces_file() {
        let (_, results) = fig13(Scale::Quick);
        let path = write_results_csv("test_fig13", &results).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() > 1);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn json_writer_produces_parseable_report() {
        let (_, results) = fig13(Scale::Quick);
        let path = write_results_json("test_fig13_json", Scale::Quick, &results).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let report = BenchReport::parse(&text).unwrap();
        assert_eq!(report.bench, "test_fig13_json");
        assert_eq!(report.entries.len(), results.len());
        assert!(report.config.iter().any(|(k, v)| k == "scale" && v == "Quick"));
        for (r, e) in results.iter().zip(&report.entries) {
            assert!(e.name.starts_with(r.framework));
            assert_eq!(e.get("committed_txns"), Some(r.committed_txns as f64));
        }
        let _ = std::fs::remove_file(path);
    }
}
