//! Paper-figure sweep drivers (§4.3): one function per table/figure,
//! shared by the `cargo bench` targets and the CLI (`atomic-rmi2 sweep`).
//!
//! The paper ran on a 16-node 1 GbE cluster with ~3 ms operations; the
//! sweeps below run the same *structure* scaled to one box (see DESIGN.md
//! §2 and §5): 2–8 simulated nodes, 0.8 ms operations, LAN-model latency.
//! Absolute throughput differs from the paper's; the comparisons —
//! who wins, by roughly what factor, where the crossovers are — are what
//! the harness regenerates.
//!
//! All sweeps run in **virtual time** ([`EigenbenchParams::virtual_time`],
//! on by default): injected operation and network latency is accounted on
//! a [`crate::clock::VirtualClock`], so regenerating a figure costs
//! seconds of CPU instead of minutes of sleeping, and throughput is
//! reported against simulated elapsed time.

use super::eigenbench::{run_eigenbench, EigenbenchParams, EigenbenchResult};
use super::frameworks::FrameworkKind;
use super::megascale::{run_megascale, MegascaleParams, MegascaleResult};
use crate::bench::{BenchEntry, BenchReport};
use crate::metrics::{fmt_throughput, Table};
use crate::NetworkModel;
use std::time::Duration;

/// Scale factor for sweep duration: `quick` runs a fraction of the work
/// for smoke-testing; full runs regenerate the figures properly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Smoke-test fraction of the sweep (CI default, `ARMI2_BENCH_QUICK`).
    Quick,
    /// The full figure-regenerating sweep.
    Full,
}

impl Scale {
    fn txns(&self) -> u32 {
        match self {
            Scale::Quick => 2,
            Scale::Full => 6,
        }
    }

    fn op_delay(&self) -> Duration {
        match self {
            Scale::Quick => Duration::from_micros(100),
            Scale::Full => Duration::from_micros(800),
        }
    }
}

/// The frameworks each figure compares (paper §4.1 set).
pub const FIGURE_FRAMEWORKS: &[FrameworkKind] = &[
    FrameworkKind::Optsva,
    FrameworkKind::Sva,
    FrameworkKind::Tfa,
    FrameworkKind::MutexS2pl,
    FrameworkKind::Mutex2pl,
    FrameworkKind::RwS2pl,
    FrameworkKind::Rw2pl,
    FrameworkKind::GLock,
];

/// The paper's three read percentages (9÷1, 5÷5, 1÷9 ratios).
pub const RATIOS: &[u8] = &[90, 50, 10];

fn base(scale: Scale) -> EigenbenchParams {
    EigenbenchParams {
        txns_per_client: scale.txns(),
        hot_ops: 10,
        op_delay: scale.op_delay(),
        net: NetworkModel::lan(),
        locality: 0.5,
        history: 5,
        ..Default::default()
    }
}

/// Fig 10: throughput vs client count (contention sweep), 3 ratios.
/// Paper: 16 nodes, 64→1024 clients; here 4 nodes, 8→64 clients.
pub fn fig10(scale: Scale) -> (Vec<Table>, Vec<EigenbenchResult>) {
    let clients_per_node: &[u32] = match scale {
        Scale::Quick => &[2, 4],
        Scale::Full => &[2, 4, 8, 16],
    };
    let mut tables = Vec::new();
    let mut all = Vec::new();
    for &read_pct in RATIOS {
        let mut t = Table::new(
            format!(
                "Fig 10 ({}÷{}): throughput [ops/s] vs clients, 4 nodes, 10 arrays/node",
                read_pct / 10,
                10 - read_pct / 10
            ),
            &std::iter::once("framework")
                .chain(clients_per_node.iter().map(|c| {
                    Box::leak(format!("{}cl", c * 4).into_boxed_str()) as &str
                }))
                .collect::<Vec<_>>(),
        );
        for &kind in FIGURE_FRAMEWORKS {
            let mut row = vec![kind.label().to_string()];
            for &cpn in clients_per_node {
                let r = run_eigenbench(&EigenbenchParams {
                    kind,
                    nodes: 4,
                    clients_per_node: cpn,
                    arrays_per_node: 10,
                    read_pct,
                    ..base(scale)
                });
                row.push(fmt_throughput(r.throughput));
                all.push(r);
            }
            t.add_row(row);
        }
        tables.push(t);
    }
    (tables, all)
}

/// Figs 11a–c: throughput vs node count at constant per-node load,
/// 5 and 10 arrays/node (higher and lower contention).
/// Paper: 4→16 nodes, 16 clients/node; here 2→8 nodes, 4 clients/node.
pub fn fig11(scale: Scale) -> (Vec<Table>, Vec<EigenbenchResult>) {
    fig_nodes(scale, 0, "Fig 11")
}

/// Fig 12: as Fig 11 but each transaction adds 10 mild-array operations
/// (conflict-free), lowering average contention.
pub fn fig12(scale: Scale) -> (Vec<Table>, Vec<EigenbenchResult>) {
    fig_nodes(scale, 10, "Fig 12")
}

fn fig_nodes(scale: Scale, mild_ops: u32, tag: &str) -> (Vec<Table>, Vec<EigenbenchResult>) {
    let nodes: &[u16] = match scale {
        Scale::Quick => &[2, 4],
        Scale::Full => &[2, 4, 8],
    };
    let arrays: &[u32] = if mild_ops == 0 { &[5, 10] } else { &[10] };
    let mut tables = Vec::new();
    let mut all = Vec::new();
    for &arrays_per_node in arrays {
        for &read_pct in RATIOS {
            let mut t = Table::new(
                format!(
                    "{tag} ({}÷{}, {arrays_per_node} arrays/node{}): throughput [ops/s] vs nodes",
                    read_pct / 10,
                    10 - read_pct / 10,
                    if mild_ops > 0 { ", +10 mild ops" } else { "" },
                ),
                &std::iter::once("framework")
                    .chain(nodes.iter().map(|n| {
                        Box::leak(format!("{n}n").into_boxed_str()) as &str
                    }))
                    .collect::<Vec<_>>(),
            );
            for &kind in FIGURE_FRAMEWORKS {
                let mut row = vec![kind.label().to_string()];
                for &n in nodes {
                    let r = run_eigenbench(&EigenbenchParams {
                        kind,
                        nodes: n,
                        clients_per_node: 4,
                        arrays_per_node,
                        mild_ops,
                        read_pct,
                        ..base(scale)
                    });
                    row.push(fmt_throughput(r.throughput));
                    all.push(r);
                }
                t.add_row(row);
            }
            tables.push(t);
        }
    }
    (tables, all)
}

/// Fig 13: abort-rate table — fraction of transactions that abort and
/// retry at least once, per client count, for TFA (HyFlow2) vs the
/// pessimistic frameworks (which must stay at exactly 0).
pub fn fig13(scale: Scale) -> (Table, Vec<EigenbenchResult>) {
    let clients_per_node: &[u32] = match scale {
        Scale::Quick => &[2, 4],
        Scale::Full => &[2, 4, 8, 16],
    };
    let mut t = Table::new(
        "Fig 13: % transactions aborted ≥once (5÷5 ratio) vs clients",
        &std::iter::once("framework")
            .chain(clients_per_node.iter().map(|c| {
                Box::leak(format!("{}cl", c * 4).into_boxed_str()) as &str
            }))
            .collect::<Vec<_>>(),
    );
    let mut all = Vec::new();
    for kind in [FrameworkKind::Tfa, FrameworkKind::Optsva, FrameworkKind::Sva] {
        let mut row = vec![kind.label().to_string()];
        for &cpn in clients_per_node {
            let r = run_eigenbench(&EigenbenchParams {
                kind,
                nodes: 4,
                clients_per_node: cpn,
                arrays_per_node: 10,
                read_pct: 50,
                ..base(scale)
            });
            row.push(format!("{:.0}%", r.abort_rate * 100.0));
            all.push(r);
        }
        t.add_row(row);
    }
    (t, all)
}

/// Fig 11 extended: throughput vs node count pushed 10–100× past the
/// paper's 16 nodes (10⁵–10⁶ simulated clients at 1000 clients/node),
/// run on the megascale discrete-event engine
/// ([`crate::workload::megascale`]) over the same sharded transport the
/// blocking frameworks use. The global hot set is a *fixed* size as
/// nodes scale, so aggregate throughput rises with node count until the
/// hot objects' service capacity saturates and the curve flattens —
/// [`flattening_point`] records where.
pub fn fig11_extended(scale: Scale) -> (Table, Vec<MegascaleResult>) {
    let (nodes, txns): (&[u16], u32) = match scale {
        // Quick already crosses the acceptance floor: 200 nodes ×
        // 1000 clients/node = 2×10⁵ simulated clients.
        Scale::Quick => (&[25, 50, 100, 200], 1),
        Scale::Full => (&[25, 50, 100, 250, 500, 1000], 2),
    };
    let mut t = Table::new(
        "Fig 11 ext: megascale throughput [ops/s] vs nodes, 1000 clients/node",
        &["nodes", "clients", "ops/s", "sim_ms", "wall_ms", "msgs", "batch"],
    );
    let mut all = Vec::new();
    for &n in nodes {
        let r = run_megascale(&MegascaleParams {
            nodes: n,
            txns_per_client: txns,
            ..Default::default()
        });
        t.add_row(vec![
            format!("{n}"),
            format!("{}", r.clients),
            fmt_throughput(r.throughput),
            format!("{}", r.sim.as_millis()),
            format!("{}", r.wall.as_millis()),
            format!("{}", r.messages),
            format!("{:.1}", r.batch_factor),
        ]);
        all.push(r);
    }
    (t, all)
}

/// Where the megascale curve flattens: the first node count whose
/// throughput gain over the previous point is below 10 %, and the peak
/// throughput of the sweep. Falls back to the last point when the curve
/// is still climbing at the end of the range.
pub fn flattening_point(results: &[MegascaleResult]) -> (u16, f64) {
    let peak = results.iter().map(|r| r.throughput).fold(0.0f64, f64::max);
    for w in results.windows(2) {
        if w[1].throughput < w[0].throughput * 1.10 {
            return (w[1].nodes, peak);
        }
    }
    (results.last().map(|r| r.nodes).unwrap_or(0), peak)
}

/// Write a megascale sweep as `target/bench-results/BENCH_<name>.json`:
/// one entry per node count plus a `flattening` entry recording the
/// [`flattening_point`]. Returns the written path.
pub fn write_megascale_json(
    name: &str,
    scale: Scale,
    results: &[MegascaleResult],
) -> std::io::Result<String> {
    let mut report = BenchReport::new(name).config("scale", format!("{scale:?}"));
    for r in results {
        report.push(
            BenchEntry::new(format!("megascale/{}n", r.nodes))
                .metric("throughput_ops_s", r.throughput)
                .metric("clients", r.clients as f64)
                .metric("committed_txns", r.committed_txns as f64)
                .metric("committed_ops", r.committed_ops as f64)
                .metric("sim_ms", r.sim.as_secs_f64() * 1e3)
                .metric("wall_ms", r.wall.as_secs_f64() * 1e3)
                .metric("messages", r.messages as f64)
                .metric("batch_factor", r.batch_factor),
        );
    }
    let (flat_nodes, peak) = flattening_point(results);
    report.push(
        BenchEntry::new("flattening")
            .metric("flatten_nodes", flat_nodes as f64)
            .metric("peak_ops_s", peak),
    );
    let path = report.write_to(&crate::bench::default_output_dir())?;
    Ok(path.display().to_string())
}

/// Append raw results to a CSV file under `target/bench-results/`.
pub fn write_results_csv(name: &str, results: &[EigenbenchResult]) -> std::io::Result<String> {
    let dir = std::path::Path::new("target/bench-results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.csv"));
    let mut out = String::from(
        "framework,label,throughput_ops_s,committed_txns,committed_ops,aborts,abort_rate,wall_ms,sim_ms,wait_p50_us,wait_p99_us\n",
    );
    for r in results {
        out.push_str(&r.csv_row());
        out.push('\n');
    }
    std::fs::write(&path, out)?;
    Ok(path.display().to_string())
}

/// Write a sweep's results as `target/bench-results/BENCH_<name>.json`
/// (one [`crate::bench::BenchEntry`] per scenario, named
/// `<framework>/<params_label>`). Returns the written path.
pub fn write_results_json(
    name: &str,
    scale: Scale,
    results: &[EigenbenchResult],
) -> std::io::Result<String> {
    let mut report = BenchReport::new(name).config("scale", format!("{scale:?}"));
    for r in results {
        let entry_name = format!("{}/{}", r.framework, r.params_label);
        report.push(r.bench_entry(entry_name));
    }
    let path = report.write_to(&crate::bench::default_output_dir())?;
    Ok(path.display().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig13_reports_zero_aborts_for_pessimistic() {
        let (table, results) = fig13(Scale::Quick);
        assert!(!table.is_empty());
        for r in results {
            if r.framework.contains("OptSVA") || r.framework.contains("SVA") {
                assert_eq!(r.abort_rate, 0.0, "{}", r.framework);
            }
        }
    }

    #[test]
    fn csv_writer_produces_file() {
        let (_, results) = fig13(Scale::Quick);
        let path = write_results_csv("test_fig13", &results).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() > 1);
        let _ = std::fs::remove_file(path);
    }

    fn mega(nodes: u16, throughput: f64) -> MegascaleResult {
        MegascaleResult {
            nodes,
            clients: nodes as u64 * 1000,
            committed_txns: 1,
            committed_ops: 4,
            sim: Duration::from_secs(1),
            wall: Duration::from_millis(5),
            throughput,
            messages: 8,
            batch_factor: 1.5,
        }
    }

    #[test]
    fn flattening_point_finds_first_sub_10pct_gain() {
        let rising = [mega(25, 100.0), mega(50, 200.0), mega(100, 400.0)];
        assert_eq!(flattening_point(&rising), (100, 400.0), "still climbing: last point");
        let flat = [mega(25, 100.0), mega(50, 200.0), mega(100, 210.0), mega(200, 215.0)];
        assert_eq!(flattening_point(&flat).0, 100, "first <10% marginal gain");
        assert_eq!(flattening_point(&flat).1, 215.0, "peak is the max, not the knee");
    }

    #[test]
    fn megascale_json_records_flattening_entry() {
        let results = [mega(25, 100.0), mega(50, 105.0)];
        let path = write_megascale_json("test_fig11ext", Scale::Quick, &results).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let report = BenchReport::parse(&text).unwrap();
        assert_eq!(report.entries.len(), 3, "one per node count + flattening");
        let flat = report.entries.iter().find(|e| e.name == "flattening").unwrap();
        assert_eq!(flat.get("flatten_nodes"), Some(50.0));
        assert_eq!(flat.get("peak_ops_s"), Some(105.0));
        let first = report.entries.iter().find(|e| e.name == "megascale/25n").unwrap();
        assert_eq!(first.get("clients"), Some(25_000.0));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn json_writer_produces_parseable_report() {
        let (_, results) = fig13(Scale::Quick);
        let path = write_results_json("test_fig13_json", Scale::Quick, &results).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let report = BenchReport::parse(&text).unwrap();
        assert_eq!(report.bench, "test_fig13_json");
        assert_eq!(report.entries.len(), results.len());
        assert!(report.config.iter().any(|(k, v)| k == "scale" && v == "Quick"));
        for (r, e) in results.iter().zip(&report.entries) {
            assert!(e.name.starts_with(r.framework));
            assert_eq!(e.get("committed_txns"), Some(r.committed_txns as f64));
        }
        let _ = std::fs::remove_file(path);
    }
}
