//! Framework registry: every concurrency-control system the paper
//! evaluates (§4.1), behind one constructor and the common [`Dtm`] trait.

use crate::api::Dtm;
use crate::cluster::{Cluster, NodeId, Oid};
use crate::locks::{Discipline, LockKind, LockSystem};
use crate::object::SharedObject;
use crate::optsva::{AtomicRmi2, OptsvaConfig};
use crate::sva::AtomicRmi1;
use crate::tfa::TfaSystem;
use std::sync::Arc;

/// Which framework to build (paper §4.1 names in comments).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FrameworkKind {
    /// Atomic RMI 2 — OptSVA-CF (the paper's contribution).
    Optsva,
    /// Ablation: OptSVA-CF with asynchronous tasks executed inline.
    OptsvaNoAsync,
    /// Atomic RMI — SVA, operation-type agnostic.
    Sva,
    /// HyFlow2 stand-in — optimistic TFA, data-flow.
    Tfa,
    /// Distributed mutual-exclusion locks, conservative strict 2PL.
    MutexS2pl,
    /// Distributed mutual-exclusion locks, early unlock after last use.
    Mutex2pl,
    /// Distributed readers–writer locks, S2PL.
    RwS2pl,
    /// Distributed readers–writer locks, 2PL.
    Rw2pl,
    /// Single global lock — the serial baseline.
    GLock,
}

/// Every framework, in the order the paper's plots list them.
pub const ALL_FRAMEWORKS: &[FrameworkKind] = &[
    FrameworkKind::Optsva,
    FrameworkKind::Sva,
    FrameworkKind::Tfa,
    FrameworkKind::MutexS2pl,
    FrameworkKind::Mutex2pl,
    FrameworkKind::RwS2pl,
    FrameworkKind::Rw2pl,
    FrameworkKind::GLock,
];

impl FrameworkKind {
    /// Short stable label (CSV columns, CLI flags).
    pub fn label(&self) -> &'static str {
        match self {
            FrameworkKind::Optsva => "atomic-rmi2",
            FrameworkKind::OptsvaNoAsync => "atomic-rmi2-sync",
            FrameworkKind::Sva => "atomic-rmi",
            FrameworkKind::Tfa => "hyflow2",
            FrameworkKind::MutexS2pl => "mutex-s2pl",
            FrameworkKind::Mutex2pl => "mutex-2pl",
            FrameworkKind::RwS2pl => "rw-s2pl",
            FrameworkKind::Rw2pl => "rw-2pl",
            FrameworkKind::GLock => "glock",
        }
    }

    /// Parse a CLI label.
    pub fn parse(s: &str) -> Option<FrameworkKind> {
        let all = [
            FrameworkKind::Optsva,
            FrameworkKind::OptsvaNoAsync,
            FrameworkKind::Sva,
            FrameworkKind::Tfa,
            FrameworkKind::MutexS2pl,
            FrameworkKind::Mutex2pl,
            FrameworkKind::RwS2pl,
            FrameworkKind::Rw2pl,
            FrameworkKind::GLock,
        ];
        all.into_iter().find(|k| k.label() == s)
    }

    /// Build an instance over `cluster`.
    pub fn build(&self, cluster: Arc<Cluster>) -> Framework {
        match self {
            FrameworkKind::Optsva => Framework::Optsva(AtomicRmi2::new(cluster)),
            FrameworkKind::OptsvaNoAsync => Framework::Optsva(AtomicRmi2::with_config(
                cluster,
                OptsvaConfig { asynchrony: false, ..OptsvaConfig::default() },
            )),
            FrameworkKind::Sva => Framework::Sva(AtomicRmi1::new(cluster)),
            FrameworkKind::Tfa => Framework::Tfa(TfaSystem::new(cluster)),
            FrameworkKind::MutexS2pl => {
                Framework::Locks(LockSystem::new(cluster, LockKind::Mutex, Discipline::S2pl))
            }
            FrameworkKind::Mutex2pl => {
                Framework::Locks(LockSystem::new(cluster, LockKind::Mutex, Discipline::Tpl))
            }
            FrameworkKind::RwS2pl => {
                Framework::Locks(LockSystem::new(cluster, LockKind::ReadWrite, Discipline::S2pl))
            }
            FrameworkKind::Rw2pl => {
                Framework::Locks(LockSystem::new(cluster, LockKind::ReadWrite, Discipline::Tpl))
            }
            FrameworkKind::GLock => {
                Framework::Locks(LockSystem::new(cluster, LockKind::Global, Discipline::S2pl))
            }
        }
    }
}

/// A built framework instance: hosts objects and runs transactions.
pub enum Framework {
    /// OptSVA-CF / Atomic RMI 2 (the paper's contribution).
    Optsva(Arc<AtomicRmi2>),
    /// SVA / Atomic RMI 1 baseline.
    Sva(Arc<AtomicRmi1>),
    /// Transaction Forwarding (HyFlow2 stand-in).
    Tfa(Arc<TfaSystem>),
    /// A distributed-lock baseline (mutex/R-W × S2PL/2PL, or global).
    Locks(Arc<LockSystem>),
}

impl Framework {
    /// Host `object` on `node` under `name`.
    pub fn host(&self, node: NodeId, name: &str, object: Box<dyn SharedObject>) -> Oid {
        match self {
            Framework::Optsva(s) => s.host(node, name, object),
            Framework::Sva(s) => s.host(node, name, object),
            Framework::Tfa(s) => s.host(node, name, object),
            Framework::Locks(s) => s.host(node, name, object),
        }
    }

    /// The polymorphic transaction runner.
    pub fn dtm(&self) -> &dyn Dtm {
        match self {
            Framework::Optsva(s) => s,
            Framework::Sva(s) => s,
            Framework::Tfa(s) => s,
            Framework::Locks(s) => s,
        }
    }

    /// Peek at an object's state (test/verification helper).
    pub fn with_object<R>(
        &self,
        oid: Oid,
        f: impl FnOnce(&dyn SharedObject) -> R,
    ) -> R {
        match self {
            Framework::Optsva(s) => s.with_object(oid, f),
            Framework::Sva(s) => s.with_object(oid, f),
            Framework::Tfa(s) => s.with_object(oid, f),
            Framework::Locks(s) => s.with_object(oid, f),
        }
    }

    /// Drain executors and background machinery (OptSVA-CF only).
    pub fn shutdown(&self) {
        if let Framework::Optsva(s) = self {
            s.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{AccessDecl, ObjHandle, Suprema, TxCtx};
    use crate::cluster::NetworkModel;
    use crate::object::{account::ops, Account};

    #[test]
    fn labels_roundtrip() {
        for k in ALL_FRAMEWORKS {
            assert_eq!(FrameworkKind::parse(k.label()), Some(*k));
        }
        assert_eq!(FrameworkKind::parse("atomic-rmi2-sync"), Some(FrameworkKind::OptsvaNoAsync));
        assert_eq!(FrameworkKind::parse("nope"), None);
    }

    #[test]
    fn every_framework_runs_the_same_transfer() {
        for kind in ALL_FRAMEWORKS.iter().chain([&FrameworkKind::OptsvaNoAsync]) {
            let cluster = Arc::new(Cluster::new(2, NetworkModel::instant()));
            let fw = kind.build(cluster);
            let a = fw.host(NodeId(0), "A", Box::new(Account::with_balance(100)));
            let b = fw.host(NodeId(1), "B", Box::new(Account::with_balance(0)));
            let decls = vec![
                AccessDecl::new("A", Suprema::updates(1)),
                AccessDecl::new("B", Suprema::updates(1)),
            ];
            fw.dtm()
                .tx(NodeId(0))
                .with_decls(&decls)
                .run(|t| {
                    t.call(ObjHandle(0), ops::withdraw(40))?;
                    t.call(ObjHandle(1), ops::deposit(40))?;
                    Ok(())
                })
                .unwrap_or_else(|e| panic!("{}: {e}", kind.label()));
            assert_eq!(
                fw.with_object(a, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()),
                60,
                "{}",
                kind.label()
            );
            assert_eq!(
                fw.with_object(b, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()),
                40,
                "{}",
                kind.label()
            );
            assert_eq!(fw.dtm().commits(), 1, "{}", kind.label());
            fw.shutdown();
        }
    }
}
