//! Typed object facades over [`ObjHandle`] — the statically typed face of
//! the dynamically typed CF object model (paper §2.5, Fig 7).
//!
//! A facade binds a declared handle to an object interface, so transaction
//! bodies call `account.deposit(t, 100)?` instead of hand-rolling
//! `OpCall`/`Value` casts. Results go through the fallible `try_*`
//! accessors, so an interface mismatch surfaces as
//! [`TxError::Object`](crate::api::TxError) instead of a panic.
//!
//! Every mutating method also has an `*_async` variant returning an
//! [`OpFuture`] (the §2.6 buffered-write / §2.8 asynchronous-dispatch
//! path); the plain variants block like classic RMI stubs.

use crate::api::{ObjHandle, OpFuture, TxCtx, TxError};
use crate::object::{OpCall, Value};

/// Interpret a `Value` that may be `Unit` (absent) as `Option<i64>`.
fn opt_int(v: Value) -> Result<Option<i64>, TxError> {
    match v {
        Value::Unit => Ok(None),
        other => Ok(Some(other.try_int()?)),
    }
}

/// Facade over the paper's `Account` interface (Fig 7).
#[derive(Debug, Clone, Copy)]
pub struct AccountRef(pub ObjHandle);

impl AccountRef {
    /// Bind the facade to a declared handle, e.g.
    /// `AccountRef::new(tx.accesses("A", Suprema::updates(2)))`.
    pub fn new(h: ObjHandle) -> Self {
        AccountRef(h)
    }

    /// The underlying declared handle.
    pub fn handle(&self) -> ObjHandle {
        self.0
    }

    /// READ `balance()`.
    pub fn balance(&self, t: &mut dyn TxCtx) -> Result<i64, TxError> {
        Ok(t.call(self.0, OpCall::nullary("balance"))?.try_int()?)
    }

    /// UPDATE `deposit(amount)`.
    pub fn deposit(&self, t: &mut dyn TxCtx, amount: i64) -> Result<(), TxError> {
        t.call(self.0, OpCall::unary("deposit", amount)).map(|_| ())
    }

    /// UPDATE `withdraw(amount)`.
    pub fn withdraw(&self, t: &mut dyn TxCtx, amount: i64) -> Result<(), TxError> {
        t.call(self.0, OpCall::unary("withdraw", amount)).map(|_| ())
    }

    /// WRITE `reset()` — executable on the log buffer (§2.6).
    pub fn reset(&self, t: &mut dyn TxCtx) -> Result<(), TxError> {
        t.call(self.0, OpCall::nullary("reset")).map(|_| ())
    }

    /// Asynchronous [`balance`](Self::balance): returns a future; waiting
    /// it yields the balance as a [`Value`].
    pub fn balance_async(&self, t: &mut dyn TxCtx) -> Result<OpFuture, TxError> {
        t.submit(self.0, OpCall::nullary("balance"))
    }

    /// Asynchronous [`deposit`](Self::deposit); per-object program order
    /// is preserved relative to other operations on this handle.
    pub fn deposit_async(&self, t: &mut dyn TxCtx, amount: i64) -> Result<OpFuture, TxError> {
        t.submit(self.0, OpCall::unary("deposit", amount))
    }

    /// Asynchronous [`withdraw`](Self::withdraw).
    pub fn withdraw_async(&self, t: &mut dyn TxCtx, amount: i64) -> Result<OpFuture, TxError> {
        t.submit(self.0, OpCall::unary("withdraw", amount))
    }
}

impl From<ObjHandle> for AccountRef {
    fn from(h: ObjHandle) -> Self {
        AccountRef(h)
    }
}

/// Facade over [`crate::object::Counter`].
#[derive(Debug, Clone, Copy)]
pub struct CounterRef(pub ObjHandle);

impl CounterRef {
    /// Bind the facade to a declared handle.
    pub fn new(h: ObjHandle) -> Self {
        CounterRef(h)
    }

    /// READ `get()`.
    pub fn get(&self, t: &mut dyn TxCtx) -> Result<i64, TxError> {
        Ok(t.call(self.0, OpCall::nullary("get"))?.try_int()?)
    }

    /// UPDATE `inc(by)`: returns the new count.
    pub fn inc(&self, t: &mut dyn TxCtx, by: i64) -> Result<i64, TxError> {
        Ok(t.call(self.0, OpCall::unary("inc", by))?.try_int()?)
    }

    /// WRITE `zero()`.
    pub fn zero(&self, t: &mut dyn TxCtx) -> Result<(), TxError> {
        t.call(self.0, OpCall::nullary("zero")).map(|_| ())
    }

    /// Asynchronous [`inc`](Self::inc); the future yields the new count.
    pub fn inc_async(&self, t: &mut dyn TxCtx, by: i64) -> Result<OpFuture, TxError> {
        t.submit(self.0, OpCall::unary("inc", by))
    }
}

impl From<ObjHandle> for CounterRef {
    fn from(h: ObjHandle) -> Self {
        CounterRef(h)
    }
}

/// Facade over [`crate::object::RegisterObject`] (the Eigenbench cell).
#[derive(Debug, Clone, Copy)]
pub struct RegisterRef(pub ObjHandle);

impl RegisterRef {
    /// Bind the facade to a declared handle.
    pub fn new(h: ObjHandle) -> Self {
        RegisterRef(h)
    }

    /// READ `get()`.
    pub fn get(&self, t: &mut dyn TxCtx) -> Result<i64, TxError> {
        Ok(t.call(self.0, OpCall::nullary("get"))?.try_int()?)
    }

    /// WRITE `set(v)` — executable on the log buffer (§2.6).
    pub fn set(&self, t: &mut dyn TxCtx, v: i64) -> Result<(), TxError> {
        t.call(self.0, OpCall::unary("set", v)).map(|_| ())
    }

    /// UPDATE `add(delta)`: returns the new value.
    pub fn add(&self, t: &mut dyn TxCtx, delta: i64) -> Result<i64, TxError> {
        Ok(t.call(self.0, OpCall::unary("add", delta))?.try_int()?)
    }

    /// Asynchronous [`get`](Self::get).
    pub fn get_async(&self, t: &mut dyn TxCtx) -> Result<OpFuture, TxError> {
        t.submit(self.0, OpCall::nullary("get"))
    }

    /// Asynchronous [`set`](Self::set) — a pure write: the future is
    /// satisfied from the log buffer with no synchronization (§2.6).
    pub fn set_async(&self, t: &mut dyn TxCtx, v: i64) -> Result<OpFuture, TxError> {
        t.submit(self.0, OpCall::unary("set", v))
    }

    /// Asynchronous [`add`](Self::add); the future yields the new value.
    pub fn add_async(&self, t: &mut dyn TxCtx, delta: i64) -> Result<OpFuture, TxError> {
        t.submit(self.0, OpCall::unary("add", delta))
    }
}

impl From<ObjHandle> for RegisterRef {
    fn from(h: ObjHandle) -> Self {
        RegisterRef(h)
    }
}

/// Facade over [`crate::object::KvStore`] (the §2.9 composite object).
#[derive(Debug, Clone, Copy)]
pub struct KvRef(pub ObjHandle);

impl KvRef {
    /// Bind the facade to a declared handle.
    pub fn new(h: ObjHandle) -> Self {
        KvRef(h)
    }

    /// READ `get(key)`: `None` if absent.
    pub fn get(&self, t: &mut dyn TxCtx, key: &str) -> Result<Option<i64>, TxError> {
        opt_int(t.call(self.0, OpCall::unary("get", key))?)
    }

    /// READ `contains(key)`.
    pub fn contains(&self, t: &mut dyn TxCtx, key: &str) -> Result<bool, TxError> {
        Ok(t.call(self.0, OpCall::unary("contains", key))?.try_bool()?)
    }

    /// READ `size()`.
    pub fn size(&self, t: &mut dyn TxCtx) -> Result<i64, TxError> {
        Ok(t.call(self.0, OpCall::nullary("size"))?.try_int()?)
    }

    /// WRITE `put(key, v)` — blind overwrite, log-buffer executable.
    pub fn put(&self, t: &mut dyn TxCtx, key: &str, v: i64) -> Result<(), TxError> {
        t.call(self.0, OpCall::new("put", vec![Value::from(key), Value::from(v)]))
            .map(|_| ())
    }

    /// WRITE `clear()`.
    pub fn clear(&self, t: &mut dyn TxCtx) -> Result<(), TxError> {
        t.call(self.0, OpCall::nullary("clear")).map(|_| ())
    }

    /// UPDATE `remove(key)`: the removed value, if any.
    pub fn remove(&self, t: &mut dyn TxCtx, key: &str) -> Result<Option<i64>, TxError> {
        opt_int(t.call(self.0, OpCall::unary("remove", key))?)
    }

    /// UPDATE `merge_add(key, delta)`: the merged value.
    pub fn merge_add(&self, t: &mut dyn TxCtx, key: &str, delta: i64) -> Result<i64, TxError> {
        Ok(t
            .call(self.0, OpCall::new("merge_add", vec![Value::from(key), Value::from(delta)]))?
            .try_int()?)
    }

    /// Asynchronous [`put`](Self::put) — a pure write, log-buffer
    /// executable with no synchronization (§2.6).
    pub fn put_async(&self, t: &mut dyn TxCtx, key: &str, v: i64) -> Result<OpFuture, TxError> {
        t.submit(self.0, OpCall::new("put", vec![Value::from(key), Value::from(v)]))
    }
}

impl From<ObjHandle> for KvRef {
    fn from(h: ObjHandle) -> Self {
        KvRef(h)
    }
}

/// Facade over [`crate::object::QueueObject`].
#[derive(Debug, Clone, Copy)]
pub struct QueueRef(pub ObjHandle);

impl QueueRef {
    /// Bind the facade to a declared handle.
    pub fn new(h: ObjHandle) -> Self {
        QueueRef(h)
    }

    /// READ `peek()`: front element, if any.
    pub fn peek(&self, t: &mut dyn TxCtx) -> Result<Option<i64>, TxError> {
        opt_int(t.call(self.0, OpCall::nullary("peek"))?)
    }

    /// READ `len()`.
    pub fn len(&self, t: &mut dyn TxCtx) -> Result<i64, TxError> {
        Ok(t.call(self.0, OpCall::nullary("len"))?.try_int()?)
    }

    /// WRITE `push(v)` — log-buffer executable (§2.6).
    pub fn push(&self, t: &mut dyn TxCtx, v: i64) -> Result<(), TxError> {
        t.call(self.0, OpCall::unary("push", v)).map(|_| ())
    }

    /// UPDATE `pop()`: front element, if any.
    pub fn pop(&self, t: &mut dyn TxCtx) -> Result<Option<i64>, TxError> {
        opt_int(t.call(self.0, OpCall::nullary("pop"))?)
    }

    /// Asynchronous [`push`](Self::push) — a pure write, log-buffer
    /// executable with no synchronization (§2.6).
    pub fn push_async(&self, t: &mut dyn TxCtx, v: i64) -> Result<OpFuture, TxError> {
        t.submit(self.0, OpCall::unary("push", v))
    }
}

impl From<ObjHandle> for QueueRef {
    fn from(h: ObjHandle) -> Self {
        QueueRef(h)
    }
}

/// Facade over [`crate::object::ComputeObject`] (CF compute delegation).
#[derive(Debug, Clone, Copy)]
pub struct ComputeRef(pub ObjHandle);

impl ComputeRef {
    /// Bind the facade to a declared handle.
    pub fn new(h: ObjHandle) -> Self {
        ComputeRef(h)
    }

    /// READ `digest()`.
    pub fn digest(&self, t: &mut dyn TxCtx) -> Result<f64, TxError> {
        Ok(t.call(self.0, OpCall::nullary("digest"))?.try_float()?)
    }

    /// READ `dim()`.
    pub fn dim(&self, t: &mut dyn TxCtx) -> Result<i64, TxError> {
        Ok(t.call(self.0, OpCall::nullary("dim"))?.try_int()?)
    }

    /// WRITE `load(state)` — blind state replacement.
    pub fn load(&self, t: &mut dyn TxCtx, state: Vec<f32>) -> Result<(), TxError> {
        t.call(self.0, OpCall::unary("load", state)).map(|_| ())
    }

    /// UPDATE `mix(params)` — runs the kernel on the home node.
    pub fn mix(&self, t: &mut dyn TxCtx, params: Vec<f32>) -> Result<(), TxError> {
        t.call(self.0, OpCall::unary("mix", params)).map(|_| ())
    }

    /// Asynchronous [`mix`](Self::mix): the kernel still runs on the
    /// object's home node; only the caller stops blocking on it.
    pub fn mix_async(&self, t: &mut dyn TxCtx, params: Vec<f32>) -> Result<OpFuture, TxError> {
        t.submit(self.0, OpCall::unary("mix", params))
    }
}

impl From<ObjHandle> for ComputeRef {
    fn from(h: ObjHandle) -> Self {
        ComputeRef(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Suprema, TxError};
    use crate::cluster::{Cluster, NetworkModel, NodeId};
    use crate::object::{Account, KvStore, ObjectError, QueueObject};
    use crate::optsva::AtomicRmi2;
    use std::sync::Arc;

    fn sys() -> Arc<AtomicRmi2> {
        AtomicRmi2::new(Arc::new(Cluster::new(1, NetworkModel::instant())))
    }

    #[test]
    fn account_facade_round_trip() {
        let sys = sys();
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(100)));
        let mut tx = sys.tx(NodeId(0));
        let acct = AccountRef::new(tx.accesses("A", Suprema::new(1, 0, 2)));
        let (seen, _) = tx
            .run(|t| {
                acct.deposit(t, 50)?;
                acct.withdraw(t, 30)?;
                acct.balance(t)
            })
            .unwrap();
        assert_eq!(seen, 120);
        sys.shutdown();
    }

    #[test]
    fn kv_and_queue_facades_map_unit_to_none() {
        let sys = sys();
        sys.host(NodeId(0), "kv", Box::new(KvStore::new()));
        sys.host(NodeId(0), "q", Box::new(QueueObject::new()));
        let mut tx = sys.tx(NodeId(0));
        let kv = KvRef::new(tx.accesses("kv", Suprema::unknown()));
        let q = QueueRef::new(tx.accesses("q", Suprema::unknown()));
        let ((missing, present, popped), _) = tx
            .run(|t| {
                kv.put(t, "k", 3)?;
                let missing = kv.get(t, "nope")?;
                let present = kv.get(t, "k")?;
                q.push(t, 9)?;
                let popped = q.pop(t)?;
                Ok((missing, present, popped))
            })
            .unwrap();
        assert_eq!(missing, None);
        assert_eq!(present, Some(3));
        assert_eq!(popped, Some(9));
        sys.shutdown();
    }

    #[test]
    fn mistyped_argument_surfaces_as_object_error_not_panic() {
        let sys = sys();
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
        let mut tx = sys.tx(NodeId(0));
        let h = tx.updates("A", 1);
        tx.begin().unwrap();
        // Bypass the typed facade with a deliberately wrong argument type.
        let err = tx
            .call(h, OpCall::unary("deposit", "not a number"))
            .unwrap_err();
        assert!(
            matches!(
                err,
                TxError::Object(ObjectError::TypeMismatch { expected: "Int", .. })
            ),
            "got {err:?}"
        );
        let _ = tx.abort();
        sys.shutdown();
    }

    #[test]
    fn async_facade_variants_pipeline() {
        let sys = sys();
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
        let mut tx = sys.tx(NodeId(0));
        let acct = AccountRef::new(tx.accesses("A", Suprema::new(1, 0, 2)));
        tx.begin().unwrap();
        let f1 = acct.deposit_async(&mut tx, 2).unwrap();
        let f2 = acct.deposit_async(&mut tx, 3).unwrap();
        let f3 = acct.balance_async(&mut tx).unwrap();
        assert_eq!(f3.wait().unwrap().as_int(), 5);
        f1.wait().unwrap();
        f2.wait().unwrap();
        tx.commit().unwrap();
        sys.shutdown();
    }
}
