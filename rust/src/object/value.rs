//! Dynamic values passed to and returned from shared-object operations.
//!
//! The CF model treats objects as black boxes with arbitrary interfaces
//! (paper §2.5); method arguments and results therefore need a dynamic
//! representation analogous to Java RMI's serialized parameters.

use std::fmt;

/// A dynamically typed argument/result value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Unit,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    /// Dense float payload, used by `ComputeObject` operations.
    Floats(Vec<f32>),
    /// Heterogeneous list.
    List(Vec<Value>),
}

impl Value {
    pub fn as_int(&self) -> i64 {
        match self {
            Value::Int(v) => *v,
            Value::Bool(b) => *b as i64,
            other => panic!("expected Int, got {other:?}"),
        }
    }

    pub fn as_float(&self) -> f64 {
        match self {
            Value::Float(v) => *v,
            Value::Int(v) => *v as f64,
            other => panic!("expected Float, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> bool {
        match self {
            Value::Bool(b) => *b,
            other => panic!("expected Bool, got {other:?}"),
        }
    }

    pub fn as_str(&self) -> &str {
        match self {
            Value::Str(s) => s,
            other => panic!("expected Str, got {other:?}"),
        }
    }

    pub fn as_floats(&self) -> &[f32] {
        match self {
            Value::Floats(v) => v,
            other => panic!("expected Floats, got {other:?}"),
        }
    }

    /// Approximate serialized size in bytes: used by the network model to
    /// charge transmission cost for arguments, results, and state copies.
    pub fn wire_size(&self) -> usize {
        match self {
            Value::Unit => 1,
            Value::Bool(_) => 2,
            Value::Int(_) => 9,
            Value::Float(_) => 9,
            Value::Str(s) => 5 + s.len(),
            Value::Floats(v) => 5 + 4 * v.len(),
            Value::List(v) => 5 + v.iter().map(Value::wire_size).sum::<usize>(),
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Unit => write!(f, "()"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Int(v) => write!(f, "{v}"),
            Value::Float(v) => write!(f, "{v}"),
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Floats(v) => write!(f, "f32[{}]", v.len()),
            Value::List(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Float(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<()> for Value {
    fn from(_: ()) -> Self {
        Value::Unit
    }
}
impl From<Vec<f32>> for Value {
    fn from(v: Vec<f32>) -> Self {
        Value::Floats(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors_roundtrip() {
        assert_eq!(Value::from(5i64).as_int(), 5);
        assert_eq!(Value::from(2.5f64).as_float(), 2.5);
        assert!(Value::from(true).as_bool());
        assert_eq!(Value::from("hi").as_str(), "hi");
        assert_eq!(Value::from(vec![1.0f32]).as_floats(), &[1.0f32]);
    }

    #[test]
    #[should_panic(expected = "expected Int")]
    fn wrong_accessor_panics() {
        Value::Str("x".into()).as_int();
    }

    #[test]
    fn wire_size_scales_with_payload() {
        assert!(Value::Floats(vec![0.0; 100]).wire_size() > Value::Int(1).wire_size());
        assert_eq!(Value::Str("abc".into()).wire_size(), 8);
        let l = Value::List(vec![Value::Int(1), Value::Unit]);
        assert_eq!(l.wire_size(), 5 + 9 + 1);
    }

    #[test]
    fn display_formats() {
        assert_eq!(Value::Int(3).to_string(), "3");
        assert_eq!(
            Value::List(vec![Value::Int(1), Value::Bool(false)]).to_string(),
            "[1, false]"
        );
    }
}
