//! Dynamic values passed to and returned from shared-object operations.
//!
//! The CF model treats objects as black boxes with arbitrary interfaces
//! (paper §2.5); method arguments and results therefore need a dynamic
//! representation analogous to Java RMI's serialized parameters.

use super::ObjectError;
use std::fmt;

/// A dynamically typed argument/result value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// No value (pure-write results).
    Unit,
    /// A boolean.
    Bool(bool),
    /// A 64-bit integer.
    Int(i64),
    /// A 64-bit float.
    Float(f64),
    /// A string.
    Str(String),
    /// Dense float payload, used by `ComputeObject` operations.
    Floats(Vec<f32>),
    /// Heterogeneous list.
    List(Vec<Value>),
}

impl Value {
    fn mismatch(&self, expected: &'static str) -> ObjectError {
        ObjectError::TypeMismatch { expected, got: format!("{self:?}") }
    }

    /// Fallible accessor: `Int` (or `Bool`, widened) as `i64`. Object
    /// `invoke` implementations use these so a mistyped argument surfaces
    /// as `TxError::Object`, not a panic.
    pub fn try_int(&self) -> Result<i64, ObjectError> {
        match self {
            Value::Int(v) => Ok(*v),
            Value::Bool(b) => Ok(*b as i64),
            other => Err(other.mismatch("Int")),
        }
    }

    /// Fallible accessor: `Float` (or `Int`, widened) as `f64`.
    pub fn try_float(&self) -> Result<f64, ObjectError> {
        match self {
            Value::Float(v) => Ok(*v),
            Value::Int(v) => Ok(*v as f64),
            other => Err(other.mismatch("Float")),
        }
    }

    /// Fallible accessor: `Bool`.
    pub fn try_bool(&self) -> Result<bool, ObjectError> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => Err(other.mismatch("Bool")),
        }
    }

    /// Fallible accessor: `Str`.
    pub fn try_str(&self) -> Result<&str, ObjectError> {
        match self {
            Value::Str(s) => Ok(s),
            other => Err(other.mismatch("Str")),
        }
    }

    /// Fallible accessor: `Floats`.
    pub fn try_floats(&self) -> Result<&[f32], ObjectError> {
        match self {
            Value::Floats(v) => Ok(v),
            other => Err(other.mismatch("Floats")),
        }
    }

    /// Panicking accessor; prefer [`Value::try_int`] anywhere a wrong
    /// variant is reachable from user input.
    pub fn as_int(&self) -> i64 {
        self.try_int().unwrap_or_else(|e| panic!("{e}"))
    }

    /// Panicking accessor; prefer [`Value::try_float`].
    pub fn as_float(&self) -> f64 {
        self.try_float().unwrap_or_else(|e| panic!("{e}"))
    }

    /// Panicking accessor; prefer [`Value::try_bool`].
    pub fn as_bool(&self) -> bool {
        self.try_bool().unwrap_or_else(|e| panic!("{e}"))
    }

    /// Panicking accessor; prefer [`Value::try_str`].
    pub fn as_str(&self) -> &str {
        self.try_str().unwrap_or_else(|e| panic!("{e}"))
    }

    /// Panicking accessor; prefer [`Value::try_floats`].
    pub fn as_floats(&self) -> &[f32] {
        self.try_floats().unwrap_or_else(|e| panic!("{e}"))
    }

    /// Approximate serialized size in bytes: used by the network model to
    /// charge transmission cost for arguments, results, and state copies.
    pub fn wire_size(&self) -> usize {
        match self {
            Value::Unit => 1,
            Value::Bool(_) => 2,
            Value::Int(_) => 9,
            Value::Float(_) => 9,
            Value::Str(s) => 5 + s.len(),
            Value::Floats(v) => 5 + 4 * v.len(),
            Value::List(v) => 5 + v.iter().map(Value::wire_size).sum::<usize>(),
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Unit => write!(f, "()"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Int(v) => write!(f, "{v}"),
            Value::Float(v) => write!(f, "{v}"),
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Floats(v) => write!(f, "f32[{}]", v.len()),
            Value::List(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Float(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<()> for Value {
    fn from(_: ()) -> Self {
        Value::Unit
    }
}
impl From<Vec<f32>> for Value {
    fn from(v: Vec<f32>) -> Self {
        Value::Floats(v)
    }
}

impl TryFrom<&Value> for i64 {
    type Error = ObjectError;
    fn try_from(v: &Value) -> Result<Self, Self::Error> {
        v.try_int()
    }
}
impl TryFrom<&Value> for f64 {
    type Error = ObjectError;
    fn try_from(v: &Value) -> Result<Self, Self::Error> {
        v.try_float()
    }
}
impl TryFrom<&Value> for bool {
    type Error = ObjectError;
    fn try_from(v: &Value) -> Result<Self, Self::Error> {
        v.try_bool()
    }
}
impl TryFrom<&Value> for String {
    type Error = ObjectError;
    fn try_from(v: &Value) -> Result<Self, Self::Error> {
        v.try_str().map(str::to_string)
    }
}
impl TryFrom<&Value> for Vec<f32> {
    type Error = ObjectError;
    fn try_from(v: &Value) -> Result<Self, Self::Error> {
        v.try_floats().map(<[f32]>::to_vec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors_roundtrip() {
        assert_eq!(Value::from(5i64).as_int(), 5);
        assert_eq!(Value::from(2.5f64).as_float(), 2.5);
        assert!(Value::from(true).as_bool());
        assert_eq!(Value::from("hi").as_str(), "hi");
        assert_eq!(Value::from(vec![1.0f32]).as_floats(), &[1.0f32]);
    }

    #[test]
    #[should_panic(expected = "expected Int")]
    fn wrong_accessor_panics() {
        Value::Str("x".into()).as_int();
    }

    #[test]
    fn try_accessors_return_errors_not_panics() {
        assert_eq!(Value::Int(3).try_int().unwrap(), 3);
        assert_eq!(Value::Bool(true).try_int().unwrap(), 1);
        assert_eq!(Value::Int(2).try_float().unwrap(), 2.0);
        assert_eq!(Value::Str("s".into()).try_str().unwrap(), "s");
        let err = Value::Str("x".into()).try_int().unwrap_err();
        assert!(matches!(err, ObjectError::TypeMismatch { expected: "Int", .. }), "{err:?}");
        assert!(err.to_string().contains("expected Int"));
        assert!(Value::Unit.try_bool().is_err());
        assert!(Value::Int(1).try_floats().is_err());
    }

    #[test]
    fn try_from_value_refs() {
        assert_eq!(i64::try_from(&Value::Int(9)).unwrap(), 9);
        assert_eq!(f64::try_from(&Value::Float(0.5)).unwrap(), 0.5);
        assert!(bool::try_from(&Value::Bool(true)).unwrap());
        assert_eq!(String::try_from(&Value::Str("a".into())).unwrap(), "a");
        assert_eq!(Vec::<f32>::try_from(&Value::Floats(vec![1.0])).unwrap(), vec![1.0]);
        assert!(i64::try_from(&Value::Unit).is_err());
    }

    #[test]
    fn wire_size_scales_with_payload() {
        assert!(Value::Floats(vec![0.0; 100]).wire_size() > Value::Int(1).wire_size());
        assert_eq!(Value::Str("abc".into()).wire_size(), 8);
        let l = Value::List(vec![Value::Int(1), Value::Unit]);
        assert_eq!(l.wire_size(), 5 + 9 + 1);
    }

    #[test]
    fn display_formats() {
        assert_eq!(Value::Int(3).to_string(), "3");
        assert_eq!(
            Value::List(vec![Value::Int(1), Value::Bool(false)]).to_string(),
            "[1, false]"
        );
    }
}
