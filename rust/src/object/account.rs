//! Bank account — the paper's running example (Fig 7).
//!
//! ```java
//! interface Account extends Remote {
//!   @Access(Mode.READ)   int balance();
//!   @Access(Mode.UPDATE) void deposit(int value);
//!   @Access(Mode.UPDATE) void withdraw(int value);
//!   @Access(Mode.WRITE)  void reset();
//! }
//! ```

use super::{MethodSpec, Mode, ObjectError, OpCall, SharedObject, Value};

/// A bank account with the paper's exact interface.
#[derive(Debug, Clone)]
pub struct Account {
    balance: i64,
}

/// Commutativity class of the additive balance updates: `deposit` and
/// `withdraw` are blind `±` on the balance, so they commute with each
/// other in any interleaving, and each inverts the other with the same
/// argument (abort-by-inverse; see docs/COMMUTATIVITY.md).
pub const ADDITIVE: u8 = 0;

const INTERFACE: &[MethodSpec] = &[
    MethodSpec::new("balance", Mode::Read),
    MethodSpec::commuting("deposit", Mode::Update, ADDITIVE, "withdraw"),
    MethodSpec::commuting("withdraw", Mode::Update, ADDITIVE, "deposit"),
    MethodSpec::new("reset", Mode::Write),
];

impl Account {
    /// An account holding `balance`.
    pub fn with_balance(balance: i64) -> Self {
        Account { balance }
    }

    /// Direct (non-transactional) balance read — tests and diagnostics.
    pub fn balance(&self) -> i64 {
        self.balance
    }
}

impl SharedObject for Account {
    fn type_name(&self) -> &'static str {
        "Account"
    }

    fn interface(&self) -> &'static [MethodSpec] {
        INTERFACE
    }

    fn invoke(&mut self, call: &OpCall) -> Result<Value, ObjectError> {
        match call.method {
            "balance" => Ok(Value::Int(self.balance)),
            "deposit" => {
                let v = call.args.first().ok_or_else(|| ObjectError::BadArgs {
                    method: "deposit".into(),
                    reason: "missing amount".into(),
                })?;
                self.balance += v.try_int()?;
                Ok(Value::Unit)
            }
            "withdraw" => {
                let v = call.args.first().ok_or_else(|| ObjectError::BadArgs {
                    method: "withdraw".into(),
                    reason: "missing amount".into(),
                })?;
                // NOTE: allowed to go negative; the paper's example transaction
                // checks the balance afterwards and aborts manually (Fig 9).
                self.balance -= v.try_int()?;
                Ok(Value::Unit)
            }
            "reset" => {
                // WRITE: sets state without reading it.
                self.balance = 0;
                Ok(Value::Unit)
            }
            m => Err(ObjectError::NoSuchMethod(m.to_string())),
        }
    }

    fn snapshot(&self) -> Box<dyn SharedObject> {
        Box::new(self.clone())
    }

    fn restore(&mut self, from: &dyn SharedObject) {
        let src = from
            .as_any()
            .downcast_ref::<Account>()
            .expect("restore: type mismatch");
        self.balance = src.balance;
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn state_size(&self) -> usize {
        8
    }
}

/// Convenience constructors for the account interface.
pub mod ops {
    use super::super::OpCall;

    /// `balance()` — read.
    pub fn balance() -> OpCall {
        OpCall::nullary("balance").with_idx(0)
    }
    /// `deposit(amount)` — commuting update (additive class).
    pub fn deposit(amount: i64) -> OpCall {
        OpCall::unary("deposit", amount).with_idx(1)
    }
    /// `withdraw(amount)` — commuting update (additive class).
    pub fn withdraw(amount: i64) -> OpCall {
        OpCall::unary("withdraw", amount).with_idx(2)
    }
    /// `reset()` — pure write (log-buffer executable).
    pub fn reset() -> OpCall {
        OpCall::nullary("reset").with_idx(3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deposit_withdraw_balance() {
        let mut a = Account::with_balance(100);
        a.invoke(&ops::deposit(50)).unwrap();
        a.invoke(&ops::withdraw(30)).unwrap();
        assert_eq!(a.invoke(&ops::balance()).unwrap().as_int(), 120);
    }

    #[test]
    fn withdraw_may_go_negative_like_the_paper_example() {
        let mut a = Account::with_balance(10);
        a.invoke(&ops::withdraw(100)).unwrap();
        assert_eq!(a.balance(), -90);
    }

    #[test]
    fn reset_is_a_pure_write() {
        let mut a = Account::with_balance(77);
        a.invoke(&ops::reset()).unwrap();
        assert_eq!(a.balance(), 0);
    }

    #[test]
    fn interface_modes_match_fig7() {
        let a = Account::with_balance(0);
        let get = |n: &str| {
            a.interface()
                .iter()
                .find(|m| m.name == n)
                .unwrap()
                .mode
        };
        assert_eq!(get("balance"), Mode::Read);
        assert_eq!(get("deposit"), Mode::Update);
        assert_eq!(get("withdraw"), Mode::Update);
        assert_eq!(get("reset"), Mode::Write);
    }
}
