//! Reference cell — the Eigenbench shared object.
//!
//! "Each object within any of the three arrays is a reference cell, i.e.,
//! an object that holds a single value, that can be either read or written
//! to." (paper §4.2). We also expose the optional per-operation synthetic
//! delay that models the paper's ~3 ms operation bodies.

use super::{MethodSpec, Mode, ObjectError, OpCall, SharedObject, Value};
use crate::clock::{Clock, RealClock};
use std::sync::Arc;
use std::time::Duration;

/// A single-value reference cell with configurable operation latency.
#[derive(Debug, Clone)]
pub struct RegisterObject {
    value: i64,
    /// Simulated operation body duration; models the "complex computation"
    /// each Eigenbench operation performs (~3 ms in the paper).
    op_delay: Duration,
    /// Time source the delay is paid on (the hosting cluster's clock, so
    /// virtual-time runs burn no wall time).
    clock: Arc<dyn Clock>,
}

const INTERFACE: &[MethodSpec] = &[
    MethodSpec::new("get", Mode::Read),
    MethodSpec::new("set", Mode::Write),
    // read-modify-write, exercised by update-classified workload ops;
    // returns the new value (an observer), so not declared commuting.
    MethodSpec::new("add", Mode::Update),
];

impl RegisterObject {
    /// A zero-latency cell holding `value`.
    pub fn new(value: i64) -> Self {
        Self::with_delay(value, Duration::ZERO)
    }

    /// Cell whose every operation takes `delay` of wall-clock time.
    pub fn with_delay(value: i64, delay: Duration) -> Self {
        Self::with_delay_on(value, delay, RealClock::shared())
    }

    /// Cell whose every operation takes `delay` on the given clock — pass
    /// the hosting cluster's clock so virtual-time runs account the delay
    /// without sleeping.
    pub fn with_delay_on(value: i64, delay: Duration, clock: Arc<dyn Clock>) -> Self {
        RegisterObject { value, op_delay: delay, clock }
    }

    /// Direct (non-transactional) read — tests and diagnostics.
    pub fn value(&self) -> i64 {
        self.value
    }

    fn burn(&self) {
        if !self.op_delay.is_zero() {
            // Sleep, not spin: on the oversubscribed evaluation box the
            // operation models remote/complex work, not local CPU burn.
            // Under a virtual clock this is pure accounting.
            self.clock.sleep(self.op_delay);
        }
    }
}

impl SharedObject for RegisterObject {
    fn type_name(&self) -> &'static str {
        "Register"
    }

    fn interface(&self) -> &'static [MethodSpec] {
        INTERFACE
    }

    fn invoke(&mut self, call: &OpCall) -> Result<Value, ObjectError> {
        match call.method {
            "get" => {
                self.burn();
                Ok(Value::Int(self.value))
            }
            "set" => {
                let v = call.args.first().ok_or_else(|| ObjectError::BadArgs {
                    method: "set".into(),
                    reason: "missing value".into(),
                })?;
                let v = v.try_int()?;
                self.burn();
                self.value = v;
                Ok(Value::Unit)
            }
            "add" => {
                let v = call.args.first().ok_or_else(|| ObjectError::BadArgs {
                    method: "add".into(),
                    reason: "missing delta".into(),
                })?;
                let v = v.try_int()?;
                self.burn();
                self.value += v;
                Ok(Value::Int(self.value))
            }
            m => Err(ObjectError::NoSuchMethod(m.to_string())),
        }
    }

    fn snapshot(&self) -> Box<dyn SharedObject> {
        Box::new(self.clone())
    }

    fn restore(&mut self, from: &dyn SharedObject) {
        let src = from
            .as_any()
            .downcast_ref::<RegisterObject>()
            .expect("restore: type mismatch");
        self.value = src.value;
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn state_size(&self) -> usize {
        8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_set_add() {
        let mut r = RegisterObject::new(10);
        assert_eq!(r.invoke(&OpCall::nullary("get")).unwrap().as_int(), 10);
        r.invoke(&OpCall::unary("set", 42i64)).unwrap();
        assert_eq!(r.value(), 42);
        assert_eq!(r.invoke(&OpCall::unary("add", 8i64)).unwrap().as_int(), 50);
    }

    #[test]
    fn missing_args_rejected() {
        let mut r = RegisterObject::new(0);
        assert!(matches!(
            r.invoke(&OpCall::nullary("set")),
            Err(ObjectError::BadArgs { .. })
        ));
    }

    #[test]
    fn unknown_method_rejected() {
        let mut r = RegisterObject::new(0);
        assert!(matches!(
            r.invoke(&OpCall::nullary("frobnicate")),
            Err(ObjectError::NoSuchMethod(_))
        ));
    }

    #[test]
    fn snapshot_then_restore() {
        let mut r = RegisterObject::new(1);
        let snap = r.snapshot();
        r.invoke(&OpCall::unary("set", 99i64)).unwrap();
        r.restore(snap.as_ref());
        assert_eq!(r.value(), 1);
    }

    #[test]
    fn op_delay_is_paid_on_the_given_clock() {
        use crate::clock::VirtualClock;
        let clock = std::sync::Arc::new(VirtualClock::new());
        let mut r = RegisterObject::with_delay_on(
            0,
            std::time::Duration::from_millis(3),
            std::sync::Arc::clone(&clock),
        );
        let t0 = std::time::Instant::now();
        r.invoke(&OpCall::nullary("get")).unwrap();
        r.invoke(&OpCall::unary("set", 1i64)).unwrap();
        assert_eq!(clock.now(), std::time::Duration::from_millis(6));
        assert!(t0.elapsed() < std::time::Duration::from_millis(500), "no real sleep");
    }
}
