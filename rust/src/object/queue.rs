//! FIFO queue object — classic TM example (push/pop, paper §1).

use super::{Commutes, MethodSpec, Mode, ObjectError, OpCall, SharedObject, Value};
use std::collections::VecDeque;

/// Bounded-ish FIFO queue of ints.
#[derive(Debug, Clone, Default)]
pub struct QueueObject {
    items: VecDeque<i64>,
}

const INTERFACE: &[MethodSpec] = &[
    MethodSpec::new("peek", Mode::Read),
    MethodSpec::new("len", Mode::Read),
    // `push` commutes with itself under *bag* semantics (membership and
    // `len` agree in any interleaving; only the FIFO pop order differs).
    // Declared `WithSelf` for documentation and the declaration lint;
    // the runtime never routes writes through group grants — blind
    // writes already run unsynchronized on the log buffer (§2.6), which
    // strictly subsumes the group-grant win.
    MethodSpec { name: "push", mode: Mode::Write, commutes: Commutes::WithSelf, inverse: None },
    MethodSpec::new("pop", Mode::Update),
];

impl QueueObject {
    /// An empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// A queue holding `items`, front first.
    pub fn from_items(items: &[i64]) -> Self {
        QueueObject { items: items.iter().copied().collect() }
    }

    /// Number of queued items.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

impl SharedObject for QueueObject {
    fn type_name(&self) -> &'static str {
        "Queue"
    }

    fn interface(&self) -> &'static [MethodSpec] {
        INTERFACE
    }

    fn invoke(&mut self, call: &OpCall) -> Result<Value, ObjectError> {
        match call.method {
            "peek" => Ok(self
                .items
                .front()
                .map(|v| Value::Int(*v))
                .unwrap_or(Value::Unit)),
            "len" => Ok(Value::Int(self.items.len() as i64)),
            "push" => {
                // WRITE: appends without observing existing state; this is
                // what makes `push` executable on a log buffer (§2.6).
                let v = call.args.first().ok_or_else(|| ObjectError::BadArgs {
                    method: "push".into(),
                    reason: "missing item".into(),
                })?;
                self.items.push_back(v.try_int()?);
                Ok(Value::Unit)
            }
            "pop" => Ok(self
                .items
                .pop_front()
                .map(Value::Int)
                .unwrap_or(Value::Unit)),
            m => Err(ObjectError::NoSuchMethod(m.to_string())),
        }
    }

    fn snapshot(&self) -> Box<dyn SharedObject> {
        Box::new(self.clone())
    }

    fn restore(&mut self, from: &dyn SharedObject) {
        let src = from
            .as_any()
            .downcast_ref::<QueueObject>()
            .expect("restore: type mismatch");
        self.items = src.items.clone();
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn state_size(&self) -> usize {
        8 * self.items.len() + 16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut q = QueueObject::new();
        q.invoke(&OpCall::unary("push", 1i64)).unwrap();
        q.invoke(&OpCall::unary("push", 2i64)).unwrap();
        assert_eq!(q.invoke(&OpCall::nullary("peek")).unwrap().as_int(), 1);
        assert_eq!(q.invoke(&OpCall::nullary("pop")).unwrap().as_int(), 1);
        assert_eq!(q.invoke(&OpCall::nullary("pop")).unwrap().as_int(), 2);
        assert_eq!(q.invoke(&OpCall::nullary("pop")).unwrap(), Value::Unit);
    }

    #[test]
    fn snapshot_isolation() {
        let mut q = QueueObject::from_items(&[5]);
        let snap = q.snapshot();
        q.invoke(&OpCall::nullary("pop")).unwrap();
        assert!(q.is_empty());
        q.restore(snap.as_ref());
        assert_eq!(q.len(), 1);
    }
}
