//! Compute-delegating shared object — the CF model's *raison d'être*.
//!
//! "A unique feature of CF is that it allows to delegate computation to
//! remote hosts … shared resources can act as both shared memory and web
//! services." (paper §1). `ComputeObject` holds a dense f32 state vector;
//! its `mix` (update) and `digest` (read) operations run a real numeric
//! kernel **on the hosting node** — in production via the AOT-compiled
//! Pallas/XLA artifact loaded by `runtime::XlaBackend`, in tests via the
//! pure-rust [`SpinBackend`] reference implementation.

use super::{MethodSpec, Mode, ObjectError, OpCall, SharedObject, Value};
use std::sync::Arc;

/// The kernel contract. Implemented by `runtime::XlaBackend` (PJRT) and by
/// [`SpinBackend`] (pure rust reference used in unit tests and when
/// artifacts are not built).
pub trait ComputeBackend: Send + Sync {
    /// `state' = mixR(state, params)` — R rounds of `tanh(state @ W + p)`.
    fn mix(&self, state: &[f32], params: &[f32]) -> Result<Vec<f32>, String>;
    /// Read-only digest of the state (sum of squares reduction).
    fn digest(&self, state: &[f32]) -> Result<f32, String>;
    /// State dimensionality the backend was compiled for.
    fn dim(&self) -> usize;
    /// Backend identifier for diagnostics (`"spin"`, `"xla"`).
    fn name(&self) -> &'static str;
}

/// Pure-rust reference backend: the same computation `ref.py` specifies,
/// with the deterministic mixing matrix `W[i][j] = sin(i*D + j)/D`.
pub struct SpinBackend {
    dim: usize,
    w: Vec<f32>, // row-major D×D
    rounds: usize,
}

impl SpinBackend {
    /// A backend for `dim`-element states running `rounds` mixing rounds.
    pub fn new(dim: usize, rounds: usize) -> Self {
        let mut w = vec![0f32; dim * dim];
        for i in 0..dim {
            for j in 0..dim {
                w[i * dim + j] = ((i * dim + j) as f32).sin() / dim as f32;
            }
        }
        SpinBackend { dim, w, rounds }
    }
}

impl ComputeBackend for SpinBackend {
    fn mix(&self, state: &[f32], params: &[f32]) -> Result<Vec<f32>, String> {
        let d = self.dim;
        if state.len() != d || params.len() != d {
            return Err(format!(
                "mix: want state/params of dim {d}, got {}/{}",
                state.len(),
                params.len()
            ));
        }
        let mut s = state.to_vec();
        let mut next = vec![0f32; d];
        for _ in 0..self.rounds {
            for j in 0..d {
                let mut acc = 0f32;
                for i in 0..d {
                    acc += s[i] * self.w[i * d + j];
                }
                next[j] = (acc + params[j]).tanh();
            }
            std::mem::swap(&mut s, &mut next);
        }
        Ok(s)
    }

    fn digest(&self, state: &[f32]) -> Result<f32, String> {
        Ok(state.iter().map(|x| x * x).sum())
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn name(&self) -> &'static str {
        "spin"
    }
}

/// Shared object whose operations delegate numeric work to the hosting
/// node's kernel backend.
pub struct ComputeObject {
    state: Vec<f32>,
    backend: Arc<dyn ComputeBackend>,
}

const INTERFACE: &[MethodSpec] = &[
    MethodSpec::new("digest", Mode::Read),
    MethodSpec::new("dim", Mode::Read),
    MethodSpec::new("load", Mode::Write),
    // matrix mixing rounds do not commute (tanh is non-linear).
    MethodSpec::new("mix", Mode::Update),
];

impl ComputeObject {
    /// An object with the all-0.5 initial state of the backend's dimension.
    pub fn new(backend: Arc<dyn ComputeBackend>) -> Self {
        let state = vec![0.5f32; backend.dim()];
        ComputeObject { state, backend }
    }

    /// An object with an explicit initial state (must match the backend's
    /// dimension).
    pub fn with_state(backend: Arc<dyn ComputeBackend>, state: Vec<f32>) -> Self {
        assert_eq!(state.len(), backend.dim());
        ComputeObject { state, backend }
    }

    /// The current state vector (tests and checkers).
    pub fn state(&self) -> &[f32] {
        &self.state
    }
}

impl SharedObject for ComputeObject {
    fn type_name(&self) -> &'static str {
        "Compute"
    }

    fn interface(&self) -> &'static [MethodSpec] {
        INTERFACE
    }

    fn invoke(&mut self, call: &OpCall) -> Result<Value, ObjectError> {
        match call.method {
            "digest" => {
                let d = self
                    .backend
                    .digest(&self.state)
                    .map_err(ObjectError::App)?;
                Ok(Value::Float(d as f64))
            }
            "dim" => Ok(Value::Int(self.backend.dim() as i64)),
            "load" => {
                // WRITE: replaces the state wholesale, never reads it.
                let v = call.args.first().ok_or_else(|| ObjectError::BadArgs {
                    method: "load".into(),
                    reason: "missing state vector".into(),
                })?;
                let s = v.try_floats()?;
                if s.len() != self.backend.dim() {
                    return Err(ObjectError::BadArgs {
                        method: "load".into(),
                        reason: format!(
                            "dim mismatch: want {}, got {}",
                            self.backend.dim(),
                            s.len()
                        ),
                    });
                }
                self.state = s.to_vec();
                Ok(Value::Unit)
            }
            "mix" => {
                let v = call.args.first().ok_or_else(|| ObjectError::BadArgs {
                    method: "mix".into(),
                    reason: "missing params vector".into(),
                })?;
                self.state = self
                    .backend
                    .mix(&self.state, v.try_floats()?)
                    .map_err(ObjectError::App)?;
                Ok(Value::Unit)
            }
            m => Err(ObjectError::NoSuchMethod(m.to_string())),
        }
    }

    fn snapshot(&self) -> Box<dyn SharedObject> {
        Box::new(ComputeObject {
            state: self.state.clone(),
            backend: Arc::clone(&self.backend),
        })
    }

    fn restore(&mut self, from: &dyn SharedObject) {
        let src = from
            .as_any()
            .downcast_ref::<ComputeObject>()
            .expect("restore: type mismatch");
        self.state = src.state.clone();
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn state_size(&self) -> usize {
        4 * self.state.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj() -> ComputeObject {
        ComputeObject::new(Arc::new(SpinBackend::new(8, 2)))
    }

    #[test]
    fn mix_changes_state_deterministically() {
        let mut a = obj();
        let mut b = obj();
        let params = Value::Floats(vec![0.1; 8]);
        a.invoke(&OpCall::new("mix", vec![params.clone()])).unwrap();
        b.invoke(&OpCall::new("mix", vec![params])).unwrap();
        assert_eq!(a.state(), b.state());
        assert_ne!(a.state(), &[0.5f32; 8]);
    }

    #[test]
    fn digest_is_sum_of_squares() {
        let mut o = ComputeObject::with_state(
            Arc::new(SpinBackend::new(4, 1)),
            vec![1.0, 2.0, 0.0, -1.0],
        );
        let d = o.invoke(&OpCall::nullary("digest")).unwrap().as_float();
        assert!((d - 6.0).abs() < 1e-6);
    }

    #[test]
    fn load_rejects_dim_mismatch() {
        let mut o = obj();
        let r = o.invoke(&OpCall::unary("load", vec![0.0f32; 3]));
        assert!(matches!(r, Err(ObjectError::BadArgs { .. })));
    }

    #[test]
    fn tanh_keeps_state_bounded() {
        let mut o = obj();
        for _ in 0..10 {
            o.invoke(&OpCall::unary("mix", vec![0.3f32; 8])).unwrap();
        }
        assert!(o.state().iter().all(|x| x.abs() <= 1.0));
    }
}
