//! A key-value store object: composite state, field-granular methods.
//!
//! This is the motivating case for OptSVA-CF over OptSVA (paper §1): a
//! write may modify field `a` while a subsequent read accesses field `b`,
//! so read-after-write is *not* local in the complex-object model and
//! requires synchronization (§2.9).

use super::{MethodSpec, Mode, ObjectError, OpCall, SharedObject, Value};
use std::collections::BTreeMap;

/// String-keyed map with read/write/update methods at key granularity.
#[derive(Debug, Clone, Default)]
pub struct KvStore {
    map: BTreeMap<String, i64>,
}

const INTERFACE: &[MethodSpec] = &[
    MethodSpec::new("get", Mode::Read),
    MethodSpec::new("contains", Mode::Read),
    MethodSpec::new("size", Mode::Read),
    // `put` on *different* keys commutes, but same-key puts are
    // last-writer-wins: the per-method declaration cannot express the
    // key-granular condition, so it stays `Never` (see
    // docs/COMMUTATIVITY.md on why declarations must be conservative).
    MethodSpec::new("put", Mode::Write),
    MethodSpec::new("clear", Mode::Write),
    MethodSpec::new("remove", Mode::Update),
    // `merge_add` is additive per key but returns the merged value — an
    // observer, so never commuting (same reasoning as `Counter::inc`).
    MethodSpec::new("merge_add", Mode::Update),
];

impl KvStore {
    /// An empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// A store pre-loaded with `pairs`.
    pub fn from_pairs(pairs: &[(&str, i64)]) -> Self {
        KvStore {
            map: pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        }
    }

    /// Number of keys.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Is the store empty?
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Direct (non-transactional) lookup — tests and diagnostics.
    pub fn peek(&self, key: &str) -> Option<i64> {
        self.map.get(key).copied()
    }

    fn key_arg(call: &OpCall) -> Result<&str, ObjectError> {
        match call.args.first() {
            Some(Value::Str(s)) => Ok(s),
            _ => Err(ObjectError::BadArgs {
                method: call.method.into(),
                reason: "first arg must be a string key".into(),
            }),
        }
    }
}

impl SharedObject for KvStore {
    fn type_name(&self) -> &'static str {
        "KvStore"
    }

    fn interface(&self) -> &'static [MethodSpec] {
        INTERFACE
    }

    fn invoke(&mut self, call: &OpCall) -> Result<Value, ObjectError> {
        match call.method {
            "get" => {
                let k = Self::key_arg(call)?;
                Ok(self
                    .map
                    .get(k)
                    .map(|v| Value::Int(*v))
                    .unwrap_or(Value::Unit))
            }
            "contains" => {
                let k = Self::key_arg(call)?;
                Ok(Value::Bool(self.map.contains_key(k)))
            }
            "size" => Ok(Value::Int(self.map.len() as i64)),
            "put" => {
                // WRITE: overwrites blindly, never observes prior state.
                let k = Self::key_arg(call)?.to_string();
                let v = call
                    .args
                    .get(1)
                    .ok_or_else(|| ObjectError::BadArgs {
                        method: "put".into(),
                        reason: "missing value".into(),
                    })?
                    .try_int()?;
                self.map.insert(k, v);
                Ok(Value::Unit)
            }
            "clear" => {
                self.map.clear();
                Ok(Value::Unit)
            }
            "remove" => {
                // UPDATE: returns the removed value (reads state).
                let k = Self::key_arg(call)?;
                Ok(self
                    .map
                    .remove(k)
                    .map(Value::Int)
                    .unwrap_or(Value::Unit))
            }
            "merge_add" => {
                let k = Self::key_arg(call)?.to_string();
                let v = call
                    .args
                    .get(1)
                    .ok_or_else(|| ObjectError::BadArgs {
                        method: "merge_add".into(),
                        reason: "missing delta".into(),
                    })?
                    .try_int()?;
                let slot = self.map.entry(k).or_insert(0);
                *slot += v;
                Ok(Value::Int(*slot))
            }
            m => Err(ObjectError::NoSuchMethod(m.to_string())),
        }
    }

    fn snapshot(&self) -> Box<dyn SharedObject> {
        Box::new(self.clone())
    }

    fn restore(&mut self, from: &dyn SharedObject) {
        let src = from
            .as_any()
            .downcast_ref::<KvStore>()
            .expect("restore: type mismatch");
        self.map = src.map.clone();
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn state_size(&self) -> usize {
        self.map.keys().map(|k| k.len() + 8 + 8).sum::<usize>() + 16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn put(k: &str, v: i64) -> OpCall {
        OpCall::new("put", vec![Value::from(k), Value::from(v)])
    }

    #[test]
    fn put_get_distinct_fields() {
        let mut kv = KvStore::new();
        kv.invoke(&put("a", 1)).unwrap();
        kv.invoke(&put("b", 2)).unwrap();
        // read of "b" is NOT local to the write of "a" — the scenario from §2.9
        assert_eq!(kv.invoke(&OpCall::unary("get", "b")).unwrap().as_int(), 2);
        assert_eq!(kv.invoke(&OpCall::nullary("size")).unwrap().as_int(), 2);
    }

    #[test]
    fn get_missing_returns_unit() {
        let mut kv = KvStore::new();
        assert_eq!(kv.invoke(&OpCall::unary("get", "x")).unwrap(), Value::Unit);
    }

    #[test]
    fn remove_returns_old_value() {
        let mut kv = KvStore::from_pairs(&[("k", 7)]);
        assert_eq!(kv.invoke(&OpCall::unary("remove", "k")).unwrap().as_int(), 7);
        assert!(kv.is_empty());
    }

    #[test]
    fn merge_add_accumulates() {
        let mut kv = KvStore::new();
        let call = OpCall::new("merge_add", vec![Value::from("n"), Value::from(3i64)]);
        assert_eq!(kv.invoke(&call).unwrap().as_int(), 3);
        assert_eq!(kv.invoke(&call).unwrap().as_int(), 6);
    }

    #[test]
    fn state_size_grows() {
        let mut kv = KvStore::new();
        let s0 = kv.state_size();
        kv.invoke(&put("key", 1)).unwrap();
        assert!(kv.state_size() > s0);
    }
}
