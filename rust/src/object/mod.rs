//! The complex shared-object model (paper §2.5).
//!
//! Shared objects are black boxes with arbitrary interfaces. Every method
//! is annotated with a [`Mode`] — READ, WRITE, or UPDATE — mirroring the
//! `@Access(Mode.…)` annotations of Atomic RMI 2's Java interfaces
//! (paper Fig 7):
//!
//!   * **read**   — may read state and return a value, never modifies it;
//!   * **write**  — may modify state, never reads it (executable against a
//!                  log buffer with *no* prior synchronization, §2.6);
//!   * **update** — may both read and modify state.
//!
//! Objects provide `snapshot`/`restore` so the concurrency-control layer
//! can build copy buffers and abort checkpoints without knowing the
//! concrete type.

pub mod account;
pub mod compute;
pub mod counter;
pub mod kvstore;
pub mod queue;
pub mod refs;
pub mod register;
pub mod value;

pub use crate::buffers::ArgList;

pub use account::Account;
pub use compute::{ComputeBackend, ComputeObject, SpinBackend};
pub use counter::Counter;
pub use kvstore::KvStore;
pub use queue::QueueObject;
pub use refs::{AccountRef, ComputeRef, CounterRef, KvRef, QueueRef, RegisterRef};
pub use register::RegisterObject;
pub use value::Value;

use std::fmt;

/// Operation classification (paper §2.5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    /// May read state and return a value, never modifies it.
    Read,
    /// May modify state, never reads it (log-buffer executable, §2.6).
    Write,
    /// May both read and modify state.
    Update,
}

impl fmt::Display for Mode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Mode::Read => write!(f, "read"),
            Mode::Write => write!(f, "write"),
            Mode::Update => write!(f, "update"),
        }
    }
}

/// A method invocation: name + arguments. The mode is looked up from the
/// object's interface (it is a property of the method, not of the call).
#[derive(Debug, Clone)]
pub struct OpCall {
    /// Method name, matched against the interface's [`MethodSpec`]s.
    pub method: &'static str,
    /// Argument values — inline for arity ≤ 2, so cloning a call into a
    /// log buffer or message allocates nothing (see [`ArgList`]).
    pub args: ArgList,
}

impl OpCall {
    /// A call with an arbitrary argument list.
    pub fn new(method: &'static str, args: impl Into<ArgList>) -> Self {
        OpCall { method, args: args.into() }
    }

    /// A call with no arguments.
    pub fn nullary(method: &'static str) -> Self {
        OpCall { method, args: ArgList::new() }
    }

    /// A call with one argument.
    pub fn unary(method: &'static str, arg: impl Into<Value>) -> Self {
        OpCall { method, args: ArgList::one(arg.into()) }
    }

    /// Approximate serialized size (for network cost accounting).
    pub fn wire_size(&self) -> usize {
        8 + self.method.len() + self.args.iter().map(Value::wire_size).sum::<usize>()
    }
}

/// Errors raised by object method execution.
#[derive(Debug, Clone, PartialEq)]
pub enum ObjectError {
    /// No method of this name in the object's interface.
    NoSuchMethod(String),
    /// The arguments did not match what the method expects.
    BadArgs {
        /// The method that rejected its arguments.
        method: String,
        /// Why they were rejected.
        reason: String,
    },
    /// A dynamically typed [`Value`] held a different variant than the
    /// accessor expected (fallible `try_*` accessors / `TryFrom`).
    TypeMismatch {
        /// The variant the accessor expected.
        expected: &'static str,
        /// The variant actually held.
        got: String,
    },
    /// The object crash-stopped (§3.4 fault injection).
    Crashed,
    /// An application-level error raised by the method body.
    App(String),
}

impl fmt::Display for ObjectError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ObjectError::NoSuchMethod(m) => write!(f, "no such method: {m}"),
            ObjectError::BadArgs { method, reason } => {
                write!(f, "bad arguments for {method}: {reason}")
            }
            ObjectError::TypeMismatch { expected, got } => {
                write!(f, "type mismatch: expected {expected}, got {got}")
            }
            ObjectError::Crashed => write!(f, "object crashed (crash-stop)"),
            ObjectError::App(e) => write!(f, "application error: {e}"),
        }
    }
}

impl std::error::Error for ObjectError {}

/// A method descriptor in an object's interface.
#[derive(Debug, Clone, Copy)]
pub struct MethodSpec {
    /// The method's name.
    pub name: &'static str,
    /// The method's declared access mode.
    pub mode: Mode,
}

/// The shared-object trait: what a "remote object" must implement to be
/// hosted by a node and driven by any of the concurrency-control layers.
pub trait SharedObject: Send {
    /// Object type name, for diagnostics.
    fn type_name(&self) -> &'static str;

    /// The object's interface: every callable method with its mode.
    fn interface(&self) -> &'static [MethodSpec];

    /// Execute a method. The concurrency-control layer guarantees
    /// exclusive access during the call.
    fn invoke(&mut self, call: &OpCall) -> Result<Value, ObjectError>;

    /// Deep copy of the object (copy buffers, checkpoints).
    fn snapshot(&self) -> Box<dyn SharedObject>;

    /// Overwrite this object's state from a snapshot of the same type
    /// (abort restore). Implementations may assume matching types.
    fn restore(&mut self, from: &dyn SharedObject);

    /// Downcast support for `restore` implementations.
    fn as_any(&self) -> &dyn std::any::Any;

    /// Approximate serialized state size in bytes (network cost of state
    /// migration in the DF baseline and of copy-buffer creation).
    fn state_size(&self) -> usize;
}

/// Look up the [`Mode`] of a method in an object's interface.
pub fn mode_of(obj: &dyn SharedObject, method: &str) -> Result<Mode, ObjectError> {
    obj.interface()
        .iter()
        .find(|m| m.name == method)
        .map(|m| m.mode)
        .ok_or_else(|| ObjectError::NoSuchMethod(method.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_lookup_works() {
        let acc = Account::with_balance(10);
        assert_eq!(mode_of(&acc, "balance").unwrap(), Mode::Read);
        assert_eq!(mode_of(&acc, "deposit").unwrap(), Mode::Update);
        assert_eq!(mode_of(&acc, "reset").unwrap(), Mode::Write);
        assert!(matches!(
            mode_of(&acc, "nope"),
            Err(ObjectError::NoSuchMethod(_))
        ));
    }

    #[test]
    fn opcall_constructors() {
        let c = OpCall::unary("deposit", 5i64);
        assert_eq!(c.method, "deposit");
        assert_eq!(c.args, vec![Value::Int(5)]);
        assert!(c.wire_size() > OpCall::nullary("x").wire_size());
    }

    #[test]
    fn snapshot_restore_roundtrip_via_trait_objects() {
        let mut a = Account::with_balance(100);
        let snap = a.snapshot();
        a.invoke(&OpCall::unary("deposit", 50i64)).unwrap();
        assert_eq!(a.invoke(&OpCall::nullary("balance")).unwrap().as_int(), 150);
        a.restore(snap.as_ref());
        assert_eq!(a.invoke(&OpCall::nullary("balance")).unwrap().as_int(), 100);
    }
}
