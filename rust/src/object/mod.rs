//! The complex shared-object model (paper §2.5).
//!
//! Shared objects are black boxes with arbitrary interfaces. Every method
//! is annotated with a [`Mode`] — READ, WRITE, or UPDATE — mirroring the
//! `@Access(Mode.…)` annotations of Atomic RMI 2's Java interfaces
//! (paper Fig 7):
//!
//!   * **read**   — may read state and return a value, never modifies it;
//!   * **write**  — may modify state, never reads it (executable against a
//!                  log buffer with *no* prior synchronization, §2.6);
//!   * **update** — may both read and modify state.
//!
//! Objects provide `snapshot`/`restore` so the concurrency-control layer
//! can build copy buffers and abort checkpoints without knowing the
//! concrete type.

pub mod account;
pub mod compute;
pub mod counter;
pub mod kvstore;
pub mod queue;
pub mod refs;
pub mod register;
pub mod value;

pub use crate::buffers::ArgList;

pub use account::Account;
pub use compute::{ComputeBackend, ComputeObject, SpinBackend};
pub use counter::Counter;
pub use kvstore::KvStore;
pub use queue::QueueObject;
pub use refs::{AccountRef, ComputeRef, CounterRef, KvRef, QueueRef, RegisterRef};
pub use register::RegisterObject;
pub use value::Value;

use std::fmt;

/// Operation classification (paper §2.5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    /// May read state and return a value, never modifies it.
    Read,
    /// May modify state, never reads it (log-buffer executable, §2.6).
    Write,
    /// May both read and modify state.
    Update,
}

impl fmt::Display for Mode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Mode::Read => write!(f, "read"),
            Mode::Write => write!(f, "write"),
            Mode::Update => write!(f, "update"),
        }
    }
}

/// Sentinel for [`OpCall::midx`]: no interface index attached; dispatch
/// falls back to a name lookup in the hosting type's method table.
pub const NO_METHOD_IDX: u16 = u16::MAX;

/// A method invocation: name + arguments. The mode is looked up from the
/// object's interface (it is a property of the method, not of the call).
#[derive(Debug, Clone)]
pub struct OpCall {
    /// Method name, matched against the interface's [`MethodSpec`]s.
    pub method: &'static str,
    /// Argument values — inline for arity ≤ 2, so cloning a call into a
    /// log buffer or message allocates nothing (see [`ArgList`]).
    pub args: ArgList,
    /// Position of the method in the target type's interface slice, or
    /// [`NO_METHOD_IDX`]. Typed `ops::` constructors and facades stamp it
    /// at construction, so the hot dispatch path resolves the
    /// [`MethodSpec`] with one bounds-checked slice access instead of a
    /// linear interface scan (see `cluster::registry::MethodTable`). The
    /// index is *advisory*: dispatch verifies `specs[midx].name` matches
    /// (pointer-first) and falls back to lookup by name, so a stale or
    /// hand-rolled call can never dispatch to the wrong method.
    pub midx: u16,
}

impl OpCall {
    /// A call with an arbitrary argument list.
    pub fn new(method: &'static str, args: impl Into<ArgList>) -> Self {
        OpCall { method, args: args.into(), midx: NO_METHOD_IDX }
    }

    /// A call with no arguments.
    pub fn nullary(method: &'static str) -> Self {
        OpCall { method, args: ArgList::new(), midx: NO_METHOD_IDX }
    }

    /// A call with one argument.
    pub fn unary(method: &'static str, arg: impl Into<Value>) -> Self {
        OpCall { method, args: ArgList::one(arg.into()), midx: NO_METHOD_IDX }
    }

    /// Attach the method's interface index (typed constructors that know
    /// the target interface statically).
    pub fn with_idx(mut self, idx: u16) -> Self {
        self.midx = idx;
        self
    }

    /// Approximate serialized size (for network cost accounting).
    pub fn wire_size(&self) -> usize {
        8 + self.method.len() + self.args.iter().map(Value::wire_size).sum::<usize>()
    }
}

/// Errors raised by object method execution.
#[derive(Debug, Clone, PartialEq)]
pub enum ObjectError {
    /// No method of this name in the object's interface.
    NoSuchMethod(String),
    /// The arguments did not match what the method expects.
    BadArgs {
        /// The method that rejected its arguments.
        method: String,
        /// Why they were rejected.
        reason: String,
    },
    /// A dynamically typed [`Value`] held a different variant than the
    /// accessor expected (fallible `try_*` accessors / `TryFrom`).
    TypeMismatch {
        /// The variant the accessor expected.
        expected: &'static str,
        /// The variant actually held.
        got: String,
    },
    /// The object crash-stopped (§3.4 fault injection).
    Crashed,
    /// An application-level error raised by the method body.
    App(String),
}

impl fmt::Display for ObjectError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ObjectError::NoSuchMethod(m) => write!(f, "no such method: {m}"),
            ObjectError::BadArgs { method, reason } => {
                write!(f, "bad arguments for {method}: {reason}")
            }
            ObjectError::TypeMismatch { expected, got } => {
                write!(f, "type mismatch: expected {expected}, got {got}")
            }
            ObjectError::Crashed => write!(f, "object crashed (crash-stop)"),
            ObjectError::App(e) => write!(f, "application error: {e}"),
        }
    }
}

impl std::error::Error for ObjectError {}

/// Commutativity class of a method (semantic concurrency control).
///
/// Two invocations commute when executing them in either order yields the
/// same final state *and* the same return values. Declaring a class lets
/// the concurrency-control layer admit same-class operations of different
/// transactions concurrently through a *group grant* instead of
/// serializing them behind the per-object version chain (see
/// `versioning::ObjectCc` and docs/COMMUTATIVITY.md).
///
/// Declaration rules (checked by the `commuting-observer` lint):
///   * only *blind* methods qualify — the return value must not depend on
///     the object's state (`deposit` returns `Unit`; `inc` returns the new
///     count and therefore must **not** be declared commuting);
///   * the method must be invertible for abort handling: the declaring
///     [`MethodSpec`] names an `inverse` method such that
///     `m(args); inverse(args)` is a state no-op in any interleaving with
///     other same-class operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Commutes {
    /// No commutativity: the operation serializes on the version chain.
    Never,
    /// Commutes with invocations of the *same method* only.
    WithSelf,
    /// Commutes with every method of the same class on the same object
    /// (e.g. `deposit`/`withdraw` are both class-0 additive updates).
    Class(u8),
}

impl Commutes {
    /// Do two declarations commute with each other?
    pub fn joins(self, other: Commutes, same_method: bool) -> bool {
        match (self, other) {
            (Commutes::Class(a), Commutes::Class(b)) => a == b,
            (Commutes::WithSelf, Commutes::WithSelf) => same_method,
            _ => false,
        }
    }

    /// The group-grant class key, if any: `Class(c)` maps to `c`,
    /// `WithSelf` to a per-method synthetic class derived by the caller,
    /// `Never` to none.
    pub fn class(self) -> Option<u8> {
        match self {
            Commutes::Class(c) => Some(c),
            _ => None,
        }
    }
}

/// A method descriptor in an object's interface.
#[derive(Debug, Clone, Copy)]
pub struct MethodSpec {
    /// The method's name.
    pub name: &'static str,
    /// The method's declared access mode.
    pub mode: Mode,
    /// Commutativity declaration ([`Commutes::Never`] by default).
    pub commutes: Commutes,
    /// Inverse method for abort-by-inverse (`deposit` ⇒ `withdraw`):
    /// invoked with the *same arguments* to undo the operation. Required
    /// (and only meaningful) for commuting declarations.
    pub inverse: Option<&'static str>,
}

impl MethodSpec {
    /// A non-commuting method (the default everywhere).
    pub const fn new(name: &'static str, mode: Mode) -> Self {
        MethodSpec { name, mode, commutes: Commutes::Never, inverse: None }
    }

    /// A commuting method of class `class`, undone by invoking `inverse`
    /// with the same arguments.
    pub const fn commuting(
        name: &'static str,
        mode: Mode,
        class: u8,
        inverse: &'static str,
    ) -> Self {
        MethodSpec { name, mode, commutes: Commutes::Class(class), inverse: Some(inverse) }
    }
}

/// The shared-object trait: what a "remote object" must implement to be
/// hosted by a node and driven by any of the concurrency-control layers.
pub trait SharedObject: Send {
    /// Object type name, for diagnostics.
    fn type_name(&self) -> &'static str;

    /// The object's interface: every callable method with its mode.
    fn interface(&self) -> &'static [MethodSpec];

    /// Execute a method. The concurrency-control layer guarantees
    /// exclusive access during the call.
    fn invoke(&mut self, call: &OpCall) -> Result<Value, ObjectError>;

    /// Deep copy of the object (copy buffers, checkpoints).
    fn snapshot(&self) -> Box<dyn SharedObject>;

    /// Overwrite this object's state from a snapshot of the same type
    /// (abort restore). Implementations may assume matching types.
    fn restore(&mut self, from: &dyn SharedObject);

    /// Downcast support for `restore` implementations.
    fn as_any(&self) -> &dyn std::any::Any;

    /// Approximate serialized state size in bytes (network cost of state
    /// migration in the DF baseline and of copy-buffer creation).
    fn state_size(&self) -> usize;
}

/// Look up the [`Mode`] of a method in an object's interface.
pub fn mode_of(obj: &dyn SharedObject, method: &str) -> Result<Mode, ObjectError> {
    spec_of(obj.interface(), method).map(|m| m.mode)
}

/// Look up a method's full [`MethodSpec`] in an interface slice.
pub fn spec_of<'a>(
    interface: &'a [MethodSpec],
    method: &str,
) -> Result<&'a MethodSpec, ObjectError> {
    interface
        .iter()
        .find(|m| m.name == method)
        .ok_or_else(|| ObjectError::NoSuchMethod(method.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_lookup_works() {
        let acc = Account::with_balance(10);
        assert_eq!(mode_of(&acc, "balance").unwrap(), Mode::Read);
        assert_eq!(mode_of(&acc, "deposit").unwrap(), Mode::Update);
        assert_eq!(mode_of(&acc, "reset").unwrap(), Mode::Write);
        assert!(matches!(
            mode_of(&acc, "nope"),
            Err(ObjectError::NoSuchMethod(_))
        ));
    }

    #[test]
    fn opcall_constructors() {
        let c = OpCall::unary("deposit", 5i64);
        assert_eq!(c.method, "deposit");
        assert_eq!(c.args, vec![Value::Int(5)]);
        assert_eq!(c.midx, NO_METHOD_IDX);
        assert_eq!(c.with_idx(1).midx, 1);
        assert!(OpCall::unary("deposit", 5i64).wire_size() > OpCall::nullary("x").wire_size());
    }

    #[test]
    fn commutativity_declarations() {
        // deposit/withdraw share an additive class and invert each other.
        let dep = spec_of(Account::with_balance(0).interface(), "deposit").unwrap();
        let wdr = spec_of(Account::with_balance(0).interface(), "withdraw").unwrap();
        assert!(dep.commutes.joins(wdr.commutes, false));
        assert_eq!(dep.inverse, Some("withdraw"));
        assert_eq!(wdr.inverse, Some("deposit"));
        // balance observes state: never commutes.
        let bal = spec_of(Account::with_balance(0).interface(), "balance").unwrap();
        assert_eq!(bal.commutes, Commutes::Never);
        assert!(!bal.commutes.joins(dep.commutes, false));
        // WithSelf joins only the same method.
        assert!(Commutes::WithSelf.joins(Commutes::WithSelf, true));
        assert!(!Commutes::WithSelf.joins(Commutes::WithSelf, false));
        assert_eq!(Commutes::Class(3).class(), Some(3));
        assert_eq!(Commutes::Never.class(), None);
    }

    #[test]
    fn snapshot_restore_roundtrip_via_trait_objects() {
        let mut a = Account::with_balance(100);
        let snap = a.snapshot();
        a.invoke(&OpCall::unary("deposit", 50i64)).unwrap();
        assert_eq!(a.invoke(&OpCall::nullary("balance")).unwrap().as_int(), 150);
        a.restore(snap.as_ref());
        assert_eq!(a.invoke(&OpCall::nullary("balance")).unwrap().as_int(), 100);
    }
}
