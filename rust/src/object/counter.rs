//! Shared counter — a minimal complex object with all three op modes.

use super::{MethodSpec, Mode, ObjectError, OpCall, SharedObject, Value};

/// Monotonic-ish counter: `get` (read), `zero` (write), `inc` (update).
#[derive(Debug, Clone, Default)]
pub struct Counter {
    count: i64,
}

const INTERFACE: &[MethodSpec] = &[
    MethodSpec::new("get", Mode::Read),
    MethodSpec::new("zero", Mode::Write),
    // `inc` is additive and *would* commute — but it returns the new
    // count, i.e. it observes state, so declaring it commuting would let
    // concurrent group members see unserialized intermediate counts. It
    // stays `Commutes::Never`; the `commuting-observer` lint exists to
    // catch exactly the tempting mis-declaration we avoid here.
    MethodSpec::new("inc", Mode::Update),
];

impl Counter {
    /// A counter at zero.
    pub fn new() -> Self {
        Counter { count: 0 }
    }

    /// A counter at `count`.
    pub fn starting_at(count: i64) -> Self {
        Counter { count }
    }

    /// Direct (non-transactional) read — tests and diagnostics.
    pub fn count(&self) -> i64 {
        self.count
    }
}

impl SharedObject for Counter {
    fn type_name(&self) -> &'static str {
        "Counter"
    }

    fn interface(&self) -> &'static [MethodSpec] {
        INTERFACE
    }

    fn invoke(&mut self, call: &OpCall) -> Result<Value, ObjectError> {
        match call.method {
            "get" => Ok(Value::Int(self.count)),
            "zero" => {
                self.count = 0;
                Ok(Value::Unit)
            }
            "inc" => {
                let by = match call.args.first() {
                    Some(v) => v.try_int()?,
                    None => 1,
                };
                self.count += by;
                Ok(Value::Int(self.count))
            }
            m => Err(ObjectError::NoSuchMethod(m.to_string())),
        }
    }

    fn snapshot(&self) -> Box<dyn SharedObject> {
        Box::new(self.clone())
    }

    fn restore(&mut self, from: &dyn SharedObject) {
        let src = from
            .as_any()
            .downcast_ref::<Counter>()
            .expect("restore: type mismatch");
        self.count = src.count;
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn state_size(&self) -> usize {
        8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inc_default_and_explicit() {
        let mut c = Counter::new();
        assert_eq!(c.invoke(&OpCall::nullary("inc")).unwrap().as_int(), 1);
        assert_eq!(c.invoke(&OpCall::unary("inc", 10i64)).unwrap().as_int(), 11);
        assert_eq!(c.invoke(&OpCall::nullary("get")).unwrap().as_int(), 11);
    }

    #[test]
    fn zero_resets() {
        let mut c = Counter::starting_at(5);
        c.invoke(&OpCall::nullary("zero")).unwrap();
        assert_eq!(c.count(), 0);
    }
}
