//! Time as a first-class, swappable substrate.
//!
//! The simulated cluster models the paper's 16-node 1 GbE interconnect by
//! injecting latency into every cross-node interaction, and the failure
//! detector (§3.4) and versioning waits are timeout-driven. With wall-clock
//! time, regenerating the Figure 10–13 sweeps means *actually sleeping*
//! through every injected microsecond — minutes of idle wall time per
//! bench run. This module factors time out of the substrate behind the
//! [`Clock`] trait so the same code runs against either:
//!
//!   * [`RealClock`] — `Instant`/`thread::sleep`, for interactive runs and
//!     tests that measure genuine wall-clock blocking;
//!   * [`VirtualClock`] — a discrete-event tick counter: `sleep` registers
//!     the caller's deadline in a priority queue and the *earliest* sleeper
//!     advances simulated time, so injected latency is accounted without a
//!     single real sleep and waiters wake in deterministic deadline order.
//!
//! Concurrent virtual sleepers coalesce (two 3 ms sleeps registered
//! together advance time by 3 ms, not 6 ms), which preserves the blocking
//! *structure* the paper's experiments measure. Because sleepers arrive on
//! real OS threads, the earliest sleeper grants a short real-time grace
//! window ([`ADVANCE_GRACE`]) before advancing, so latencies issued at the
//! same moment by parallel clients overlap instead of stacking. The
//! accounting is still an approximation — a sleeper that registers after
//! the window pays its latency serially — but wake-up *order* is
//! deterministic (deadline, then arrival) and no thread ever sleeps for
//! the simulated duration.
//!
//! Timeout-bounded condition waits (the versioning access/commit waits,
//! async-task joins) go through [`wait_deadline`]: under a real clock the
//! deadline maps to a plain `Condvar::wait_timeout`; under a virtual clock
//! the wait is notify-driven with a short real re-check slice, and a wait
//! that observes a completely stalled clock for a full slice may advance
//! simulated time to its own deadline ([`Clock::advance_if_stalled`]) so
//! failure-suspicion timeouts still fire in bounded real time on a
//! quiescent (crashed) system.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::{Duration, Instant};

/// The time source every latency injection, timeout, and failure-detector
/// scan in the substrate runs against.
pub trait Clock: Send + Sync + std::fmt::Debug {
    /// Time elapsed since this clock's epoch.
    fn now(&self) -> Duration;

    /// Let `d` of clock time pass on behalf of the calling thread.
    fn sleep(&self, d: Duration);

    /// Block until the clock reaches the absolute `deadline`; a deadline
    /// already passed returns immediately. The default maps to
    /// [`Clock::sleep`]; [`VirtualClock`] overrides it to register the
    /// deadline atomically with reading `now`, so concurrent sleepers
    /// targeting the same arrival instant (batched message delivery)
    /// always land in the same coalesced advance.
    fn sleep_until(&self, deadline: Duration) {
        let now = self.now();
        if deadline > now {
            self.sleep(deadline - now);
        }
    }

    /// Does this clock simulate time (no real sleeping)?
    fn is_virtual(&self) -> bool {
        false
    }

    /// Stamp that changes whenever simulated time moves or a sleeper
    /// arrives. Real clocks always report 0 (time moves by itself).
    fn activity(&self) -> u64 {
        0
    }

    /// Virtual clocks only: jump to `target` if nothing has moved since
    /// the `seen` activity stamp and no sleeper is registered — the escape
    /// hatch that lets a timeout fire on an otherwise-dead system.
    fn advance_if_stalled(&self, _seen: u64, _target: Duration) {}
}

/// Count of actual `thread::sleep` calls made by [`RealClock`]s in this
/// process. Lets tests assert a virtual-time run never fell back to real
/// sleeping through the substrate.
static REAL_SLEEPS: AtomicU64 = AtomicU64::new(0);

/// Total `RealClock::sleep` invocations process-wide.
pub fn real_sleep_count() -> u64 {
    REAL_SLEEPS.load(Ordering::Relaxed)
}

/// Wall-clock time: `now` is `Instant`-based, `sleep` really sleeps.
#[derive(Debug)]
pub struct RealClock {
    epoch: Instant,
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl RealClock {
    /// A clock whose epoch is now.
    pub fn new() -> Self {
        RealClock { epoch: Instant::now() }
    }

    /// The process-wide shared real clock (the default everywhere a clock
    /// is not supplied explicitly).
    pub fn shared() -> Arc<RealClock> {
        static SHARED: OnceLock<Arc<RealClock>> = OnceLock::new();
        Arc::clone(SHARED.get_or_init(|| Arc::new(RealClock::new())))
    }
}

impl Clock for RealClock {
    fn now(&self) -> Duration {
        self.epoch.elapsed()
    }

    fn sleep(&self, d: Duration) {
        if !d.is_zero() {
            REAL_SLEEPS.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(d);
        }
    }
}

#[derive(Debug, Default)]
struct VcState {
    now: Duration,
    next_seq: u64,
    /// `(deadline, arrival seq)` of every thread currently in `sleep`.
    sleepers: Vec<(Duration, u64)>,
    /// Bumped on every sleeper arrival and every advance.
    activity: u64,
    /// While > 0, time may not advance (test orchestration).
    holds: u32,
}

/// Simulated time: an atomic tick counter driven by the sleepers
/// themselves. No thread ever blocks in a real sleep; the earliest
/// registered deadline advances the clock and wakes everyone whose
/// deadline has passed, in deterministic `(deadline, arrival)` order.
#[derive(Debug, Default)]
pub struct VirtualClock {
    state: Mutex<VcState>,
    cond: Condvar,
}

impl VirtualClock {
    /// A clock at simulated time zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// [`new`](Self::new), `Arc`-wrapped for sharing.
    pub fn arc() -> Arc<Self> {
        Arc::new(Self::new())
    }

    /// Poison-tolerant state lock: the clock must stay usable on the
    /// shutdown/join path even after some task thread panicked while
    /// holding it (`VcState` is counters and a Vec — always structurally
    /// valid between mutations).
    fn lock_state(&self) -> MutexGuard<'_, VcState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// `sleep`, returning the simulated wake-up time (read atomically with
    /// the wake itself, so concurrent waiters can prove their ordering).
    pub fn sleep_tracked(&self, d: Duration) -> Duration {
        let s = self.lock_state();
        if d.is_zero() {
            return s.now;
        }
        let deadline = s.now + d;
        self.sleep_registered(s, deadline)
    }

    /// [`Clock::sleep_until`] with the wake-up time returned: registers
    /// the *absolute* deadline under the state lock, so the decision
    /// "already passed vs. must wait" is atomic with reading `now` and
    /// equal arrival deadlines from concurrent senders coalesce into a
    /// single advance.
    pub fn sleep_until_tracked(&self, deadline: Duration) -> Duration {
        let s = self.lock_state();
        if deadline <= s.now {
            return s.now;
        }
        self.sleep_registered(s, deadline)
    }

    /// Register `(deadline, seq)` and block until simulated time reaches
    /// it. The earliest registered sleeper advances the clock after a
    /// short real-time grace window; everyone else is woken by advances.
    fn sleep_registered(&self, mut s: MutexGuard<'_, VcState>, deadline: Duration) -> Duration {
        s.activity += 1;
        let seq = s.next_seq;
        s.next_seq += 1;
        s.sleepers.push((deadline, seq));
        let mut grace_served = false;
        loop {
            if s.now >= deadline {
                s.sleepers.retain(|&e| e != (deadline, seq));
                self.cond.notify_all();
                return s.now;
            }
            let earliest = s.sleepers.iter().min().copied();
            if s.holds == 0 && earliest == Some((deadline, seq)) {
                if !grace_served {
                    // We are the next event, but concurrently-arriving
                    // sleepers must get a chance to register so parallel
                    // latencies coalesce instead of stacking serially.
                    // Bounded real wait, then re-evaluate.
                    let (g, _) = self
                        .cond
                        .wait_timeout(s, ADVANCE_GRACE)
                        .unwrap_or_else(PoisonError::into_inner);
                    s = g;
                    grace_served = true;
                    continue;
                }
                // Still the next event after the grace window: advance
                // simulated time to our deadline and wake everyone to
                // re-check theirs.
                s.now = deadline;
                s.activity += 1;
                s.sleepers.retain(|&e| e != (deadline, seq));
                self.cond.notify_all();
                return s.now;
            }
            grace_served = false;
            s = self.cond.wait(s).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Freeze time: sleepers queue up but none advances until [`Self::release`].
    /// Used by tests to register concurrent sleepers deterministically.
    pub fn hold(&self) {
        self.lock_state().holds += 1;
    }

    /// Undo one [`Self::hold`].
    pub fn release(&self) {
        let mut s = self.lock_state();
        assert!(s.holds > 0, "release without hold");
        s.holds -= 1;
        self.cond.notify_all();
    }

    /// Number of threads currently blocked in `sleep`.
    pub fn sleeper_count(&self) -> usize {
        self.lock_state().sleepers.len()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Duration {
        self.lock_state().now
    }

    fn sleep(&self, d: Duration) {
        self.sleep_tracked(d);
    }

    fn sleep_until(&self, deadline: Duration) {
        self.sleep_until_tracked(deadline);
    }

    fn is_virtual(&self) -> bool {
        true
    }

    fn activity(&self) -> u64 {
        self.lock_state().activity
    }

    fn advance_if_stalled(&self, seen: u64, target: Duration) {
        let mut s = self.lock_state();
        if s.holds == 0 && s.activity == seen && s.sleepers.is_empty() && s.now < target {
            s.now = target;
            s.activity += 1;
            self.cond.notify_all();
        }
    }
}

/// Real-time grace the earliest virtual sleeper grants before advancing,
/// so sleeps issued concurrently by parallel threads land in the same
/// advance and coalesce. Costs at most this much wall time per distinct
/// simulated wake-up instant.
pub const ADVANCE_GRACE: Duration = Duration::from_micros(100);

/// Real-time re-check slice for deadline waits under a virtual clock: the
/// wait is still notify-driven (a release wakes it immediately); the slice
/// only bounds how long a *timeout* takes to be noticed.
pub const VIRTUAL_WAIT_SLICE: Duration = Duration::from_millis(25);

/// Consecutive zero-activity slices (~1 s of real time) required before a
/// virtual-deadline wait declares the clock stalled and forces its own
/// deadline. A runnable-but-descheduled or CPU-busy thread will touch the
/// clock well within this window even on a badly oversubscribed box, so
/// only a genuinely dead system (every thread blocked; a crashed client
/// holding the object) trips it.
const STALL_CONFIRM_SLICES: u32 = 40;

/// Block on `cond` until notified or until `deadline` (absolute, in
/// `clock` time) passes. Returns the reacquired guard and whether the
/// deadline has passed. Callers loop: re-check their condition first and
/// treat the expired flag as a timeout only if the condition still fails.
///
/// Poison-tolerant: a panicking task elsewhere must not turn every
/// subsequent join/versioning wait on the same mutex into a second
/// panic (and thence a wedged shutdown) — the protected state is only
/// ever mutated under invariant-preserving single assignments.
pub fn wait_deadline<'a, T>(
    clock: &dyn Clock,
    cond: &Condvar,
    guard: MutexGuard<'a, T>,
    deadline: Option<Duration>,
) -> (MutexGuard<'a, T>, bool) {
    let Some(d) = deadline else {
        return (cond.wait(guard).unwrap_or_else(PoisonError::into_inner), false);
    };
    let now = clock.now();
    if now >= d {
        return (guard, true);
    }
    if !clock.is_virtual() {
        let (g, _) = cond.wait_timeout(guard, d - now).unwrap_or_else(PoisonError::into_inner);
        return (g, clock.now() >= d);
    }
    let seen = clock.activity();
    let mut g = guard;
    let mut stalled_slices = 0u32;
    loop {
        let (g2, to) = cond
            .wait_timeout(g, VIRTUAL_WAIT_SLICE)
            .unwrap_or_else(PoisonError::into_inner);
        g = g2;
        if !to.timed_out() {
            // Notified: hand back so the caller re-checks its condition.
            return (g, clock.now() >= d);
        }
        if clock.now() >= d {
            return (g, true);
        }
        if clock.activity() != seen {
            // Simulated time is moving; let the caller re-evaluate.
            return (g, false);
        }
        stalled_slices += 1;
        if stalled_slices >= STALL_CONFIRM_SLICES {
            // ~1 s of real time with zero clock movement: the system is
            // dead; force the timeout in simulated time.
            clock.advance_if_stalled(seen, d);
            return (g, clock.now() >= d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn real_clock_advances_and_counts_sleeps() {
        let c = RealClock::new();
        let before = real_sleep_count();
        let t0 = c.now();
        c.sleep(Duration::from_millis(5));
        assert!(c.now() >= t0 + Duration::from_millis(5));
        assert!(real_sleep_count() > before);
        assert!(!c.is_virtual());
    }

    #[test]
    fn virtual_sleep_is_instant_in_real_time_and_exact_in_virtual_time() {
        let c = VirtualClock::new();
        let t0 = Instant::now();
        c.sleep(Duration::from_secs(3600));
        // An hour of virtual time in (essentially) zero wall time proves no
        // real sleep happened. (The global real-sleep counter is asserted
        // in the paper_scenarios integration test, whose process has no
        // concurrent RealClock users.)
        assert!(t0.elapsed() < Duration::from_secs(2), "must not really sleep");
        assert_eq!(c.now(), Duration::from_secs(3600));
    }

    #[test]
    fn zero_sleep_is_a_no_op() {
        let c = VirtualClock::new();
        c.sleep(Duration::ZERO);
        assert_eq!(c.now(), Duration::ZERO);
    }

    #[test]
    fn sleep_until_targets_absolute_deadlines() {
        let c = VirtualClock::new();
        c.sleep_until(Duration::from_millis(40));
        assert_eq!(c.now(), Duration::from_millis(40));
        // A deadline already passed returns immediately without advancing.
        c.sleep_until(Duration::from_millis(10));
        assert_eq!(c.now(), Duration::from_millis(40));
        assert_eq!(c.sleep_until_tracked(Duration::from_millis(40)), Duration::from_millis(40));
    }

    /// Concurrent sleepers targeting the *same* absolute deadline — the
    /// batched-delivery wake-up pattern — coalesce into one advance.
    #[test]
    fn equal_sleep_until_deadlines_coalesce() {
        let c = VirtualClock::arc();
        c.hold();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = Arc::clone(&c);
            handles.push(thread::spawn(move || c.sleep_until_tracked(Duration::from_millis(8))));
        }
        while c.sleeper_count() < 4 {
            thread::yield_now();
        }
        c.release();
        for h in handles {
            assert_eq!(h.join().unwrap(), Duration::from_millis(8));
        }
        assert_eq!(c.now(), Duration::from_millis(8), "one coalesced advance");
    }

    /// The satellite regression: two waiters sleeping different durations
    /// wake in deadline order, at exactly their deadlines, and concurrent
    /// sleeps coalesce (total advance = max, not sum).
    #[test]
    fn two_waiters_wake_in_deterministic_deadline_order() {
        let c = VirtualClock::arc();
        c.hold(); // freeze time until both sleepers are registered
        let (ca, cb) = (Arc::clone(&c), Arc::clone(&c));
        let a = thread::spawn(move || ca.sleep_tracked(Duration::from_millis(5)));
        let b = thread::spawn(move || cb.sleep_tracked(Duration::from_millis(10)));
        while c.sleeper_count() < 2 {
            thread::yield_now();
        }
        c.release();
        let woke_a = a.join().unwrap();
        let woke_b = b.join().unwrap();
        assert_eq!(woke_a, Duration::from_millis(5), "short sleeper wakes at its deadline");
        assert_eq!(woke_b, Duration::from_millis(10), "long sleeper wakes at its deadline");
        assert!(woke_a < woke_b, "wake order follows deadlines, not arrival");
        assert_eq!(c.now(), Duration::from_millis(10), "concurrent sleeps coalesce");
    }

    #[test]
    fn equal_deadlines_break_ties_by_arrival_and_coalesce() {
        let c = VirtualClock::arc();
        c.hold();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = Arc::clone(&c);
            handles.push(thread::spawn(move || c.sleep_tracked(Duration::from_millis(3))));
        }
        while c.sleeper_count() < 4 {
            thread::yield_now();
        }
        c.release();
        for h in handles {
            assert_eq!(h.join().unwrap(), Duration::from_millis(3));
        }
        assert_eq!(c.now(), Duration::from_millis(3), "4 parallel sleeps cost one");
    }

    /// Without any test-only `hold()`: parallel sleeps never account more
    /// than their serial sum (no double counting), and sleepers arriving
    /// within the advance grace window coalesce well below it. The exact
    /// coalescing factor is scheduling-dependent, so only the sum bound is
    /// asserted; the deterministic coalescing guarantee is covered by the
    /// `hold()`-based tests above.
    #[test]
    fn unheld_concurrent_sleeps_never_exceed_the_serial_sum() {
        let c = VirtualClock::arc();
        let barrier = Arc::new(std::sync::Barrier::new(8));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = Arc::clone(&c);
            let barrier = Arc::clone(&barrier);
            handles.push(thread::spawn(move || {
                barrier.wait();
                c.sleep(Duration::from_millis(10));
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let total = c.now();
        assert!(total >= Duration::from_millis(10), "at least one chain accounted");
        assert!(
            total <= Duration::from_millis(80),
            "8 parallel 10 ms sleeps can never exceed the 80 ms serial sum, got {total:?}"
        );
    }

    #[test]
    fn wait_deadline_times_out_on_a_stalled_virtual_clock() {
        let c = VirtualClock::new();
        let m = Mutex::new(());
        let cv = Condvar::new();
        let deadline = Some(Duration::from_secs(5)); // 5 s *virtual*
        let t0 = Instant::now();
        let mut expired = false;
        while !expired {
            let g = m.lock().unwrap();
            (_, expired) = wait_deadline(&c, &cv, g, deadline);
        }
        // Fires via advance_if_stalled: bounded real time, full virtual jump.
        assert!(t0.elapsed() < Duration::from_secs(5), "must not wait 5 real seconds");
        assert!(c.now() >= Duration::from_secs(5));
    }

    #[test]
    fn wait_deadline_respects_real_deadlines() {
        let c = RealClock::new();
        let m = Mutex::new(());
        let cv = Condvar::new();
        let d = Some(c.now() + Duration::from_millis(20));
        let mut expired = false;
        let t0 = Instant::now();
        while !expired {
            let g = m.lock().unwrap();
            (_, expired) = wait_deadline(&c, &cv, g, d);
        }
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn advance_if_stalled_is_inert_while_sleepers_exist() {
        let c = VirtualClock::arc();
        c.hold();
        let c2 = Arc::clone(&c);
        let h = thread::spawn(move || c2.sleep_tracked(Duration::from_millis(7)));
        while c.sleeper_count() < 1 {
            thread::yield_now();
        }
        let seen = c.activity();
        c.advance_if_stalled(seen, Duration::from_secs(100));
        assert_eq!(c.now(), Duration::ZERO, "a registered sleeper blocks the stall path");
        c.release();
        h.join().unwrap();
        assert_eq!(c.now(), Duration::from_millis(7));
    }
}
