//! Name registry — the RMI-registry analogue.
//!
//! Transactions locate shared objects by global name before declaring them
//! in the preamble (paper Fig 9: `registry.locate("A")`). The registry maps
//! names to [`Oid`]s; the hosting framework maps `Oid`s to live objects.

use super::{NodeId, Oid};
use std::collections::HashMap;
use std::sync::RwLock;

/// Thread-safe name → object-id directory.
pub struct Registry {
    entries: RwLock<HashMap<String, Oid>>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Registry { entries: RwLock::new(HashMap::new()) }
    }

    /// Bind a name to an object id. Rebinding an existing name replaces
    /// the entry (RMI `Naming.rebind` semantics).
    pub fn bind(&self, name: impl Into<String>, oid: Oid) {
        self.entries.write().unwrap().insert(name.into(), oid);
    }

    /// Look up a name (RMI `Naming.lookup` / the paper's `locate`).
    pub fn locate(&self, name: &str) -> Option<Oid> {
        self.entries.read().unwrap().get(name).copied()
    }

    /// Remove a binding (object decommissioned / crash-stop).
    pub fn unbind(&self, name: &str) -> Option<Oid> {
        self.entries.write().unwrap().remove(name)
    }

    /// All registered names on a given node (diagnostics).
    pub fn names_on(&self, node: NodeId) -> Vec<String> {
        let map = self.entries.read().unwrap();
        let mut names: Vec<String> = map
            .iter()
            .filter(|(_, oid)| oid.node == node)
            .map(|(k, _)| k.clone())
            .collect();
        names.sort();
        names
    }

    pub fn len(&self) -> usize {
        self.entries.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bind_locate_unbind() {
        let r = Registry::new();
        let oid = Oid::new(NodeId(1), 7);
        r.bind("A", oid);
        assert_eq!(r.locate("A"), Some(oid));
        assert_eq!(r.unbind("A"), Some(oid));
        assert_eq!(r.locate("A"), None);
    }

    #[test]
    fn rebind_replaces() {
        let r = Registry::new();
        r.bind("A", Oid::new(NodeId(0), 0));
        r.bind("A", Oid::new(NodeId(1), 1));
        assert_eq!(r.locate("A"), Some(Oid::new(NodeId(1), 1)));
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn names_on_filters_by_node() {
        let r = Registry::new();
        r.bind("a0", Oid::new(NodeId(0), 0));
        r.bind("b0", Oid::new(NodeId(0), 1));
        r.bind("a1", Oid::new(NodeId(1), 0));
        assert_eq!(r.names_on(NodeId(0)), vec!["a0".to_string(), "b0".to_string()]);
        assert_eq!(r.names_on(NodeId(1)), vec!["a1".to_string()]);
    }
}
