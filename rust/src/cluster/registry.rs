//! Name registry — the RMI-registry analogue, with name interning.
//!
//! Transactions locate shared objects by global name before declaring them
//! in the preamble (paper Fig 9: `registry.locate("A")`). The registry maps
//! names to [`Oid`]s; the hosting framework maps `Oid`s to live objects.
//!
//! # Interning
//!
//! Name lookup sits on the per-transaction hot path: every attempt of every
//! transaction resolves its whole access set. The original implementation
//! was a single `RwLock<HashMap<String, Oid>>`, which cost one `String`
//! hash plus one shared-lock acquisition per declaration per attempt, on
//! one global lock. This version splits the work:
//!
//!  * **Interning** (`intern` / `lookup`) maps a name to a small dense
//!    [`NameId`] once — typically at [`crate::api::TxBuilder`] time or when
//!    a workload pre-generates its object names. The name→id map is
//!    **striped** over [`STRIPES`] independent `RwLock`ed shards keyed by
//!    name hash, so concurrent transactions resolving different names do
//!    not contend on one lock.
//!  * **Resolution** (`resolve`) maps a [`NameId`] to the currently bound
//!    [`Oid`] without touching any string: an index into an append-only
//!    entry table plus one atomic load. Rebinding (`bind`) and unbinding
//!    mutate the entry's atomic in place, so `resolve` stays coherent with
//!    RMI `rebind` semantics.
//!
//! `locate(name)` is still available as the compatibility path (one stripe
//! read + one resolve); frameworks that thread [`NameId`]s through their
//! preambles never hash a string after interning.

use super::{NodeId, Oid};
use crate::object::{MethodSpec, OpCall, NO_METHOD_IDX};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Number of independent name→id shards. A small power of two: enough to
/// keep a few dozen client threads off each other's locks, small enough
/// that a full-registry snapshot stays cheap.
pub const STRIPES: usize = 16;

/// Dense identifier of an interned object name.
///
/// Invariant: a `NameId` returned by [`Registry::intern`] or
/// [`Registry::lookup`] stays valid for the registry's lifetime — entries
/// are append-only, and [`Registry::unbind`] only clears the binding, never
/// the name. Resolving an id whose name is currently unbound yields `None`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NameId(pub u32);

/// Packed binding state: bit 63 = bound flag, bits 32..48 = node, bits
/// 0..32 = index. The all-zeros value means "interned but not bound".
const BOUND: u64 = 1 << 63;

fn pack(oid: Oid) -> u64 {
    BOUND | ((oid.node.0 as u64) << 32) | oid.index as u64
}

fn unpack(raw: u64) -> Option<Oid> {
    if raw & BOUND == 0 {
        return None;
    }
    Some(Oid { node: NodeId(((raw >> 32) & 0xFFFF) as u16), index: raw as u32 })
}

/// FNV-1a — stable, dependency-free stripe selector.
fn fnv1a(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One interned name and its (atomic) current binding.
struct NameEntry {
    name: Arc<str>,
    oid: AtomicU64,
}

/// Thread-safe name → object-id directory with interning.
pub struct Registry {
    /// name → id, sharded by name hash.
    stripes: Vec<RwLock<HashMap<Arc<str>, NameId>>>,
    /// id → entry; append-only (push under the write lock, never removed).
    entries: RwLock<Vec<Arc<NameEntry>>>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Self {
        Registry {
            stripes: (0..STRIPES).map(|_| RwLock::new(HashMap::new())).collect(),
            entries: RwLock::new(Vec::new()),
        }
    }

    fn stripe(&self, name: &str) -> &RwLock<HashMap<Arc<str>, NameId>> {
        &self.stripes[(fnv1a(name) as usize) & (STRIPES - 1)]
    }

    /// Intern `name`, returning its dense id. Idempotent; never unbinds or
    /// rebinds. The common (already-interned) path is one shared-lock read
    /// on the name's stripe.
    pub fn intern(&self, name: &str) -> NameId {
        if let Some(&id) = self.stripe(name).read().unwrap().get(name) {
            return id;
        }
        // Slow path: allocate the entry, then publish the mapping. Take the
        // stripe lock first and re-check, so a racing intern of the same
        // name yields one id.
        let mut stripe = self.stripe(name).write().unwrap();
        if let Some(&id) = stripe.get(name) {
            return id;
        }
        let shared: Arc<str> = Arc::from(name);
        let mut entries = self.entries.write().unwrap();
        let id = NameId(u32::try_from(entries.len()).expect("too many interned names"));
        entries.push(Arc::new(NameEntry { name: Arc::clone(&shared), oid: AtomicU64::new(0) }));
        drop(entries);
        stripe.insert(shared, id);
        id
    }

    /// Id of an already-interned name, without interning it. The
    /// read-mostly companion of [`Registry::intern`] for callers that must
    /// not grow the table on behalf of unknown names.
    pub fn lookup(&self, name: &str) -> Option<NameId> {
        self.stripe(name).read().unwrap().get(name).copied()
    }

    /// Current binding of an interned name — the hot-path lookup: an index
    /// into the entry table plus one atomic load, no string hashing.
    pub fn resolve(&self, id: NameId) -> Option<Oid> {
        let entries = self.entries.read().unwrap();
        entries.get(id.0 as usize).and_then(|e| unpack(e.oid.load(Ordering::Acquire)))
    }

    /// The interned name behind an id (diagnostics).
    pub fn name_of(&self, id: NameId) -> Option<Arc<str>> {
        let entries = self.entries.read().unwrap();
        entries.get(id.0 as usize).map(|e| Arc::clone(&e.name))
    }

    /// Bind a name to an object id, interning it as needed. Rebinding an
    /// existing name replaces the entry (RMI `Naming.rebind` semantics);
    /// the name's [`NameId`] is stable across rebinds.
    pub fn bind(&self, name: impl AsRef<str>, oid: Oid) {
        let id = self.intern(name.as_ref());
        let entries = self.entries.read().unwrap();
        entries[id.0 as usize].oid.store(pack(oid), Ordering::Release);
    }

    /// Look up a name (RMI `Naming.lookup` / the paper's `locate`). The
    /// compatibility path: equivalent to `lookup` + `resolve`.
    pub fn locate(&self, name: &str) -> Option<Oid> {
        self.lookup(name).and_then(|id| self.resolve(id))
    }

    /// Remove a binding (object decommissioned / crash-stop). The name
    /// stays interned — its id remains valid and resolves to `None`.
    pub fn unbind(&self, name: &str) -> Option<Oid> {
        let id = self.lookup(name)?;
        let entries = self.entries.read().unwrap();
        unpack(entries[id.0 as usize].oid.swap(0, Ordering::AcqRel))
    }

    /// All currently bound names on a given node (diagnostics).
    ///
    /// Snapshots the entry table under the read lock (cheap `Arc` clones),
    /// then filters, extracts and sorts entirely outside it, so a large
    /// registry never holds up concurrent binds while sorting.
    pub fn names_on(&self, node: NodeId) -> Vec<String> {
        let snapshot: Vec<Arc<NameEntry>> = self.entries.read().unwrap().clone();
        let mut names: Vec<String> = snapshot
            .iter()
            .filter(|e| unpack(e.oid.load(Ordering::Acquire)).is_some_and(|o| o.node == node))
            .map(|e| e.name.to_string())
            .collect();
        names.sort();
        names
    }

    /// Number of currently bound names (unbound interned names excluded).
    pub fn len(&self) -> usize {
        let entries = self.entries.read().unwrap();
        entries.iter().filter(|e| e.oid.load(Ordering::Acquire) & BOUND != 0).count()
    }

    /// Is no name currently bound?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Per-type method-dispatch table: method name → position in the type's
/// interface slice, built once when an object type is hosted.
///
/// The dispatch hot path (`Proxy::spec_of`, the `ready_for` executor gate)
/// resolves a call's [`MethodSpec`] by its [`OpCall::midx`] in O(1); this
/// table is where that index comes from for calls that were not stamped by
/// a typed `ops::` constructor — e.g. hand-built calls from scenario
/// scripts or the CLI. [`MethodTable::stamp`] runs once per operation at
/// submit time, replacing the per-scheduler-pass linear interface scan the
/// gate used to pay.
pub struct MethodTable {
    /// `(name, index)` pairs sorted by name. Interfaces are tiny (≤ a
    /// dozen methods), so a sorted slice + binary search beats a `HashMap`
    /// on both footprint and lookup cost, and needs no hashing.
    by_name: Vec<(&'static str, u16)>,
}

impl MethodTable {
    /// Build the table for one interface slice.
    pub fn new(interface: &'static [MethodSpec]) -> Self {
        let mut by_name: Vec<(&'static str, u16)> = interface
            .iter()
            .enumerate()
            .map(|(i, m)| (m.name, u16::try_from(i).expect("interface too large")))
            .collect();
        by_name.sort_unstable_by_key(|&(n, _)| n);
        MethodTable { by_name }
    }

    /// Interface position of `method`, if it exists.
    pub fn index_of(&self, method: &str) -> Option<u16> {
        self.by_name
            .binary_search_by(|&(n, _)| n.cmp(method))
            .ok()
            .map(|i| self.by_name[i].1)
    }

    /// Stamp an unindexed call with its interface position. Already-stamped
    /// calls (typed constructors) and unknown methods (surfaced as
    /// `NoSuchMethod` at dispatch) pass through untouched.
    pub fn stamp(&self, call: &mut OpCall) {
        if call.midx == NO_METHOD_IDX {
            if let Some(idx) = self.index_of(call.method) {
                call.midx = idx;
            }
        }
    }
}

/// The pre-interning registry — one coarse `RwLock<HashMap<String, Oid>>`
/// around everything — retained verbatim as the micro-benchmark comparison
/// baseline. `benches/micro.rs` measures `CoarseRegistry::locate` against
/// [`Registry::resolve`] and records the ratio in `BENCH_micro.json`; it is
/// not used by any framework.
pub struct CoarseRegistry {
    entries: RwLock<HashMap<String, Oid>>,
}

impl Default for CoarseRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl CoarseRegistry {
    /// An empty coarse registry.
    pub fn new() -> Self {
        CoarseRegistry { entries: RwLock::new(HashMap::new()) }
    }

    /// Bind a name (rebind replaces).
    pub fn bind(&self, name: impl Into<String>, oid: Oid) {
        self.entries.write().unwrap().insert(name.into(), oid);
    }

    /// Stringly-keyed lookup: hashes the name under the global read lock.
    pub fn locate(&self, name: &str) -> Option<Oid> {
        self.entries.read().unwrap().get(name).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bind_locate_unbind() {
        let r = Registry::new();
        let oid = Oid::new(NodeId(1), 7);
        r.bind("A", oid);
        assert_eq!(r.locate("A"), Some(oid));
        assert_eq!(r.unbind("A"), Some(oid));
        assert_eq!(r.locate("A"), None);
        // The interned id survives the unbind and resolves to nothing.
        let id = r.lookup("A").unwrap();
        assert_eq!(r.resolve(id), None);
        assert_eq!(r.name_of(id).as_deref(), Some("A"));
    }

    #[test]
    fn rebind_replaces() {
        let r = Registry::new();
        r.bind("A", Oid::new(NodeId(0), 0));
        let id = r.lookup("A").unwrap();
        r.bind("A", Oid::new(NodeId(1), 1));
        assert_eq!(r.locate("A"), Some(Oid::new(NodeId(1), 1)));
        assert_eq!(r.len(), 1);
        // Stable id across rebind, resolving to the new binding.
        assert_eq!(r.lookup("A"), Some(id));
        assert_eq!(r.resolve(id), Some(Oid::new(NodeId(1), 1)));
    }

    #[test]
    fn names_on_filters_by_node() {
        let r = Registry::new();
        r.bind("a0", Oid::new(NodeId(0), 0));
        r.bind("b0", Oid::new(NodeId(0), 1));
        r.bind("a1", Oid::new(NodeId(1), 0));
        assert_eq!(r.names_on(NodeId(0)), vec!["a0".to_string(), "b0".to_string()]);
        assert_eq!(r.names_on(NodeId(1)), vec!["a1".to_string()]);
    }

    #[test]
    fn interning_is_idempotent_and_dense() {
        let r = Registry::new();
        let a = r.intern("A");
        let b = r.intern("B");
        assert_ne!(a, b);
        assert_eq!(r.intern("A"), a);
        assert_eq!(r.lookup("A"), Some(a));
        assert_eq!(r.lookup("missing"), None);
        // Interned-but-unbound resolves to None; len counts bindings only.
        assert_eq!(r.resolve(a), None);
        assert_eq!(r.len(), 0);
        assert!(r.is_empty());
    }

    #[test]
    fn interned_and_stringly_lookups_resolve_identically() {
        // Regression guard for the hot-path rework: for every bound name,
        // `resolve(intern(name))` must agree with `locate(name)` and with
        // the coarse baseline registry.
        let r = Registry::new();
        let coarse = CoarseRegistry::new();
        let mut oids = Vec::new();
        for i in 0..64u32 {
            let name = format!("obj-{}-{}", i % 7, i);
            let oid = Oid::new(NodeId((i % 5) as u16), i);
            r.bind(&name, oid);
            coarse.bind(name.clone(), oid);
            oids.push((name, oid));
        }
        for (name, oid) in &oids {
            let id = r.intern(name);
            assert_eq!(r.resolve(id), Some(*oid), "{name}");
            assert_eq!(r.locate(name), Some(*oid), "{name}");
            assert_eq!(coarse.locate(name), Some(*oid), "{name}");
        }
    }

    #[test]
    fn method_table_stamps_unindexed_calls() {
        use crate::object::{account::ops, OpCall, SharedObject, Value, NO_METHOD_IDX};
        let acc = crate::object::Account::with_balance(0);
        let table = MethodTable::new(acc.interface());
        // Every interface method resolves to its own position.
        for (i, m) in acc.interface().iter().enumerate() {
            assert_eq!(table.index_of(m.name), Some(i as u16), "{}", m.name);
        }
        assert_eq!(table.index_of("nope"), None);
        // A hand-built call gets stamped; dispatch and the typed
        // constructor agree on the index.
        let mut call = OpCall::new("deposit", vec![Value::from(5i64)]);
        assert_eq!(call.midx, NO_METHOD_IDX);
        table.stamp(&mut call);
        assert_eq!(call.midx, ops::deposit(5).midx);
        // Unknown methods stay unstamped (NoSuchMethod at dispatch).
        let mut bogus = OpCall::new("nope", Vec::<Value>::new());
        table.stamp(&mut bogus);
        assert_eq!(bogus.midx, NO_METHOD_IDX);
    }

    #[test]
    fn concurrent_bind_and_resolve() {
        use std::sync::Arc;
        let r = Arc::new(Registry::new());
        let names: Arc<Vec<String>> = Arc::new((0..128).map(|i| format!("c-{i}")).collect());
        // Half the threads bind/rebind, half intern+resolve concurrently;
        // every id handed out must stay valid and every resolved Oid must
        // be one that some bind actually wrote.
        let mut handles = Vec::new();
        for t in 0..4u16 {
            let r = Arc::clone(&r);
            let names = Arc::clone(&names);
            handles.push(std::thread::spawn(move || {
                for round in 0..50u32 {
                    for (i, name) in names.iter().enumerate() {
                        r.bind(name, Oid::new(NodeId(t), i as u32 + round));
                    }
                }
            }));
        }
        for _ in 0..4 {
            let r = Arc::clone(&r);
            let names = Arc::clone(&names);
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    for name in names.iter() {
                        let id = r.intern(name);
                        if let Some(oid) = r.resolve(id) {
                            assert!(oid.node.0 < 4, "resolved an Oid nobody bound");
                        }
                        assert_eq!(r.lookup(name), Some(id), "interned id must be stable");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Quiescent state: every name bound, ids dense and resolvable.
        assert_eq!(r.len(), names.len());
        for name in names.iter() {
            let id = r.lookup(name).unwrap();
            assert_eq!(r.resolve(id), r.locate(name));
        }
    }
}
