//! Simulated distributed cluster substrate.
//!
//! The paper evaluates on a 16-node, 1 GbE cluster. The reproduction bands
//! flag that hardware as unavailable, so per the substitution rule we model
//! the cluster **in-process**: nodes are logical endpoints, every
//! cross-node interaction goes through [`Cluster::rpc`], which injects
//! configurable network latency (sleep) and accounts messages and bytes.
//!
//! What this preserves — and what the paper's experiments measure — is the
//! *blocking structure* of distributed synchronization: who waits for whom,
//! for how long, and how much communication each algorithm needs. Java
//! RMI's remote call semantics (caller blocks, method runs at the object's
//! home node) are preserved exactly: the calling thread pays request
//! latency, executes the server-side handler against the hosting node's
//! state, then pays response latency. This is behaviourally identical to a
//! server worker thread executing the handler while the caller blocks, but
//! does not require thousands of OS threads on the 1-core evaluation box.
//!
//! All latency is paid through the cluster's [`Clock`]: under the default
//! [`RealClock`](crate::clock::RealClock) the calling thread really
//! sleeps; under a [`VirtualClock`](crate::clock::VirtualClock) the delay
//! is accounted in simulated time and costs no wall time (see
//! [`Cluster::new_virtual`]).
//!
//! Message *transport* is sharded ([`inbox::ShardedInboxes`]): every
//! cross-node message is posted into the destination node's lock-striped
//! inbox with an absolute arrival deadline (FIFO per sender–receiver
//! pair), the sending thread sleeps to that deadline on the cluster
//! clock (equal deadlines coalesce into one virtual advance), and
//! whichever thread reaches a node's deadline first drains the whole due
//! batch in one lock acquisition, emitting one callee-side
//! `msg-deliver` trace event per message.

pub mod inbox;
pub mod registry;

pub use inbox::{Envelope, ShardedInboxes};
pub use registry::{NameId, Registry};

use crate::clock::{Clock, RealClock};
use crate::trace::{self, EventKind};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Logical node identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub u16);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// Globally ordered object identifier: `(home node, index on node)`.
///
/// The total order over `Oid`s is the *global lock order* used for atomic
/// private-version acquisition (paper §2.10.2) and for S2PL lock
/// acquisition — it is what rules out deadlock during transaction start.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Oid {
    /// Home node hosting the object.
    pub node: NodeId,
    /// Index of the object within its home node's slot table.
    pub index: u32,
}

impl Oid {
    /// Identifier of object `index` on `node`.
    pub fn new(node: NodeId, index: u32) -> Self {
        Oid { node, index }
    }
}

impl fmt::Display for Oid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}#{}", self.node, self.index)
    }
}

/// Latency/bandwidth model for the simulated interconnect.
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// One-way propagation + protocol latency per message.
    pub one_way: Duration,
    /// Additional transmission time per KiB of payload.
    pub per_kib: Duration,
}

impl NetworkModel {
    /// Zero-latency network (unit tests, deterministic interleavings).
    pub fn instant() -> Self {
        NetworkModel { one_way: Duration::ZERO, per_kib: Duration::ZERO }
    }

    /// Scaled-down 1 GbE LAN: ~100 µs one-way (RMI stack + switch),
    /// ~8 µs/KiB transmission.
    pub fn lan() -> Self {
        NetworkModel {
            one_way: Duration::from_micros(100),
            per_kib: Duration::from_micros(8),
        }
    }

    /// One-way delay for a payload of `bytes`.
    pub fn delay(&self, bytes: usize) -> Duration {
        self.one_way + self.per_kib.mul_f64(bytes as f64 / 1024.0)
    }
}

/// Message/byte counters, kept per cluster and readable by benchmarks.
#[derive(Debug, Default)]
pub struct NetStats {
    /// Cross-node messages sent (requests and responses both count).
    pub messages: AtomicU64,
    /// Total payload bytes crossing the simulated network.
    pub bytes: AtomicU64,
    /// Remote calls that stayed on-node (proxy co-located with object).
    pub local_calls: AtomicU64,
}

impl NetStats {
    /// `(messages, bytes, local_calls)` at this instant.
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.messages.load(Ordering::Relaxed),
            self.bytes.load(Ordering::Relaxed),
            self.local_calls.load(Ordering::Relaxed),
        )
    }
}

/// The simulated cluster: node count, interconnect model, name registry,
/// and communication accounting. Concurrency-control frameworks build
/// their per-node state on top of this (indexed by `NodeId`).
pub struct Cluster {
    nodes: u16,
    net: NetworkModel,
    clock: Arc<dyn Clock>,
    /// Global name → [`Oid`] directory (the RMI-registry analogue).
    pub registry: Registry,
    /// Message/byte accounting for the simulated interconnect.
    pub stats: NetStats,
    inboxes: ShardedInboxes,
}

impl Cluster {
    /// Cluster on the shared wall clock (interactive runs, latency tests).
    pub fn new(nodes: u16, net: NetworkModel) -> Self {
        Self::with_clock(nodes, net, RealClock::shared())
    }

    /// Cluster on a fresh [`crate::clock::VirtualClock`]: every injected
    /// latency, timeout and detector scan runs in simulated time.
    pub fn new_virtual(nodes: u16, net: NetworkModel) -> Self {
        Self::with_clock(nodes, net, Arc::new(crate::clock::VirtualClock::new()))
    }

    /// Cluster on an explicit time source (shared with other components).
    pub fn with_clock(nodes: u16, net: NetworkModel, clock: Arc<dyn Clock>) -> Self {
        assert!(nodes > 0, "cluster needs at least one node");
        Cluster {
            nodes,
            net,
            clock,
            registry: Registry::new(),
            stats: NetStats::default(),
            inboxes: ShardedInboxes::new(nodes),
        }
    }

    /// The time source all latency, timeouts, and fault detection use.
    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    /// Number of simulated nodes.
    pub fn node_count(&self) -> u16 {
        self.nodes
    }

    /// Every node id, `n0..n{count-1}`.
    pub fn node_ids(&self) -> impl Iterator<Item = NodeId> {
        (0..self.nodes).map(NodeId)
    }

    /// The interconnect's latency/bandwidth model.
    pub fn network(&self) -> NetworkModel {
        self.net
    }

    /// The sharded per-node inboxes every cross-node message flows
    /// through. Exposed for transport tests and delivery-batching metrics
    /// ([`ShardedInboxes::delivery_stats`]).
    pub fn inboxes(&self) -> &ShardedInboxes {
        &self.inboxes
    }

    /// Account one message leg at *send time* (mid-run snapshots must see
    /// in-flight traffic) and emit the sender-side `msg-send` event.
    fn account_send(&self, from: NodeId, to: NodeId, bytes: usize) {
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        if trace::enabled() {
            trace::emit(from.0, EventKind::MsgSend { from, to, bytes });
        }
    }

    /// Post one message leg into `to`'s inbox and ride along with it:
    /// sleep (in cluster-clock time) until its effective arrival
    /// deadline, then drain and deliver `to`'s whole due batch. The
    /// shared transmission path of [`Cluster::rpc`], [`Cluster::send`]
    /// and [`Cluster::deliver`].
    fn transmit(&self, from: NodeId, to: NodeId, bytes: usize, sent_at: Duration) {
        let arrival = self.inboxes.post(from, to, bytes, sent_at, self.net.delay(bytes), 0);
        self.clock.sleep_until(arrival);
        self.deliver_due(to);
    }

    /// Drain every due envelope at `to` in one inbox-lock acquisition,
    /// emitting a callee-side `msg-deliver` trace event per message.
    pub fn deliver_due(&self, to: NodeId) {
        let due = self.inboxes.drain_due(to, self.clock.now());
        if trace::enabled() {
            for env in &due {
                trace::emit(
                    env.to.0,
                    EventKind::MsgDeliver { from: env.from, to: env.to, bytes: env.bytes },
                );
            }
        }
        // Hand the batch buffer back so the next drain at this node reuses
        // the allocation (one drain per message leg on the RPC hot path).
        self.inboxes.recycle(to, due);
    }

    /// Perform a remote procedure call from `from` to `to`.
    ///
    /// The handler `f` runs at the callee (it must only touch `to`-local
    /// state); the calling thread pays one-way latency for the request of
    /// `req_bytes` and for the response of the size `f` reports. Each leg
    /// is accounted and trace-stamped symmetrically: `msg-send` at the
    /// sending node when the leg starts, `msg-deliver` at the receiving
    /// node when its envelope is drained — so a traced round trip is four
    /// events (two per leg), and stats snapshots taken inside `f` already
    /// see the request leg.
    pub fn rpc<R>(
        &self,
        from: NodeId,
        to: NodeId,
        req_bytes: usize,
        f: impl FnOnce() -> (R, usize),
    ) -> R {
        if from == to {
            self.stats.local_calls.fetch_add(1, Ordering::Relaxed);
            return f().0;
        }
        self.account_send(from, to, req_bytes);
        self.transmit(from, to, req_bytes, self.clock.now());
        let (result, resp_bytes) = f();
        self.account_send(to, from, resp_bytes);
        self.transmit(to, from, resp_bytes, self.clock.now());
        result
    }

    /// Account a one-way message that was *sent at* `sent_at` (cluster
    /// clock time) and block the calling thread only until its arrival —
    /// the pipelined-delivery counterpart of [`Cluster::send`], used for
    /// asynchronous operation responses: the transmission overlaps with
    /// whatever the caller did since `sent_at`, so a caller that waits
    /// late pays nothing (unless an earlier same-pair message is still in
    /// flight — FIFO delivery never lets a later send overtake it).
    pub fn deliver(&self, from: NodeId, to: NodeId, bytes: usize, sent_at: Duration) {
        if from == to {
            self.stats.local_calls.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.account_send(from, to, bytes);
        self.transmit(from, to, bytes, sent_at);
    }

    /// One-way message (no reply): fault-detection pings, invalidations.
    pub fn send(&self, from: NodeId, to: NodeId, bytes: usize) {
        if from == to {
            self.stats.local_calls.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.account_send(from, to, bytes);
        self.transmit(from, to, bytes, self.clock.now());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn oid_order_is_node_major() {
        let a = Oid::new(NodeId(0), 99);
        let b = Oid::new(NodeId(1), 0);
        assert!(a < b);
        let c = Oid::new(NodeId(1), 1);
        assert!(b < c);
    }

    #[test]
    fn local_rpc_is_free_and_counted() {
        let c = Cluster::new(2, NetworkModel::lan());
        let t0 = Instant::now();
        let v = c.rpc(NodeId(0), NodeId(0), 1000, || (42, 1000));
        assert_eq!(v, 42);
        assert!(t0.elapsed() < Duration::from_millis(5));
        let (msgs, _, local) = c.stats.snapshot();
        assert_eq!(msgs, 0);
        assert_eq!(local, 1);
    }

    #[test]
    fn remote_rpc_pays_latency_and_counts() {
        let c = Cluster::new(2, NetworkModel {
            one_way: Duration::from_millis(2),
            per_kib: Duration::ZERO,
        });
        let t0 = Instant::now();
        let v = c.rpc(NodeId(0), NodeId(1), 100, || ("ok", 100));
        assert_eq!(v, "ok");
        assert!(t0.elapsed() >= Duration::from_millis(4), "2 one-way trips");
        let (msgs, bytes, _) = c.stats.snapshot();
        assert_eq!(msgs, 2);
        assert_eq!(bytes, 200);
    }

    #[test]
    fn payload_size_adds_transmission_delay() {
        let net = NetworkModel {
            one_way: Duration::from_micros(10),
            per_kib: Duration::from_millis(1),
        };
        assert!(net.delay(4096) >= Duration::from_millis(4));
        assert!(net.delay(0) == Duration::from_micros(10));
    }

    #[test]
    fn node_ids_enumerate_all() {
        let c = Cluster::new(4, NetworkModel::instant());
        let ids: Vec<_> = c.node_ids().collect();
        assert_eq!(ids, vec![NodeId(0), NodeId(1), NodeId(2), NodeId(3)]);
    }

    #[test]
    fn virtual_cluster_accounts_latency_without_real_sleeping() {
        // A delay that would take 10 real seconds per message.
        let c = Cluster::new_virtual(2, NetworkModel {
            one_way: Duration::from_secs(10),
            per_kib: Duration::ZERO,
        });
        let t0 = Instant::now();
        let v = c.rpc(NodeId(0), NodeId(1), 100, || (7, 100));
        assert_eq!(v, 7);
        assert!(t0.elapsed() < Duration::from_secs(2), "no wall-clock sleeping");
        assert_eq!(c.clock().now(), Duration::from_secs(20), "2 one-way trips accounted");
        let (msgs, bytes, _) = c.stats.snapshot();
        assert_eq!(msgs, 2);
        assert_eq!(bytes, 200);
    }

    #[test]
    fn deliver_overlaps_transmission_with_caller_work() {
        let c = Cluster::new_virtual(
            2,
            NetworkModel { one_way: Duration::from_millis(10), per_kib: Duration::ZERO },
        );
        let sent_at = c.clock().now();
        c.clock().sleep(Duration::from_millis(25)); // caller did other work meanwhile
        c.deliver(NodeId(1), NodeId(0), 64, sent_at);
        assert_eq!(
            c.clock().now(),
            Duration::from_millis(25),
            "arrival already passed: no extra wait"
        );
        let sent_at = c.clock().now();
        c.deliver(NodeId(1), NodeId(0), 64, sent_at);
        assert_eq!(
            c.clock().now(),
            Duration::from_millis(35),
            "fresh delivery pays the one-way latency"
        );
        let (msgs, _, _) = c.stats.snapshot();
        assert_eq!(msgs, 2);
    }

    #[test]
    fn virtual_send_accounts_one_way_latency() {
        let c = Cluster::new_virtual(2, NetworkModel {
            one_way: Duration::from_millis(500),
            per_kib: Duration::ZERO,
        });
        c.send(NodeId(0), NodeId(1), 24);
        assert_eq!(c.clock().now(), Duration::from_millis(500));
    }

    /// The per-leg accounting bugfix: a stats snapshot taken *inside* the
    /// RPC handler — mid-flight, after the request leg but before the
    /// response leg — must already see the request message. The old code
    /// incremented both legs once after both latency sleeps, so mid-run
    /// snapshots undercounted in-flight traffic.
    #[test]
    fn rpc_accounts_each_leg_at_send_time() {
        let net = NetworkModel { one_way: Duration::from_millis(5), per_kib: Duration::ZERO };
        let c = Cluster::new_virtual(2, net);
        let v = c.rpc(NodeId(0), NodeId(1), 70, || {
            let (msgs, bytes, _) = c.stats.snapshot();
            assert_eq!(msgs, 1, "request leg visible mid-flight");
            assert_eq!(bytes, 70, "request bytes visible mid-flight");
            (9, 30)
        });
        assert_eq!(v, 9);
        let (msgs, bytes, _) = c.stats.snapshot();
        assert_eq!(msgs, 2);
        assert_eq!(bytes, 100);
    }

    /// FIFO per sender–receiver pair through the cluster transport: a
    /// pipelined delivery posted behind an earlier, slower same-pair
    /// message is clamped to the earlier message's arrival.
    #[test]
    fn pipelined_delivery_never_overtakes_an_earlier_same_pair_message() {
        let net = NetworkModel {
            one_way: Duration::from_millis(1),
            per_kib: Duration::from_millis(10),
        };
        let c = Cluster::new_virtual(2, net);
        // A bulky response sent at t=0 arrives at ~1 ms + 10 ms/KiB * 4 KiB.
        let slow_arrival = c.network().delay(4096);
        c.inboxes().post(NodeId(1), NodeId(0), 4096, Duration::ZERO, slow_arrival, 0);
        // A small response sent later on the same pair would nominally
        // arrive much earlier; FIFO clamps it behind the bulky one.
        c.deliver(NodeId(1), NodeId(0), 16, Duration::ZERO);
        assert_eq!(c.clock().now(), slow_arrival, "clamped to the in-flight message");
        assert_eq!(c.inboxes().pending(NodeId(0)), 0, "both delivered in one batch");
        let (delivered, drains) = c.inboxes().delivery_stats();
        assert_eq!((delivered, drains), (2, 1), "batched delivery: two messages, one drain");
    }
}
