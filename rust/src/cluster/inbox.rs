//! Sharded per-node message inboxes with batched virtual-time delivery.
//!
//! The transport refactor behind every heavy-traffic claim: instead of a
//! global funnel, each node owns an inbox behind its own lock (lock
//! striping at the destination-node grain). Senders [`post`] envelopes
//! with a precomputed arrival deadline; any thread that reaches that
//! deadline [`drain_due`]s the whole batch of due envelopes in one lock
//! acquisition. Arrival deadlines are clamped so messages between the
//! same sender–receiver node pair never overtake each other (FIFO per
//! pair — the link-order guarantee Java RMI over TCP gives the paper's
//! evaluation cluster), while messages on different pairs stay fully
//! independent.
//!
//! Wake-ups coalesce on the [`VirtualClock`](crate::clock::VirtualClock)
//! for free: posting threads sleep to *absolute arrival deadlines*
//! ([`Clock::sleep_until`](crate::clock::Clock::sleep_until)), and the
//! clock's deadline heap already advances equal deadlines in a single
//! step, so a burst of messages to one node costs one simulated advance
//! and one batched drain instead of one wake-up per message.
//!
//! [`post`]: ShardedInboxes::post
//! [`drain_due`]: ShardedInboxes::drain_due

use super::NodeId;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// One message in flight between two nodes.
#[derive(Debug, Clone, Copy)]
pub struct Envelope {
    /// Sending node.
    pub from: NodeId,
    /// Destination node (which inbox shard the envelope sits in).
    pub to: NodeId,
    /// Payload size, for accounting and trace events.
    pub bytes: usize,
    /// Cluster-clock time the message was sent.
    pub sent_at: Duration,
    /// Effective arrival deadline: `sent_at + delay`, clamped so this
    /// envelope never arrives before an earlier one from the same sender.
    pub arrives_at: Duration,
    /// Global post order; ties on `arrives_at` deliver in post order.
    pub seq: u64,
    /// Caller-defined payload tag (0 for the blocking cluster paths; the
    /// megascale discrete-event engine encodes client/op identity here).
    pub tag: u64,
}

/// Batch buffers kept per node for reuse. Two is enough for the blocking
/// transport (one drain in flight per node at a time); a little headroom
/// covers racing drainers without hoarding memory.
const FREE_LIST_CAP: usize = 4;

/// One node's inbox: pending envelopes sorted by `(arrives_at, seq)`,
/// plus the per-sender FIFO clamp state.
#[derive(Debug, Default)]
struct NodeInbox {
    pending: Vec<Envelope>,
    /// Latest arrival deadline handed out per sending node: the FIFO
    /// floor for that sender's next envelope.
    last_arrival: HashMap<u16, Duration>,
    delivered: u64,
    /// Non-empty drains, for the batching-factor metric.
    drains: u64,
    /// Free list of drained batch buffers ([`ShardedInboxes::recycle`]):
    /// the RPC hot path drains one batch per message leg, so without
    /// reuse every leg allocates (and soon frees) a `Vec`. Capped at
    /// [`FREE_LIST_CAP`] buffers.
    free: Vec<Vec<Envelope>>,
    /// Drains served from the free list vs. fresh allocations, for the
    /// pooling micro-bench (`BENCH_micro.json` → `inbox_pool`).
    pool_hits: u64,
    pool_allocs: u64,
}

/// Lock-striped per-node inboxes: one [`Mutex`] per destination node, so
/// traffic to different nodes never contends on a shared structure.
#[derive(Debug)]
pub struct ShardedInboxes {
    shards: Vec<Mutex<NodeInbox>>,
    seq: AtomicU64,
}

/// Poison-tolerant lock: a shard stays usable even if a panicking thread
/// died while holding it (the inbox state is a sorted Vec plus counters —
/// always structurally valid between mutations).
fn lock_shard(m: &Mutex<NodeInbox>) -> MutexGuard<'_, NodeInbox> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl ShardedInboxes {
    /// Inboxes for a cluster of `nodes` nodes.
    pub fn new(nodes: u16) -> Self {
        ShardedInboxes {
            shards: (0..nodes).map(|_| Mutex::new(NodeInbox::default())).collect(),
            seq: AtomicU64::new(0),
        }
    }

    /// Post an envelope from `from` to `to`'s inbox and return its
    /// effective arrival deadline: `sent_at + delay`, raised to the
    /// latest arrival already promised for the same sender–receiver pair
    /// (messages on one pair never overtake; equal deadlines keep post
    /// order via `seq`).
    pub fn post(
        &self,
        from: NodeId,
        to: NodeId,
        bytes: usize,
        sent_at: Duration,
        delay: Duration,
        tag: u64,
    ) -> Duration {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let mut inbox = lock_shard(&self.shards[to.0 as usize]);
        let mut arrives_at = sent_at + delay;
        if let Some(&floor) = inbox.last_arrival.get(&from.0) {
            arrives_at = arrives_at.max(floor);
        }
        inbox.last_arrival.insert(from.0, arrives_at);
        // `seq` is globally increasing, so among equal deadlines the new
        // envelope sorts last: insert before the first strictly-later one.
        let at = inbox.pending.partition_point(|e| e.arrives_at <= arrives_at);
        inbox.pending.insert(at, Envelope { from, to, bytes, sent_at, arrives_at, seq, tag });
        arrives_at
    }

    /// Remove and return every envelope at `to` whose arrival deadline is
    /// `<= now`, in `(arrives_at, seq)` order — the whole due batch under
    /// a single lock acquisition. The returned buffer comes from the
    /// node's free list when one is available; hand it back with
    /// [`ShardedInboxes::recycle`] after processing to keep the hot path
    /// allocation-free.
    pub fn drain_due(&self, to: NodeId, now: Duration) -> Vec<Envelope> {
        let mut inbox = lock_shard(&self.shards[to.0 as usize]);
        let cut = inbox.pending.partition_point(|e| e.arrives_at <= now);
        if cut == 0 {
            return Vec::new();
        }
        let mut due = match inbox.free.pop() {
            Some(buf) => {
                inbox.pool_hits += 1;
                buf
            }
            None => {
                inbox.pool_allocs += 1;
                Vec::with_capacity(cut)
            }
        };
        due.extend_from_slice(&inbox.pending[..cut]);
        // In-place shift of the not-yet-due tail: no allocation, unlike
        // the old `split_off`, which manufactured a fresh `Vec` per drain.
        inbox.pending.drain(..cut);
        inbox.delivered += due.len() as u64;
        inbox.drains += 1;
        due
    }

    /// Return a drained batch buffer to `to`'s free list for reuse by a
    /// later [`ShardedInboxes::drain_due`]. Buffers beyond
    /// [`FREE_LIST_CAP`] (or with no backing allocation) are dropped.
    pub fn recycle(&self, to: NodeId, mut batch: Vec<Envelope>) {
        if batch.capacity() == 0 {
            return;
        }
        batch.clear();
        let mut inbox = lock_shard(&self.shards[to.0 as usize]);
        if inbox.free.len() < FREE_LIST_CAP {
            inbox.free.push(batch);
        }
    }

    /// `(free-list hits, fresh allocations)` summed over all inboxes —
    /// the pooling effectiveness metric (`BENCH_micro.json` → `inbox_pool`).
    pub fn pool_stats(&self) -> (u64, u64) {
        let mut hits = 0;
        let mut allocs = 0;
        for shard in &self.shards {
            let inbox = lock_shard(shard);
            hits += inbox.pool_hits;
            allocs += inbox.pool_allocs;
        }
        (hits, allocs)
    }

    /// Earliest pending arrival deadline at `to`, if any — the wake-up
    /// target for a thread that wants to deliver `to`'s next batch.
    pub fn earliest(&self, to: NodeId) -> Option<Duration> {
        lock_shard(&self.shards[to.0 as usize]).pending.first().map(|e| e.arrives_at)
    }

    /// Number of envelopes currently in flight toward `to`.
    pub fn pending(&self, to: NodeId) -> usize {
        lock_shard(&self.shards[to.0 as usize]).pending.len()
    }

    /// `(messages delivered, non-empty drains)` summed over all inboxes.
    /// `delivered / drains` is the batching factor: how many messages the
    /// average successful drain handed over in one lock acquisition.
    pub fn delivery_stats(&self) -> (u64, u64) {
        let mut delivered = 0;
        let mut drains = 0;
        for shard in &self.shards {
            let inbox = lock_shard(shard);
            delivered += inbox.delivered;
            drains += inbox.drains;
        }
        (delivered, drains)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: Duration = Duration::from_millis(1);

    #[test]
    fn fifo_per_pair_clamps_small_message_behind_big_one() {
        let ib = ShardedInboxes::new(2);
        // Big payload sent first: arrives late.
        let a1 = ib.post(NodeId(0), NodeId(1), 10_000, Duration::ZERO, 50 * MS, 0);
        // Tiny payload sent later on the same pair: would arrive earlier,
        // must be clamped to the big one's arrival (no overtaking).
        let a2 = ib.post(NodeId(0), NodeId(1), 10, MS, 2 * MS, 0);
        assert_eq!(a1, 50 * MS);
        assert_eq!(a2, 50 * MS, "same-pair FIFO: clamped to the earlier arrival");
        let due = ib.drain_due(NodeId(1), 50 * MS);
        assert_eq!(due.len(), 2);
        assert!(due[0].seq < due[1].seq, "equal deadlines deliver in post order");
        assert_eq!(due[0].bytes, 10_000, "the first-posted message is first");
    }

    #[test]
    fn different_pairs_do_not_clamp_each_other() {
        let ib = ShardedInboxes::new(3);
        let slow = ib.post(NodeId(0), NodeId(2), 10_000, Duration::ZERO, 50 * MS, 0);
        let fast = ib.post(NodeId(1), NodeId(2), 10, Duration::ZERO, 2 * MS, 0);
        assert_eq!(slow, 50 * MS);
        assert_eq!(fast, 2 * MS, "a different sender is an independent FIFO lane");
        let due = ib.drain_due(NodeId(2), 10 * MS);
        assert_eq!(due.len(), 1, "only the fast lane's message is due");
        assert_eq!(due[0].from, NodeId(1));
        assert_eq!(ib.pending(NodeId(2)), 1);
    }

    #[test]
    fn drain_returns_whole_due_batch_in_deadline_order() {
        let ib = ShardedInboxes::new(4);
        ib.post(NodeId(1), NodeId(0), 1, Duration::ZERO, 30 * MS, 0);
        ib.post(NodeId(2), NodeId(0), 2, Duration::ZERO, 10 * MS, 0);
        ib.post(NodeId(3), NodeId(0), 3, Duration::ZERO, 20 * MS, 0);
        ib.post(NodeId(2), NodeId(0), 4, Duration::ZERO, 99 * MS, 0);
        assert_eq!(ib.earliest(NodeId(0)), Some(10 * MS));
        let due = ib.drain_due(NodeId(0), 30 * MS);
        let order: Vec<usize> = due.iter().map(|e| e.bytes).collect();
        assert_eq!(order, vec![2, 3, 1], "one drain, deadline order");
        assert_eq!(ib.pending(NodeId(0)), 1, "the 99 ms envelope is not yet due");
        let (delivered, drains) = ib.delivery_stats();
        assert_eq!((delivered, drains), (3, 1), "three messages in one batched drain");
    }

    #[test]
    fn recycled_batch_buffers_are_reused() {
        let ib = ShardedInboxes::new(1);
        for round in 0..5u64 {
            ib.post(NodeId(0), NodeId(0), 8, Duration::ZERO, MS, round);
            let due = ib.drain_due(NodeId(0), MS);
            assert_eq!(due.len(), 1);
            ib.recycle(NodeId(0), due);
        }
        let (hits, allocs) = ib.pool_stats();
        assert_eq!(allocs, 1, "only the first drain allocates");
        assert_eq!(hits, 4, "every later drain reuses the recycled buffer");
        // Recycling a zero-capacity batch (the empty-drain fast path) is a
        // no-op rather than a free-list entry.
        ib.recycle(NodeId(0), Vec::new());
    }

    #[test]
    fn empty_drain_is_free_and_uncounted() {
        let ib = ShardedInboxes::new(1);
        assert!(ib.drain_due(NodeId(0), Duration::from_secs(1)).is_empty());
        assert_eq!(ib.delivery_stats(), (0, 0));
        assert_eq!(ib.earliest(NodeId(0)), None);
    }
}
