//! Fault tolerance mechanisms (paper §3.4).
//!
//! Two failure classes:
//!
//!  * **Remote object failures** — crash-stop: the object disappears; every
//!    later call raises `TxError::ObjectCrashed`. Injected with
//!    [`AtomicRmi2::crash_object`]; the programmer handles the exception
//!    (rerun, compensate).
//!
//!  * **Transaction failures** — a client crashes mid-transaction, leaving
//!    objects acquired and other transactions blocked. The [`Detector`]
//!    plays the paper's server-side role: each object watches whether its
//!    current transaction is still responding; on timeout the object
//!    "performs a rollback on itself: it reverts its state and releases
//!    itself". If the crash was illusory and the client resumes, its next
//!    call on the rolled-back object is refused and the transaction is
//!    forced to abort — exactly the paper's resolution.
//!
//! Eviction is only performed when the suspect's commit condition holds
//! (it is the next transaction in termination order for that object), so
//! `lv`/`ltv` remain consistent; a chain of crashed transactions is
//! cleaned up over successive scans.

use crate::clock::Clock;
use crate::optsva::AtomicRmi2;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Background transaction-failure detector for an [`AtomicRmi2`] system.
pub struct Detector {
    stop: Arc<AtomicBool>,
    evictions: Arc<AtomicU64>,
    thread: Option<JoinHandle<()>>,
}

impl Detector {
    /// Start scanning `sys` every `scan_every`; a transaction is suspected
    /// once it has not dispatched to an object for `suspect_after`. Both
    /// intervals are measured on the system's cluster clock.
    ///
    /// **Virtual-clock caveat:** a background detector *drives* simulated
    /// time forward (each scan sleep advances the clock), so real-time
    /// gaps in a live client's call stream get compressed into large
    /// simulated staleness and the client can be falsely suspected. On a
    /// virtual clock prefer driving detection explicitly with
    /// [`Detector::scan`] after advancing the clock, and reserve
    /// `Detector::start` for real-clock systems.
    pub fn start(sys: Arc<AtomicRmi2>, suspect_after: Duration, scan_every: Duration) -> Detector {
        let stop = Arc::new(AtomicBool::new(false));
        let evictions = Arc::new(AtomicU64::new(0));
        let (stop2, evictions2) = (Arc::clone(&stop), Arc::clone(&evictions));
        let clock = Arc::clone(sys.cluster().clock());
        let thread = std::thread::Builder::new()
            .name("fault-detector".into())
            .spawn(move || {
                while !stop2.load(Ordering::Acquire) {
                    evictions2
                        .fetch_add(Self::scan(&sys, suspect_after), Ordering::Relaxed);
                    clock.sleep(scan_every);
                }
            })
            .expect("spawn fault detector");
        Detector { stop, evictions, thread: Some(thread) }
    }

    /// One synchronous pass (also used directly by tests): evict every
    /// live, stale, commit-ready proxy. Returns the eviction count.
    pub fn scan(sys: &AtomicRmi2, suspect_after: Duration) -> u64 {
        let mut evicted = 0;
        for slot in sys.all_slots() {
            let mut active = slot.active.lock().unwrap();
            // Prune proxies whose transactions are gone or finished.
            active.retain(|w| {
                w.upgrade().map(|p| !p.terminated()).unwrap_or(false)
            });
            let stale: Vec<_> = active
                .iter()
                .filter_map(|w| w.upgrade())
                .filter(|p| {
                    !p.is_evicted() && p.staleness() > suspect_after && p.evictable()
                })
                .collect();
            drop(active);
            for p in stale {
                p.evict();
                evicted += 1;
            }
        }
        if crate::trace::enabled() {
            // Node 0 stands in for the detector itself (it scans the whole
            // system, not one node).
            crate::trace::emit(0, crate::trace::EventKind::FaultScan { evicted });
        }
        evicted
    }

    /// Total objects rolled back so far.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Stop the detector thread.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Detector {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Suprema, TxCtx, TxError};
    use crate::cluster::{Cluster, NetworkModel, NodeId};
    use crate::object::{account::ops, Account};
    use crate::optsva::OptsvaConfig;

    /// Fault machinery runs on a *virtual* clock: staleness accrues by
    /// advancing simulated time, so none of these tests really sleeps.
    fn sys() -> Arc<AtomicRmi2> {
        let cluster = Arc::new(Cluster::new_virtual(1, NetworkModel::instant()));
        AtomicRmi2::with_config(
            cluster,
            OptsvaConfig { wait_timeout: Some(Duration::from_secs(5)), asynchrony: true },
        )
    }

    #[test]
    fn crashed_client_objects_roll_themselves_back() {
        let sys = sys();
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(100)));

        // "Crash" a client mid-transaction: modify A, never commit, leak.
        let mut tx = sys.tx(NodeId(0));
        let h = tx.updates("A", 2);
        tx.begin().unwrap();
        tx.call(h, ops::withdraw(60)).unwrap();
        std::mem::forget(tx); // no Drop rollback: a real crash

        sys.cluster().clock().sleep(Duration::from_millis(30));
        let n = Detector::scan(&sys, Duration::from_millis(10));
        assert_eq!(n, 1, "the abandoned object must be evicted");
        // State reverted, object released: a new transaction proceeds.
        assert_eq!(sys.with_object(a, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()), 100);
        let mut t2 = sys.tx(NodeId(0));
        let h2 = t2.updates("A", 1);
        t2.run(|t| {
            t.call(h2, ops::deposit(1))?;
            Ok(())
        })
        .unwrap();
        sys.shutdown();
    }

    #[test]
    fn illusory_crash_forces_the_returning_transaction_to_abort() {
        let sys = sys();
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(100)));
        let mut tx = sys.tx(NodeId(0));
        let h = tx.updates("A", 2);
        tx.begin().unwrap();
        tx.call(h, ops::withdraw(60)).unwrap();

        // The detector (too aggressively) suspects the client.
        sys.cluster().clock().sleep(Duration::from_millis(30));
        assert_eq!(Detector::scan(&sys, Duration::from_millis(10)), 1);

        // The client was actually alive; its next call must be refused.
        let err = tx.call(h, ops::deposit(1)).unwrap_err();
        assert!(matches!(err, TxError::ForcedAbort(_)), "got {err:?}");
        // commit must also fail
        let err = tx.commit().unwrap_err();
        assert!(matches!(err, TxError::ForcedAbort(_)));
        sys.shutdown();
    }

    #[test]
    fn responsive_transactions_are_not_evicted() {
        let sys = sys();
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
        let mut tx = sys.tx(NodeId(0));
        let h = tx.updates("A", 3);
        tx.begin().unwrap();
        tx.call(h, ops::deposit(1)).unwrap();
        // Recently active ⇒ a scan with a generous timeout evicts nothing.
        assert_eq!(Detector::scan(&sys, Duration::from_secs(10)), 0);
        tx.call(h, ops::deposit(1)).unwrap();
        tx.call(h, ops::deposit(1)).unwrap();
        tx.commit().unwrap();
        sys.shutdown();
    }

    #[test]
    fn background_detector_unblocks_waiters() {
        // Real clock on purpose: with a background detector driving
        // virtual time forward at CPU speed, a client could be suspected
        // in the gap between its begin() and first call. Wall-clock
        // staleness keeps the suspicion threshold meaningful here.
        let cluster = Arc::new(Cluster::new(1, NetworkModel::instant()));
        let sys = AtomicRmi2::with_config(
            cluster,
            OptsvaConfig { wait_timeout: Some(Duration::from_secs(5)), asynchrony: true },
        );
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
        let det = Detector::start(
            Arc::clone(&sys),
            Duration::from_millis(40),
            Duration::from_millis(10),
        );

        // Crash one client holding A…
        let mut dead = sys.tx(NodeId(0));
        let hd = dead.updates("A", 2);
        dead.begin().unwrap();
        dead.call(hd, ops::deposit(7)).unwrap();
        std::mem::forget(dead);

        // …a second client still gets through once the detector fires.
        let mut t2 = sys.tx(NodeId(0));
        let h2 = t2.updates("A", 1);
        t2.begin().unwrap();
        t2.call(h2, ops::deposit(1)).unwrap();
        t2.commit().unwrap();
        assert!(det.evictions() >= 1);
        det.stop();
        let oid = sys.cluster().registry.locate("A").unwrap();
        assert_eq!(sys.with_object(oid, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()), 1);
        sys.shutdown();
    }

    #[test]
    fn crash_stop_object_failure_raises() {
        let sys = sys();
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
        let mut tx = sys.tx(NodeId(0));
        let h = tx.updates("A", 1);
        tx.begin().unwrap();
        sys.crash_object(a);
        let err = tx.call(h, ops::deposit(1)).unwrap_err();
        assert_eq!(err, TxError::ObjectCrashed(a));
        let _ = tx.abort();
        sys.shutdown();
    }
}
