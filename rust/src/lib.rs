//! # Atomic RMI 2 — OptSVA-CF distributed transactional memory
//!
//! A from-scratch reproduction of *"Atomic RMI 2: Highly Parallel
//! Pessimistic Distributed Transactional Memory"* (Siek & Wojciechowski,
//! CS.DC 2016): a control-flow-model DTM with pessimistic, abort-free
//! supremum-versioning concurrency control, early release, and
//! asynchronous buffering — plus every baseline the paper evaluates
//! against (SVA, TFA/HyFlow2, distributed mutex/R-W locks in S2PL and 2PL
//! variants, a global lock) and a distributed Eigenbench workload.
//!
//! ## Layout
//!
//! * [`clock`] — swappable time substrate: [`RealClock`] (wall clock) and
//!   [`VirtualClock`] (discrete-event simulated time; latency injection,
//!   timeouts and fault detection with zero real sleeping);
//! * [`cluster`] — simulated distributed substrate (nodes, latency-injected
//!   RPC through sharded per-node inboxes with FIFO-per-pair batched
//!   delivery, name registry);
//! * [`object`] — the complex shared-object model (§2.5): black-box objects
//!   with READ/WRITE/UPDATE-annotated methods;
//! * [`buffers`] — copy & log buffers (§2.6);
//! * [`versioning`] — `pv`/`lv`/`ltv` counters, access & commit conditions,
//!   invalidation marks (§2.1–§2.3);
//! * [`executor`] — per-node (condition, code) task executor (§3.3), plus
//!   the work-stealing [`executor::ExecutorPool`] that drains hundreds of
//!   node shards with a bounded worker set;
//! * [`optsva`] — **the paper's contribution**: OptSVA-CF / Atomic RMI 2
//!   (§2.8, §3), extended with commutativity-aware group grants (see
//!   `docs/COMMUTATIVITY.md`);
//! * [`sva`] — Atomic RMI 1 baseline (operation-agnostic SVA);
//! * [`tfa`] — HyFlow2 stand-in (optimistic Transaction Forwarding, DF);
//! * [`locks`] — distributed lock baselines (Mutex/R-W × S2PL/2PL, GLock);
//! * [`api`] — the framework-polymorphic `Transaction`/`Dtm` API (Fig 8);
//! * [`workload`] — distributed Eigenbench (§4.2) and the megascale
//!   discrete-event extension of fig 11 (`workload::megascale`);
//! * [`metrics`], [`config`], [`checker`], [`faults`] — measurement,
//!   scenario configuration, safety checking, fault injection;
//! * [`bench`] — machine-readable `BENCH_*.json` reports and the CI
//!   regression gate (see `docs/BENCHMARKS.md`);
//! * [`analysis`] — deterministic schedule exploration, last-use-opacity
//!   checking over explored histories, and the declaration lint behind
//!   `atomic-rmi2 check` (see `docs/ANALYSIS.md`);
//! * [`trace`] — virtual-time structured tracing: lifecycle/wait/early-release
//!   events from every layer, wait-at-version histograms, and the
//!   Perfetto trace exporter behind `atomic-rmi2 trace` (see
//!   `docs/OBSERVABILITY.md`);
//! * [`runtime`] — PJRT/XLA loader executing the AOT-compiled Pallas
//!   kernel used by `object::ComputeObject` (CF compute delegation).
//!
//! A map from paper concepts to these modules, with the request lifecycle,
//! lives in `docs/ARCHITECTURE.md`.

#![warn(missing_docs)]

pub mod analysis;
pub mod api;
pub mod bench;
pub mod checker;
pub mod clock;
pub mod config;
pub mod buffers;
pub mod cluster;
pub mod locks;
pub mod executor;
pub mod faults;
pub mod metrics;
pub mod object;
pub mod optsva;
pub mod runtime;
pub mod sva;
pub mod tfa;
pub mod trace;
pub mod util;
pub mod workload;
pub mod versioning;

pub use api::{
    AccessDecl, Dtm, ObjHandle, OpFuture, Suprema, TxBuilder, TxCtx, TxError, TxSpec, TxStats,
};
pub use clock::{Clock, RealClock, VirtualClock};
pub use cluster::{Cluster, NameId, NetworkModel, NodeId, Oid};
pub use optsva::{AtomicRmi2, OptsvaConfig};
