//! PJRT/XLA runtime: loads the AOT-compiled Pallas/JAX artifacts and
//! executes them on the request path — Python is never involved.
//!
//! The build-time half lives in `python/compile/aot.py`: JAX lowers the L2
//! graphs (which call the L1 Pallas kernels) to **HLO text** under
//! `artifacts/`. Here we parse that text (`HloModuleProto::from_text_file`,
//! which reassigns the 64-bit instruction ids jax ≥ 0.5 emits), compile it
//! once on the PJRT CPU client, and cache the executable.
//!
//! The PJRT client lives behind the **`xla` cargo feature** because the
//! `xla` crate is not in the offline mirror. Without the feature (the
//! default) this module compiles a stub whose `load` fails with an
//! actionable error, so `ComputeObject` users fall back to the pure-rust
//! [`SpinBackend`](crate::object::SpinBackend) reference implementation —
//! the same graceful degradation the Python test-suite applies when the
//! PJRT runtime is absent.
//!
//! With the feature, the `xla` crate's client/executable types wrap raw
//! PJRT pointers and are not `Send`/`Sync`, so the runtime owns a
//! dedicated **kernel-server thread** per loaded runtime: callers submit
//! requests over a channel and block on a reply. This serializes kernel
//! execution per hosting node — which is exactly the CF model's semantics
//! (the object's home node does the work) — while keeping the public
//! [`XlaBackend`] `Send + Sync` for use inside `ComputeObject`s.

use std::fmt;
use std::path::Path;
use std::path::PathBuf;

/// Errors from artifact loading / kernel execution.
#[derive(Debug, Clone)]
pub enum RuntimeError {
    /// An artifact file is missing.
    Missing(String),
    /// The PJRT/XLA layer reported an error.
    Xla(String),
    /// The kernel-server thread is gone.
    Stopped,
    /// Input vector length does not match the compiled dimension.
    BadShape { expected: usize, got: usize },
    /// The crate was built without the `xla` feature.
    FeatureDisabled,
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RuntimeError::Missing(p) => {
                write!(f, "artifact missing: {p} (run `make artifacts`)")
            }
            RuntimeError::Xla(e) => write!(f, "xla: {e}"),
            RuntimeError::Stopped => write!(f, "kernel server stopped"),
            RuntimeError::BadShape { expected, got } => {
                write!(f, "bad shape: expected dim {expected}, got {got}")
            }
            RuntimeError::FeatureDisabled => write!(
                f,
                "built without the `xla` cargo feature: PJRT runtime unavailable \
                 (ComputeObject falls back to SpinBackend)"
            ),
        }
    }
}

impl std::error::Error for RuntimeError {}

/// Default artifact directory: `$ATOMIC_RMI2_ARTIFACTS` or `artifacts/`
/// relative to the workspace root.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("ATOMIC_RMI2_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Are the HLO text artifacts present on disk?
pub fn artifact_files_present(dir: &Path) -> bool {
    dir.join("mix.hlo.txt").is_file() && dir.join("digest.hlo.txt").is_file()
}

// The offline mirror cannot vendor the `xla` crate, so the feature flag
// exists without a backing dependency: enabling it needs a manifest edit.
// Fail with an actionable message instead of a raw unresolved-crate error.
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` feature requires the (unvendored) `xla` crate: add it to \
     rust/Cargo.toml [dependencies] and delete this guard"
);

#[cfg(feature = "xla")]
mod pjrt {
    use super::{artifact_files_present, default_artifact_dir, RuntimeError};
    use crate::object::ComputeBackend;
    use std::path::{Path, PathBuf};
    use std::sync::mpsc;
    use std::sync::Mutex;
    use std::thread::JoinHandle;

    enum Request {
        Mix {
            state: Vec<f32>,
            params: Vec<f32>,
            reply: mpsc::Sender<Result<Vec<f32>, RuntimeError>>,
        },
        Digest {
            state: Vec<f32>,
            reply: mpsc::Sender<Result<f32, RuntimeError>>,
        },
        Shutdown,
    }

    /// Handle to a kernel-server thread running compiled XLA executables.
    #[derive(Debug)]
    pub struct XlaRuntime {
        sender: Mutex<mpsc::Sender<Request>>,
        thread: Mutex<Option<JoinHandle<()>>>,
        dim: usize,
    }

    impl XlaRuntime {
        /// The default artifact directory (`ARMI2_ARTIFACT_DIR` override).
        pub fn default_dir() -> PathBuf {
            default_artifact_dir()
        }

        /// Are the artifacts present (lets tests skip gracefully)?
        pub fn artifacts_present(dir: &Path) -> bool {
            artifact_files_present(dir)
        }

        /// Load `mix.hlo.txt` + `digest.hlo.txt` from `dir`, compile on the
        /// PJRT CPU client, and start the kernel-server thread.
        pub fn load(dir: &Path) -> Result<XlaRuntime, RuntimeError> {
            for f in ["mix.hlo.txt", "digest.hlo.txt"] {
                if !dir.join(f).is_file() {
                    return Err(RuntimeError::Missing(dir.join(f).display().to_string()));
                }
            }
            // Parse the manifest for the state dimension (default 64).
            let dim = std::fs::read_to_string(dir.join("manifest.txt"))
                .ok()
                .and_then(|m| {
                    m.lines().find(|l| l.starts_with("digest")).and_then(|l| {
                        l.split('=').nth(1)?.trim().split(',').nth(1)?.trim().parse().ok()
                    })
                })
                .unwrap_or(64);

            // Materialize the mixing matrix W (a runtime input: large
            // constants cannot ride through HLO text — the printer elides
            // them). Same formula as python's w_matrix / rust's SpinBackend.
            let mut w = vec![0f32; dim * dim];
            for (idx, slot) in w.iter_mut().enumerate() {
                *slot = (idx as f32).sin() / dim as f32;
            }

            let mix_path = dir.join("mix.hlo.txt");
            let digest_path = dir.join("digest.hlo.txt");
            let (tx, rx) = mpsc::channel::<Request>();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<(), RuntimeError>>();
            let thread = std::thread::Builder::new()
                .name("xla-kernel-server".into())
                .spawn(move || {
                    kernel_server(&mix_path, &digest_path, dim, w, rx, ready_tx);
                })
                .expect("spawn kernel server");
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    let _ = thread.join();
                    return Err(e);
                }
                Err(_) => return Err(RuntimeError::Stopped),
            }
            Ok(XlaRuntime {
                sender: Mutex::new(tx),
                thread: Mutex::new(Some(thread)),
                dim,
            })
        }

        /// State dimension the loaded artifacts were compiled for.
        pub fn dim(&self) -> usize {
            self.dim
        }

        /// Execute the `mix` artifact: `state' = mix_R(state, params)`.
        pub fn mix(&self, state: &[f32], params: &[f32]) -> Result<Vec<f32>, RuntimeError> {
            if state.len() != self.dim || params.len() != self.dim {
                return Err(RuntimeError::BadShape { expected: self.dim, got: state.len() });
            }
            let (reply, rx) = mpsc::channel();
            self.sender
                .lock()
                .unwrap()
                .send(Request::Mix { state: state.to_vec(), params: params.to_vec(), reply })
                .map_err(|_| RuntimeError::Stopped)?;
            rx.recv().map_err(|_| RuntimeError::Stopped)?
        }

        /// Execute the `digest` artifact: sum of squares of the state.
        pub fn digest(&self, state: &[f32]) -> Result<f32, RuntimeError> {
            if state.len() != self.dim {
                return Err(RuntimeError::BadShape { expected: self.dim, got: state.len() });
            }
            let (reply, rx) = mpsc::channel();
            self.sender
                .lock()
                .unwrap()
                .send(Request::Digest { state: state.to_vec(), reply })
                .map_err(|_| RuntimeError::Stopped)?;
            rx.recv().map_err(|_| RuntimeError::Stopped)?
        }
    }

    impl Drop for XlaRuntime {
        fn drop(&mut self) {
            let _ = self.sender.lock().unwrap().send(Request::Shutdown);
            if let Some(t) = self.thread.lock().unwrap().take() {
                let _ = t.join();
            }
        }
    }

    /// The kernel-server loop: owns the non-Send PJRT objects.
    fn kernel_server(
        mix_path: &Path,
        digest_path: &Path,
        dim: usize,
        w: Vec<f32>,
        rx: mpsc::Receiver<Request>,
        ready: mpsc::Sender<Result<(), RuntimeError>>,
    ) {
        let setup = || -> Result<_, RuntimeError> {
            let client = xla::PjRtClient::cpu().map_err(|e| RuntimeError::Xla(e.to_string()))?;
            let load = |p: &Path| -> Result<xla::PjRtLoadedExecutable, RuntimeError> {
                let proto = xla::HloModuleProto::from_text_file(
                    p.to_str().expect("artifact path is utf-8"),
                )
                .map_err(|e| RuntimeError::Xla(e.to_string()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client.compile(&comp).map_err(|e| RuntimeError::Xla(e.to_string()))
            };
            let mix = load(mix_path)?;
            let digest = load(digest_path)?;
            Ok((client, mix, digest))
        };
        let (_client, mix_exe, digest_exe) = match setup() {
            Ok(v) => {
                let _ = ready.send(Ok(()));
                v
            }
            Err(e) => {
                let _ = ready.send(Err(e));
                return;
            }
        };

        // Perf (§Perf L1/L2): the naive path built a Literal per argument
        // and deep-cloned the 16 KiB W literal on every call (~75 µs/mix).
        // Instead:
        //   * W is uploaded to a device-resident PjRtBuffer once;
        //   * state/params go host→device via `buffer_from_host_buffer`
        //     (no Literal intermediate, no reshape);
        //   * execution uses `execute_b` over buffers.
        let xerr = |e: xla::Error| RuntimeError::Xla(e.to_string());
        let w_buf = match _client.buffer_from_host_buffer::<f32>(&w, &[dim, dim], None) {
            Ok(b) => b,
            Err(e) => {
                // Report via the first request (ready was already signalled).
                let _ = ready.send(Err(xerr(e)));
                return;
            }
        };
        let upload = |v: &[f32]| -> Result<xla::PjRtBuffer, RuntimeError> {
            _client
                .buffer_from_host_buffer::<f32>(v, &[1, dim], None)
                .map_err(xerr)
        };
        let run_b = |exe: &xla::PjRtLoadedExecutable,
                     inputs: &[&xla::PjRtBuffer]|
         -> Result<Vec<f32>, RuntimeError> {
            let out = exe.execute_b(inputs).map_err(xerr)?[0][0]
                .to_literal_sync()
                .map_err(xerr)?;
            // aot.py lowers with return_tuple=True ⇒ unwrap the 1-tuple.
            let t = out.to_tuple1().map_err(xerr)?;
            t.to_vec::<f32>().map_err(xerr)
        };

        while let Ok(req) = rx.recv() {
            match req {
                Request::Mix { state, params, reply } => {
                    let r = upload(&state)
                        .and_then(|s| upload(&params).map(|p| (s, p)))
                        .and_then(|(s, p)| run_b(&mix_exe, &[&s, &p, &w_buf]));
                    let _ = reply.send(r);
                }
                Request::Digest { state, reply } => {
                    let r = upload(&state)
                        .and_then(|s| run_b(&digest_exe, &[&s]))
                        .map(|v| v[0]);
                    let _ = reply.send(r);
                }
                Request::Shutdown => break,
            }
        }
    }

    /// [`ComputeBackend`] over the loaded runtime — plugs into
    /// [`crate::object::ComputeObject`] so shared objects execute real
    /// AOT-compiled kernel work on their home node.
    pub struct XlaBackend {
        rt: XlaRuntime,
    }

    impl XlaBackend {
        /// Load from [`XlaRuntime::default_dir`].
        pub fn load_default() -> Result<XlaBackend, RuntimeError> {
            Ok(XlaBackend { rt: XlaRuntime::load(&XlaRuntime::default_dir())? })
        }

        /// Load artifacts from an explicit directory.
        pub fn load(dir: &Path) -> Result<XlaBackend, RuntimeError> {
            Ok(XlaBackend { rt: XlaRuntime::load(dir)? })
        }
    }

    impl ComputeBackend for XlaBackend {
        fn mix(&self, state: &[f32], params: &[f32]) -> Result<Vec<f32>, String> {
            self.rt.mix(state, params).map_err(|e| e.to_string())
        }

        fn digest(&self, state: &[f32]) -> Result<f32, String> {
            self.rt.digest(state).map_err(|e| e.to_string())
        }

        fn dim(&self) -> usize {
            self.rt.dim()
        }

        fn name(&self) -> &'static str {
            "xla-pjrt"
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::{XlaBackend, XlaRuntime};

#[cfg(not(feature = "xla"))]
mod stub {
    use super::{default_artifact_dir, RuntimeError};
    use crate::object::ComputeBackend;
    use std::path::{Path, PathBuf};

    /// Stub for offline builds: same surface as the PJRT-backed runtime,
    /// but loading always fails with [`RuntimeError::FeatureDisabled`] so
    /// callers (the `pipeline` example, `micro` bench, tests) degrade to
    /// [`crate::object::SpinBackend`].
    #[derive(Debug)]
    pub struct XlaRuntime {
        never: std::convert::Infallible,
    }

    impl XlaRuntime {
        /// The default artifact directory (`ARMI2_ARTIFACT_DIR` override).
        pub fn default_dir() -> PathBuf {
            default_artifact_dir()
        }

        /// Without the `xla` feature the artifacts are unusable even when
        /// present on disk, so report them absent: every caller gates on
        /// this before `load`/`expect`, and gets the SpinBackend path.
        pub fn artifacts_present(_dir: &Path) -> bool {
            false
        }

        /// Always fails with [`RuntimeError::FeatureDisabled`].
        pub fn load(_dir: &Path) -> Result<XlaRuntime, RuntimeError> {
            Err(RuntimeError::FeatureDisabled)
        }

        /// Unreachable (the stub cannot be constructed).
        pub fn dim(&self) -> usize {
            match self.never {}
        }

        /// Unreachable (the stub cannot be constructed).
        pub fn mix(&self, _state: &[f32], _params: &[f32]) -> Result<Vec<f32>, RuntimeError> {
            match self.never {}
        }

        /// Unreachable (the stub cannot be constructed).
        pub fn digest(&self, _state: &[f32]) -> Result<f32, RuntimeError> {
            match self.never {}
        }
    }

    /// Stub backend mirroring [`super::RuntimeError::FeatureDisabled`].
    pub struct XlaBackend {
        rt: XlaRuntime,
    }

    impl XlaBackend {
        /// Always fails with [`RuntimeError::FeatureDisabled`].
        pub fn load_default() -> Result<XlaBackend, RuntimeError> {
            Ok(XlaBackend { rt: XlaRuntime::load(&XlaRuntime::default_dir())? })
        }

        /// Always fails with [`RuntimeError::FeatureDisabled`].
        pub fn load(dir: &Path) -> Result<XlaBackend, RuntimeError> {
            Ok(XlaBackend { rt: XlaRuntime::load(dir)? })
        }
    }

    impl ComputeBackend for XlaBackend {
        fn mix(&self, state: &[f32], params: &[f32]) -> Result<Vec<f32>, String> {
            self.rt.mix(state, params).map_err(|e| e.to_string())
        }

        fn digest(&self, state: &[f32]) -> Result<f32, String> {
            self.rt.digest(state).map_err(|e| e.to_string())
        }

        fn dim(&self) -> usize {
            self.rt.dim()
        }

        fn name(&self) -> &'static str {
            "xla-stub"
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::{XlaBackend, XlaRuntime};

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_load_fails_gracefully() {
        let err = XlaRuntime::load(Path::new("/nonexistent")).unwrap_err();
        assert!(matches!(err, RuntimeError::FeatureDisabled));
        assert!(err.to_string().contains("SpinBackend"), "actionable message: {err}");
        assert!(!XlaRuntime::artifacts_present(&XlaRuntime::default_dir()));
        assert!(XlaBackend::load_default().is_err());
    }

    #[test]
    fn runtime_errors_render() {
        assert!(RuntimeError::Missing("x.hlo.txt".into())
            .to_string()
            .contains("make artifacts"));
        let e = RuntimeError::BadShape { expected: 64, got: 3 };
        assert!(e.to_string().contains("64"));
    }

    #[cfg(feature = "xla")]
    mod with_xla {
        use super::super::*;
        use crate::object::{ComputeBackend, SpinBackend};
        use std::path::PathBuf;

        fn artifacts() -> Option<PathBuf> {
            let dir = XlaRuntime::default_dir();
            if XlaRuntime::artifacts_present(&dir) {
                Some(dir)
            } else {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                None
            }
        }

        #[test]
        fn missing_artifacts_error_is_actionable() {
            let err = XlaRuntime::load(Path::new("/nonexistent")).unwrap_err();
            assert!(err.to_string().contains("make artifacts"));
        }

        #[test]
        fn xla_mix_matches_spin_reference() {
            let Some(dir) = artifacts() else { return };
            let xla = XlaBackend::load(&dir).expect("load artifacts");
            let d = xla.dim();
            let spin = SpinBackend::new(d, 4);
            let state: Vec<f32> = (0..d).map(|i| (i as f32 * 0.1).sin()).collect();
            let params: Vec<f32> = (0..d).map(|i| (i as f32 * 0.05).cos()).collect();
            let got = xla.mix(&state, &params).unwrap();
            let want = spin.mix(&state, &params).unwrap();
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "mix diverged: {g} vs {w}");
            }
        }

        #[test]
        fn xla_digest_matches_spin_reference() {
            let Some(dir) = artifacts() else { return };
            let xla = XlaBackend::load(&dir).expect("load artifacts");
            let d = xla.dim();
            let spin = SpinBackend::new(d, 4);
            let state: Vec<f32> = (0..d).map(|i| 0.01 * i as f32).collect();
            let got = xla.digest(&state).unwrap();
            let want = spin.digest(&state).unwrap();
            assert!((got - want).abs() / want.max(1e-6) < 1e-4, "{got} vs {want}");
        }

        #[test]
        fn backend_is_shared_across_threads() {
            let Some(dir) = artifacts() else { return };
            let xla = std::sync::Arc::new(XlaBackend::load(&dir).unwrap());
            let d = xla.dim();
            let mut handles = vec![];
            for t in 0..4 {
                let xla = std::sync::Arc::clone(&xla);
                handles.push(std::thread::spawn(move || {
                    let state = vec![0.1 * t as f32; d];
                    let params = vec![0.0f32; d];
                    xla.mix(&state, &params).unwrap()
                }));
            }
            for h in handles {
                assert_eq!(h.join().unwrap().len(), d);
            }
        }

        #[test]
        fn bad_shape_is_rejected() {
            let Some(dir) = artifacts() else { return };
            let xla = XlaBackend::load(&dir).unwrap();
            assert!(xla.mix(&[1.0; 3], &[1.0; 3]).is_err());
        }
    }
}
