//! Scenario configuration: a small `key = value` file format plus CLI
//! argument parsing (the offline mirror carries no `clap`/`serde`, so both
//! are hand-rolled and tested here).
//!
//! Example scenario file (see `configs/`):
//!
//! ```text
//! # Fig 10, read-dominated point
//! framework = atomic-rmi2
//! nodes = 4
//! clients_per_node = 8
//! arrays_per_node = 10
//! txns_per_client = 10
//! hot_ops = 10
//! read_pct = 90
//! locality = 0.5
//! op_delay_us = 3000
//! ```

use crate::cluster::NetworkModel;
use crate::workload::{EigenbenchParams, FrameworkKind};
use std::collections::BTreeMap;
use std::time::Duration;

/// Parsed `key = value` map with typed getters.
#[derive(Debug, Clone, Default)]
pub struct KvConfig {
    entries: BTreeMap<String, String>,
}

/// Configuration/argument errors.
#[derive(Debug, Clone, PartialEq)]
pub enum ConfigError {
    /// Line did not parse as `key = value` (line number, offending text).
    Syntax(usize, String),
    /// A value failed its typed conversion (key, reason).
    BadValue(String, String),
    /// `framework =` named no known framework.
    UnknownFramework(String),
    /// File could not be read.
    Io(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Syntax(line, got) => {
                write!(f, "line {line}: expected `key = value`, got {got:?}")
            }
            ConfigError::BadValue(key, why) => write!(f, "key {key:?}: {why}"),
            ConfigError::UnknownFramework(fw) => write!(f, "unknown framework {fw:?}"),
            ConfigError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl KvConfig {
    /// Parse `key = value` lines; `#` starts a comment; blanks ignored.
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut entries = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| ConfigError::Syntax(lineno + 1, raw.to_string()))?;
            entries.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(KvConfig { entries })
    }

    /// Read and [`parse`](Self::parse) a file.
    pub fn load(path: &str) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path).map_err(|e| ConfigError::Io(e.to_string()))?;
        Self::parse(&text)
    }

    /// Raw string value of `key`, if set.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(String::as_str)
    }

    /// Set (or override) `key`.
    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.entries.insert(key.to_string(), value.to_string());
    }

    /// All set keys, sorted.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    fn typed<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, ConfigError>
    where
        T::Err: std::fmt::Display,
    {
        match self.entries.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| ConfigError::BadValue(key.into(), e.to_string())),
        }
    }

    /// Overlay this config onto a default [`EigenbenchParams`].
    pub fn to_eigenbench(&self) -> Result<EigenbenchParams, ConfigError> {
        let mut p = EigenbenchParams::default();
        if let Some(fw) = self.get("framework") {
            p.kind = FrameworkKind::parse(fw)
                .ok_or_else(|| ConfigError::UnknownFramework(fw.to_string()))?;
        }
        if let Some(v) = self.typed::<u16>("nodes")? {
            p.nodes = v;
        }
        if let Some(v) = self.typed::<u32>("clients_per_node")? {
            p.clients_per_node = v;
        }
        if let Some(v) = self.typed::<u32>("arrays_per_node")? {
            p.arrays_per_node = v;
        }
        if let Some(v) = self.typed::<u32>("txns_per_client")? {
            p.txns_per_client = v;
        }
        if let Some(v) = self.typed::<u32>("hot_ops")? {
            p.hot_ops = v;
        }
        if let Some(v) = self.typed::<u32>("mild_ops")? {
            p.mild_ops = v;
        }
        if let Some(v) = self.typed::<u32>("cold_ops")? {
            p.cold_ops = v;
        }
        if let Some(v) = self.typed::<u8>("read_pct")? {
            if v > 100 {
                return Err(ConfigError::BadValue("read_pct".into(), "must be ≤ 100".into()));
            }
            p.read_pct = v;
        }
        if let Some(v) = self.typed::<f64>("locality")? {
            p.locality = v;
        }
        if let Some(v) = self.typed::<usize>("history")? {
            p.history = v;
        }
        if let Some(v) = self.typed::<u64>("op_delay_us")? {
            p.op_delay = Duration::from_micros(v);
        }
        if let Some(v) = self.typed::<u64>("net_one_way_us")? {
            p.net = NetworkModel {
                one_way: Duration::from_micros(v),
                per_kib: p.net.per_kib,
            };
        }
        if let Some(v) = self.typed::<bool>("irrevocable")? {
            p.irrevocable = v;
        }
        if let Some(v) = self.typed::<bool>("pipeline_ops")? {
            p.pipeline_ops = v;
        }
        if let Some(v) = self.typed::<bool>("virtual_time")? {
            p.virtual_time = v;
        }
        if let Some(v) = self.typed::<bool>("trace")? {
            p.trace = v;
        }
        if let Some(v) = self.typed::<u64>("seed")? {
            p.seed = v;
        }
        Ok(p)
    }
}

/// Minimal CLI parser: positionals + `--key value` / `--flag` options.
#[derive(Debug, Clone, Default)]
pub struct CliArgs {
    /// Arguments without a `--` prefix, in order.
    pub positional: Vec<String>,
    /// `--key value` pairs; bare `--flag` maps to `"true"`.
    pub options: BTreeMap<String, String>,
}

impl CliArgs {
    /// Parse an argument iterator (pass `std::env::args().skip(1)`).
    pub fn parse(args: impl IntoIterator<Item = String>) -> Self {
        let mut out = CliArgs::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let next_is_value = it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                if next_is_value {
                    out.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.options.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Value of `--key value`, if given.
    pub fn option(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Was bare `--key` given?
    pub fn flag(&self, key: &str) -> bool {
        self.options.get(key).map(String::as_str) == Some("true")
    }

    /// Fold `--key value` options into a [`KvConfig`] (CLI overrides file).
    pub fn overlay(&self, mut kv: KvConfig) -> KvConfig {
        for (k, v) in &self.options {
            kv.set(k, v);
        }
        kv
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_kv_with_comments() {
        let kv = KvConfig::parse("# hello\nnodes = 8\n\nread_pct=10 # trailing\n").unwrap();
        assert_eq!(kv.get("nodes"), Some("8"));
        assert_eq!(kv.get("read_pct"), Some("10"));
        assert_eq!(kv.keys().count(), 2);
    }

    #[test]
    fn rejects_bad_syntax_and_values() {
        assert!(matches!(KvConfig::parse("nodes 8"), Err(ConfigError::Syntax(1, _))));
        let kv = KvConfig::parse("nodes = eight").unwrap();
        assert!(matches!(kv.to_eigenbench(), Err(ConfigError::BadValue(_, _))));
        let kv = KvConfig::parse("read_pct = 150").unwrap();
        assert!(kv.to_eigenbench().is_err());
        let kv = KvConfig::parse("framework = zaphod").unwrap();
        assert!(matches!(kv.to_eigenbench(), Err(ConfigError::UnknownFramework(_))));
    }

    #[test]
    fn eigenbench_overlay_applies_fields() {
        let kv = KvConfig::parse(
            "framework = hyflow2\nnodes = 8\nclients_per_node = 16\nread_pct = 10\nop_delay_us = 500\nirrevocable = true\npipeline_ops = true\ntrace = true",
        )
        .unwrap();
        let p = kv.to_eigenbench().unwrap();
        assert_eq!(p.kind, FrameworkKind::Tfa);
        assert_eq!(p.nodes, 8);
        assert_eq!(p.clients_per_node, 16);
        assert_eq!(p.read_pct, 10);
        assert_eq!(p.op_delay, Duration::from_micros(500));
        assert!(p.irrevocable);
        assert!(p.pipeline_ops);
        assert!(p.trace);
        // untouched fields keep defaults
        assert_eq!(p.locality, 0.5);
    }

    #[test]
    fn cli_parses_options_flags_positionals() {
        let args = CliArgs::parse(
            ["sweep", "fig10", "--nodes", "4", "--csv", "--seed", "7"]
                .into_iter()
                .map(String::from),
        );
        assert_eq!(args.positional, vec!["sweep", "fig10"]);
        assert_eq!(args.option("nodes"), Some("4"));
        assert!(args.flag("csv"));
        let kv = args.overlay(KvConfig::default());
        assert_eq!(kv.get("seed"), Some("7"));
    }
}
