//! Declaration lint: declared suprema vs. recorded operation usage.
//!
//! The preamble's suprema drive everything in OptSVA-CF: the access
//! condition, the release points, the commit condition. Mis-declaring
//! them is therefore either *unsafe* or *slow*:
//!
//!   * **under-declared** — the body attempted more operations of a mode
//!     than declared. The runtime catches the overflow
//!     (`TxError::SupremaExceeded`) and aborts, so this is correctness-
//!     adjacent: the transaction can never succeed.
//!   * **over-declared** — the supremum is higher than the body ever
//!     uses, so the object is released later than necessary and every
//!     successor waits longer than it has to. Safe, but it surrenders
//!     exactly the parallelism §3 is about.
//!   * **unused** — declared but never touched: the successor chain on
//!     that object serializes behind a transaction that does not need it
//!     at all (the degenerate over-declaration).
//!   * **unbounded** — `Suprema::unknown()` (no supremum): the object is
//!     only released at commit, i.e. early release is disabled for it.
//!
//! Usage is aggregated across all explored schedules per (transaction
//! tag, object): under-declaration is judged against the *maximum* usage
//! seen anywhere; over-declaration only against schedules where the
//! transaction committed (an aborted run may have stopped early, which
//! proves nothing about the declaration).

use crate::api::Suprema;
use crate::object::{Commutes, MethodSpec, Mode};
use std::collections::BTreeMap;

/// Observed per-mode usage of one declaration in one run.
#[derive(Debug, Clone)]
pub struct DeclUsage {
    /// Transaction tag.
    pub tag: String,
    /// Declared object name.
    pub object: String,
    /// Declared suprema.
    pub declared: Suprema,
    /// Read operations attempted (counter value, may exceed the bound).
    pub used_reads: u64,
    /// Write operations attempted.
    pub used_writes: u64,
    /// Update operations attempted.
    pub used_updates: u64,
    /// Did this run of the transaction commit?
    pub committed: bool,
}

/// What a lint diagnostic is about.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LintKind {
    /// Usage exceeded the declared supremum (runtime-error territory).
    UnderDeclared,
    /// The declared supremum was never reached by any committed run.
    OverDeclared,
    /// Declared but never used in any run.
    UnusedDeclaration,
    /// Declared with no bound (`Suprema::unknown()`): early release off.
    UnboundedSupremum,
    /// A commuting-declared method whose mode is `Read`: its return value
    /// observes state, so concurrent group members would see unserialized
    /// intermediate states. Observers cannot commute.
    CommutingObserver,
    /// A `Commutes::Class` method with no inverse: the group path cannot
    /// undo it on abort, so the runtime ignores the declaration.
    CommutingNoInverse,
}

impl LintKind {
    /// Stable lint code (docs/ANALYSIS.md catalogue).
    pub fn code(&self) -> &'static str {
        match self {
            LintKind::UnderDeclared => "under-declared",
            LintKind::OverDeclared => "over-declared",
            LintKind::UnusedDeclaration => "unused-declaration",
            LintKind::UnboundedSupremum => "unbounded-supremum",
            LintKind::CommutingObserver => "commuting-observer",
            LintKind::CommutingNoInverse => "commuting-no-inverse",
        }
    }
}

/// One structured lint diagnostic.
#[derive(Debug, Clone)]
pub struct LintDiagnostic {
    /// Which lint fired.
    pub kind: LintKind,
    /// Transaction tag (interface lints put the *method name* here).
    pub tag: String,
    /// Object name (interface lints put the *type name* here).
    pub object: String,
    /// The mode concerned (`"read"`/`"write"`/`"update"`; `"*"` for
    /// whole-declaration lints).
    pub mode: &'static str,
    /// The declared supremum for that mode (0 for whole-declaration
    /// lints, `u64::MAX` for unbounded).
    pub declared: u64,
    /// Maximum observed usage relevant to the lint.
    pub used: u64,
}

impl std::fmt::Display for LintDiagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.kind {
            LintKind::UnderDeclared => write!(
                f,
                "[under-declared] tx {} on {}: attempted {} {} ops, declared supremum {} — \
                 the transaction cannot succeed",
                self.tag, self.object, self.used, self.mode, self.declared
            ),
            LintKind::OverDeclared => write!(
                f,
                "[over-declared] tx {} on {}: declared {} {} ops but committed runs use at \
                 most {} — the object is released later than necessary (§3 parallelism bug)",
                self.tag, self.object, self.declared, self.mode, self.used
            ),
            LintKind::UnusedDeclaration => write!(
                f,
                "[unused-declaration] tx {} declares {} but never touches it — successors \
                 serialize behind it for nothing",
                self.tag, self.object
            ),
            LintKind::UnboundedSupremum => write!(
                f,
                "[unbounded-supremum] tx {} on {}: no {} bound declared — early release is \
                 disabled for this object",
                self.tag, self.object, self.mode
            ),
            LintKind::CommutingObserver => write!(
                f,
                "[commuting-observer] {}::{} declares a commutativity class but its mode is \
                 {} — an observer's return value depends on chain position, so group members \
                 would see unserialized intermediate state",
                self.object, self.tag, self.mode
            ),
            LintKind::CommutingNoInverse => write!(
                f,
                "[commuting-no-inverse] {}::{} declares Commutes::Class but names no inverse \
                 — aborts cannot be undone by inverse, so the group path ignores the \
                 declaration and the method serializes on the version chain",
                self.object, self.tag
            ),
        }
    }
}

#[derive(Default)]
struct Agg {
    declared: Option<Suprema>,
    max_used: [u64; 3],
    max_used_committed: [u64; 3],
    any_committed: bool,
    any_used: bool,
}

const MODES: [&str; 3] = ["read", "write", "update"];

fn per_mode(s: &Suprema) -> [u64; 3] {
    [s.reads, s.writes, s.updates]
}

/// Aggregate usage records and produce the lint diagnostics, in a stable
/// (tag, object, mode) order.
pub fn lint_declarations(usages: &[DeclUsage]) -> Vec<LintDiagnostic> {
    let mut aggs: BTreeMap<(String, String), Agg> = BTreeMap::new();
    for u in usages {
        let agg = aggs.entry((u.tag.clone(), u.object.clone())).or_default();
        agg.declared.get_or_insert(u.declared);
        let used = [u.used_reads, u.used_writes, u.used_updates];
        for m in 0..3 {
            agg.max_used[m] = agg.max_used[m].max(used[m]);
            if u.committed {
                agg.max_used_committed[m] = agg.max_used_committed[m].max(used[m]);
            }
        }
        agg.any_committed |= u.committed;
        agg.any_used |= used.iter().any(|&c| c > 0);
    }

    let mut out = Vec::new();
    for ((tag, object), agg) in &aggs {
        let declared = per_mode(&agg.declared.expect("aggregate has a declaration"));
        if !agg.any_used {
            out.push(LintDiagnostic {
                kind: LintKind::UnusedDeclaration,
                tag: tag.clone(),
                object: object.clone(),
                mode: "*",
                declared: 0,
                used: 0,
            });
        }
        for m in 0..3 {
            if declared[m] == u64::MAX {
                out.push(LintDiagnostic {
                    kind: LintKind::UnboundedSupremum,
                    tag: tag.clone(),
                    object: object.clone(),
                    mode: MODES[m],
                    declared: u64::MAX,
                    used: agg.max_used[m],
                });
                continue;
            }
            if agg.max_used[m] > declared[m] {
                out.push(LintDiagnostic {
                    kind: LintKind::UnderDeclared,
                    tag: tag.clone(),
                    object: object.clone(),
                    mode: MODES[m],
                    declared: declared[m],
                    used: agg.max_used[m],
                });
            } else if agg.any_committed
                && agg.any_used
                && declared[m] > 0
                && agg.max_used_committed[m] > 0
                && agg.max_used_committed[m] < declared[m]
            {
                out.push(LintDiagnostic {
                    kind: LintKind::OverDeclared,
                    tag: tag.clone(),
                    object: object.clone(),
                    mode: MODES[m],
                    declared: declared[m],
                    used: agg.max_used_committed[m],
                });
            }
        }
    }
    out
}

/// Static pass over one object type's interface: check the commutativity
/// declaration rules of [`crate::object::Commutes`].
///
///   * a commuting method must be *blind* — `Mode::Read` methods return
///     state, so their results depend on chain position and cannot
///     commute ([`LintKind::CommutingObserver`]);
///   * a `Commutes::Class` method must name an inverse, or the runtime
///     cannot undo it on abort and ignores the declaration
///     ([`LintKind::CommutingNoInverse`]). `WithSelf` without an inverse
///     is allowed: it is documentation-only and never routed through a
///     group grant.
pub fn lint_interface(type_name: &str, interface: &[MethodSpec]) -> Vec<LintDiagnostic> {
    let mut out = Vec::new();
    for m in interface {
        let mode = match m.mode {
            Mode::Read => "read",
            Mode::Write => "write",
            Mode::Update => "update",
        };
        if !matches!(m.commutes, Commutes::Never) && m.mode == Mode::Read {
            out.push(LintDiagnostic {
                kind: LintKind::CommutingObserver,
                tag: m.name.to_string(),
                object: type_name.to_string(),
                mode,
                declared: 0,
                used: 0,
            });
        }
        if matches!(m.commutes, Commutes::Class(_)) && m.inverse.is_none() {
            out.push(LintDiagnostic {
                kind: LintKind::CommutingNoInverse,
                tag: m.name.to_string(),
                object: type_name.to_string(),
                mode,
                declared: 0,
                used: 0,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn usage(
        tag: &str,
        object: &str,
        declared: Suprema,
        used: (u64, u64, u64),
        committed: bool,
    ) -> DeclUsage {
        DeclUsage {
            tag: tag.into(),
            object: object.into(),
            declared,
            used_reads: used.0,
            used_writes: used.1,
            used_updates: used.2,
            committed,
        }
    }

    fn kinds_for(diags: &[LintDiagnostic], tag: &str, object: &str) -> Vec<LintKind> {
        diags
            .iter()
            .filter(|d| d.tag == tag && d.object == object)
            .map(|d| d.kind)
            .collect()
    }

    #[test]
    fn exact_declaration_is_clean() {
        let diags =
            lint_declarations(&[usage("t", "a", Suprema::new(1, 0, 1), (1, 0, 1), true)]);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn under_declaration_is_flagged_even_on_aborted_runs() {
        let diags = lint_declarations(&[usage("t", "a", Suprema::updates(1), (0, 0, 2), false)]);
        assert_eq!(kinds_for(&diags, "t", "a"), vec![LintKind::UnderDeclared]);
    }

    #[test]
    fn over_declaration_needs_a_committed_run() {
        // Only aborted runs: usage proves nothing, no over-declaration.
        let aborted = lint_declarations(&[usage("t", "a", Suprema::updates(5), (0, 0, 1), false)]);
        assert!(aborted.is_empty(), "{aborted:?}");
        // A committed run that never gets past 2 of 5: flagged.
        let diags = lint_declarations(&[
            usage("t", "a", Suprema::updates(5), (0, 0, 1), false),
            usage("t", "a", Suprema::updates(5), (0, 0, 2), true),
        ]);
        assert_eq!(kinds_for(&diags, "t", "a"), vec![LintKind::OverDeclared]);
        assert_eq!(diags[0].used, 2);
    }

    #[test]
    fn max_usage_across_runs_suppresses_over_declaration() {
        let diags = lint_declarations(&[
            usage("t", "a", Suprema::updates(2), (0, 0, 1), true),
            usage("t", "a", Suprema::updates(2), (0, 0, 2), true),
        ]);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn unused_and_unbounded_are_flagged() {
        let diags = lint_declarations(&[usage("t", "b", Suprema::unknown(), (0, 0, 0), true)]);
        let kinds = kinds_for(&diags, "t", "b");
        assert!(kinds.contains(&LintKind::UnusedDeclaration), "{diags:?}");
        assert!(kinds.contains(&LintKind::UnboundedSupremum), "{diags:?}");
        // Unbounded modes must not additionally read as over-declared.
        assert!(!kinds.contains(&LintKind::OverDeclared));
    }

    #[test]
    fn commuting_observer_is_flagged() {
        // A read-mode method declared commuting: the tempting `inc`-style
        // mis-declaration the built-in Counter deliberately avoids.
        let iface: &[MethodSpec] = &[
            MethodSpec::new("get", Mode::Read),
            MethodSpec {
                name: "count",
                mode: Mode::Read,
                commutes: Commutes::Class(0),
                inverse: Some("uncount"),
            },
            MethodSpec::commuting("add", Mode::Update, 0, "sub"),
        ];
        let diags = lint_interface("BadCounter", iface);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].kind, LintKind::CommutingObserver);
        assert_eq!(diags[0].tag, "count");
        assert_eq!(diags[0].object, "BadCounter");
        assert!(diags[0].to_string().contains("commuting-observer"));
    }

    #[test]
    fn commuting_class_without_inverse_is_flagged() {
        let iface: &[MethodSpec] = &[MethodSpec {
            name: "add",
            mode: Mode::Update,
            commutes: Commutes::Class(1),
            inverse: None,
        }];
        let diags = lint_interface("T", iface);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].kind, LintKind::CommutingNoInverse);
        // `WithSelf` without an inverse is documentation-only: clean.
        let with_self: &[MethodSpec] = &[MethodSpec {
            name: "push",
            mode: Mode::Write,
            commutes: Commutes::WithSelf,
            inverse: None,
        }];
        assert!(lint_interface("Q", with_self).is_empty());
    }

    #[test]
    fn builtin_interfaces_are_clean() {
        use crate::object::SharedObject;
        for (name, iface) in [
            ("Account", crate::object::Account::with_balance(0).interface()),
            ("Counter", crate::object::Counter::new().interface()),
            ("Queue", crate::object::QueueObject::new().interface()),
        ] {
            assert!(lint_interface(name, iface).is_empty(), "{name}");
        }
    }

    #[test]
    fn diagnostics_render() {
        let diags = lint_declarations(&[usage("t2", "a", Suprema::updates(1), (0, 0, 2), false)]);
        let msg = diags[0].to_string();
        assert!(msg.contains("under-declared") && msg.contains("t2"), "{msg}");
    }
}
