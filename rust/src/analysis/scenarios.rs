//! Built-in explorer scenarios: small multi-transaction programs (2–4
//! transactions over 2–3 objects) distilled from the paper_scenarios and
//! consistency suites, shaped so that the interesting OptSVA-CF machinery
//! — early release at suprema (§2.8.3), read-only asynchronous buffering
//! (§2.8.1), pure-write log buffers (§2.8.4, Fig 5), cascading aborts
//! (§2.7) — all fire under at least some interleavings.
//!
//! Scenario scripts must be valid under *any* private-version order: the
//! explorer schedules `begin` as an ordinary action, so any transaction
//! may acquire its versions first.

use crate::api::Suprema;
use crate::object::account::ops;
use crate::object::OpCall;

/// One shared object a scenario hosts: an [`crate::object::Account`] with
/// a starting balance.
#[derive(Debug, Clone)]
pub struct ObjectSpec {
    /// Registry name.
    pub name: &'static str,
    /// Home node index.
    pub node: u16,
    /// Initial account balance.
    pub initial: i64,
}

/// How a transaction script ends (after its last operation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TxEnd {
    /// Commit (may still be forced to abort by a cascade).
    Commit,
    /// Voluntary abort — the trigger for §2.7 cascades.
    Abort,
}

/// A scripted transaction: declarations, operations in program order
/// (each referencing a declaration by index), and how it ends.
#[derive(Debug, Clone)]
pub struct TxScript {
    /// Tag for histories and diagnostics.
    pub tag: &'static str,
    /// The preamble: (object name, suprema) per declared object.
    pub decls: Vec<(&'static str, Suprema)>,
    /// Operations in program order: (declaration index, call).
    pub steps: Vec<(usize, OpCall)>,
    /// Terminal action.
    pub end: TxEnd,
}

/// A complete explorer scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Scenario name (CLI `--scenario`).
    pub name: &'static str,
    /// One-line description for reports.
    pub description: &'static str,
    /// Hosted objects.
    pub objects: Vec<ObjectSpec>,
    /// Scripted transactions.
    pub txs: Vec<TxScript>,
}

impl Scenario {
    /// Number of cluster nodes the scenario needs.
    pub fn nodes(&self) -> u16 {
        self.objects.iter().map(|o| o.node + 1).max().unwrap_or(1)
    }
}

/// Cross-transfers with a read-only auditor: the bread-and-butter bank
/// workload (consistency suite shape). Exercises early release after the
/// last update and §2.8.1 read-only buffering (the auditor).
fn transfers() -> Scenario {
    Scenario {
        name: "transfers",
        description: "two cross transfers + read-only auditor",
        objects: vec![
            ObjectSpec { name: "a", node: 0, initial: 100 },
            ObjectSpec { name: "b", node: 1, initial: 100 },
        ],
        txs: vec![
            TxScript {
                tag: "t0",
                decls: vec![("a", Suprema::new(1, 0, 1)), ("b", Suprema::updates(1))],
                steps: vec![
                    (0, ops::withdraw(30)),
                    (1, ops::deposit(30)),
                    (0, ops::balance()),
                ],
                end: TxEnd::Commit,
            },
            TxScript {
                tag: "t1",
                decls: vec![("b", Suprema::new(1, 0, 1)), ("a", Suprema::updates(1))],
                steps: vec![
                    (0, ops::withdraw(10)),
                    (1, ops::deposit(10)),
                    (0, ops::balance()),
                ],
                end: TxEnd::Commit,
            },
            TxScript {
                tag: "t2",
                decls: vec![("a", Suprema::reads(1)), ("b", Suprema::reads(1))],
                steps: vec![(0, ops::balance()), (1, ops::balance())],
                end: TxEnd::Commit,
            },
        ],
    }
}

/// A voluntary abort after an early release: under schedules where the
/// reader consumes the early-released state before the rollback, the
/// §2.7 cascade must doom the reader (and its own reader, transitively).
/// This is the scenario that catches the `skip-invalidation` mutation.
fn cascade() -> Scenario {
    Scenario {
        name: "cascade",
        description: "early release + voluntary abort -> cascade",
        objects: vec![
            ObjectSpec { name: "a", node: 0, initial: 100 },
            ObjectSpec { name: "b", node: 1, initial: 100 },
        ],
        txs: vec![
            TxScript {
                tag: "t0",
                decls: vec![("a", Suprema::updates(1))],
                steps: vec![(0, ops::deposit(900))],
                end: TxEnd::Abort,
            },
            TxScript {
                tag: "t1",
                decls: vec![("a", Suprema::reads(1)), ("b", Suprema::updates(1))],
                steps: vec![(0, ops::balance()), (1, ops::deposit(5))],
                end: TxEnd::Commit,
            },
            TxScript {
                tag: "t2",
                decls: vec![("a", Suprema::reads(1)), ("b", Suprema::reads(1))],
                steps: vec![(0, ops::balance()), (1, ops::balance())],
                end: TxEnd::Commit,
            },
        ],
    }
}

/// Update-heavy contention on one object plus a pure-write object: the
/// copy-buffer (stale-read) and log-buffer (Fig 5 asynchronous apply +
/// release) paths. This is the scenario that catches the
/// `premature-release` mutation: releasing `a` one update early leaves
/// `t0`'s copy buffer stale, so its later read diverges from any
/// committed-order replay.
fn async_buffering() -> Scenario {
    Scenario {
        name: "async_buffering",
        description: "copy/log buffer asynchrony under update contention",
        objects: vec![
            ObjectSpec { name: "a", node: 0, initial: 10 },
            ObjectSpec { name: "b", node: 1, initial: 0 },
        ],
        txs: vec![
            TxScript {
                tag: "t0",
                decls: vec![("a", Suprema::new(1, 0, 2))],
                steps: vec![
                    (0, ops::deposit(5)),
                    (0, ops::deposit(7)),
                    (0, ops::balance()),
                ],
                end: TxEnd::Commit,
            },
            TxScript {
                tag: "t1",
                decls: vec![("a", Suprema::new(1, 0, 1))],
                steps: vec![(0, ops::deposit(100)), (0, ops::balance())],
                end: TxEnd::Commit,
            },
            TxScript {
                tag: "t2",
                decls: vec![("b", Suprema::new(1, 1, 0))],
                steps: vec![(0, ops::reset()), (0, ops::balance())],
                end: TxEnd::Commit,
            },
        ],
    }
}

/// Deliberately mis-declared preambles for the declaration lint: an
/// over-declared updater (serializes for nothing, §3), an unused +
/// unbounded declaration, and an under-declared updater that trips the
/// runtime supremum check. The runs themselves stay opaque — the lint
/// diagnostics are warnings, not violations.
fn lint_demo() -> Scenario {
    Scenario {
        name: "lint_demo",
        description: "declaration lint showcase (over/under/unused/unbounded)",
        objects: vec![
            ObjectSpec { name: "a", node: 0, initial: 50 },
            ObjectSpec { name: "b", node: 1, initial: 50 },
        ],
        txs: vec![
            TxScript {
                tag: "t0",
                decls: vec![("a", Suprema::updates(5))],
                steps: vec![(0, ops::deposit(1)), (0, ops::deposit(2))],
                end: TxEnd::Commit,
            },
            TxScript {
                tag: "t1",
                decls: vec![("a", Suprema::reads(2)), ("b", Suprema::unknown())],
                steps: vec![(0, ops::balance())],
                end: TxEnd::Commit,
            },
            TxScript {
                tag: "t2",
                decls: vec![("a", Suprema::updates(1))],
                steps: vec![(0, ops::deposit(3)), (0, ops::deposit(4))],
                end: TxEnd::Commit,
            },
        ],
    }
}

/// Commuting deposits on one hot account: the group-grant path. `t0`
/// (two deposits) and `t1` are update-only commuting transactions; while
/// `t0` is still active between its deposits, `t1` can join its group
/// grant and both hold access concurrently, in either order. `t2`
/// deposits too but also *reads* the balance (declared `reads: 1`),
/// which makes its declaration non-blind — it takes the exclusive chain
/// path. This is the scenario that catches the `bogus-commute` mutation:
/// trusting the method's commutativity class alone routes `t2`'s deposit
/// through the group as well, and its subsequent live read observes
/// co-members' unserialized intermediate state.
fn commute() -> Scenario {
    Scenario {
        name: "commute",
        description: "commuting deposits share a group grant on a hot account",
        objects: vec![ObjectSpec { name: "hot", node: 0, initial: 100 }],
        txs: vec![
            TxScript {
                tag: "t0",
                decls: vec![("hot", Suprema::updates(2))],
                steps: vec![(0, ops::deposit(100)), (0, ops::deposit(20))],
                end: TxEnd::Commit,
            },
            TxScript {
                tag: "t1",
                decls: vec![("hot", Suprema::updates(1))],
                steps: vec![(0, ops::deposit(10))],
                end: TxEnd::Commit,
            },
            TxScript {
                tag: "t2",
                decls: vec![("hot", Suprema::new(1, 0, 1))],
                steps: vec![(0, ops::deposit(1)), (0, ops::balance())],
                end: TxEnd::Commit,
            },
        ],
    }
}

/// Every built-in scenario, in a stable order.
pub fn builtin() -> Vec<Scenario> {
    vec![transfers(), cascade(), async_buffering(), lint_demo(), commute()]
}

/// Look up a built-in scenario by name.
pub fn by_name(name: &str) -> Option<Scenario> {
    builtin().into_iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_scenarios_are_well_formed() {
        let all = builtin();
        assert_eq!(all.len(), 5);
        for s in &all {
            assert!(s.nodes() >= 1);
            assert!(!s.txs.is_empty());
            for tx in &s.txs {
                for (decl_idx, _) in &tx.steps {
                    assert!(
                        *decl_idx < tx.decls.len(),
                        "{}.{}: step references undeclared handle",
                        s.name,
                        tx.tag
                    );
                }
                for (name, _) in &tx.decls {
                    assert!(
                        s.objects.iter().any(|o| o.name == *name),
                        "{}.{}: declaration of unhosted object {name}",
                        s.name,
                        tx.tag
                    );
                }
            }
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("cascade").is_some());
        assert!(by_name("nope").is_none());
    }
}
