//! Schedule exploration and static analysis for OptSVA-CF
//! (`atomic-rmi2 check`).
//!
//! Three coordinated parts (see `docs/ANALYSIS.md`):
//!
//!   * [`explorer`] — a controlled-scheduler harness that runs small
//!     multi-transaction [`scenarios`] under hundreds of seed-derived
//!     schedules (plus depth-bounded delivery-order flips), entirely
//!     deterministic on one thread over virtual time;
//!   * the history checkers in [`crate::checker`] — every explored
//!     schedule's full history is checked for last-use opacity, and
//!     stuck schedules are explained by a wait-for-graph;
//!   * [`lint`] — a static pass over declared suprema vs. recorded
//!     usage, flagging under-declared (unsafe), over-declared
//!     (serializing), unused, and unbounded declarations.
//!
//! Violations are reported with a replayable [`explorer::ScheduleId`];
//! the harness validates itself by catching seeded protocol mutations
//! ([`crate::optsva::ProtocolMutation`]) within the seed budget.

pub mod explorer;
pub mod lint;
pub mod scenarios;

pub use explorer::{
    explore, run_schedule, ExploreConfig, ExploreReport, RunOutcome, ScheduleId, Violation,
};
pub use lint::{lint_declarations, lint_interface, DeclUsage, LintDiagnostic, LintKind};
pub use scenarios::{ObjectSpec, Scenario, TxEnd, TxScript};
