//! Deterministic schedule exploration for OptSVA-CF.
//!
//! The explorer replaces "whatever order threads wake" with an explicit,
//! seed-derived permutation: everything runs on **one** thread, node
//! executors are threadless ([`crate::executor::Executor::manual`]), the
//! network is instant, and time is virtual — so the only source of
//! nondeterminism left is *which enabled action runs next*, and the
//! explorer owns that choice.
//!
//! An **action** is one of: begin a scripted transaction, execute its
//! next operation, finish it (commit/abort), or fire one ready executor
//! task (the asynchronous buffering/release work of §2.8.1/§2.8.4 —
//! the "deliverable messages" of the permutation). An action is
//! **enabled** only if it is guaranteed not to block, which the gates
//! [`crate::optsva::Transaction::call_ready`] /
//! [`crate::optsva::Transaction::finish_ready`] and
//! [`crate::executor::Executor::ready_count`] decide exactly; all of
//! them are monotone under the single-threaded discipline, so an enabled
//! action stays enabled until taken.
//!
//! Each round the explorer draws the next choice from a seed-derived
//! stream ([`ScheduleId`] names the stream), records the full per-run
//! choice trace, and on completion checks the recorded history with
//! [`crate::checker::check_last_use_opacity`]; a stuck round (no enabled
//! action, transactions outstanding) is handed to the wait-for-graph
//! detector instead. Neighborhood exploration (DPOR-lite) re-runs a base
//! schedule's trace up to round `k`, forces the alternative `a` there,
//! and continues seed-derived — `S<seed>~<k>.<a>` replays exactly.

use crate::api::{ObjHandle, TxCtx, TxError};
use crate::checker::{
    check_last_use_opacity, FinalProbe, HistoryTx, OpRecord, TxOutcome, WaitGraph,
};
use crate::cluster::{Cluster, NetworkModel, NodeId};
use crate::object::{account::ops, Account, SharedObject, Value};
use crate::optsva::{AtomicRmi2, OptsvaConfig, ProtocolMutation, Transaction};
use crate::util::prng::Prng;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Duration;

use super::lint::{lint_declarations, lint_interface, DeclUsage, LintDiagnostic};
use super::scenarios::{Scenario, TxEnd, TxScript};

/// Explorer tuning. The defaults satisfy the acceptance bar (≥ 200
/// distinct schedules per scenario) within a couple of seconds.
#[derive(Debug, Clone, Copy)]
pub struct ExploreConfig {
    /// Base seed budget: seeds `0..seeds` are always run.
    pub seeds: u64,
    /// Rounds eligible for delivery-order flips (DPOR-lite depth).
    pub flip_depth: usize,
    /// How many of the first base seeds get neighborhood exploration.
    pub flip_bases: u64,
    /// Hard per-run round cap (runaway/livelock guard).
    pub max_rounds: usize,
    /// Keep drawing seeds (up to 8× `seeds`) until this many distinct
    /// schedules were observed.
    pub min_distinct: usize,
    /// Protocol mutation to run under ([`ProtocolMutation::None`] checks
    /// the real protocol).
    pub mutation: ProtocolMutation,
}

impl Default for ExploreConfig {
    fn default() -> Self {
        ExploreConfig {
            seeds: 256,
            flip_depth: 6,
            flip_bases: 4,
            max_rounds: 10_000,
            min_distinct: 200,
            mutation: ProtocolMutation::None,
        }
    }
}

/// Replayable schedule name: `S<seed>` for a plain seeded run,
/// `S<seed>~<k>.<a>` for its neighborhood flip (replay the base trace to
/// round `k`, force alternative `a`, continue seed-derived).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScheduleId {
    /// The base seed.
    pub base_seed: u64,
    /// Optional delivery-order flip `(round, alternative index)`.
    pub flip: Option<(usize, usize)>,
}

impl ScheduleId {
    /// A plain seeded schedule.
    pub fn seed(base_seed: u64) -> Self {
        ScheduleId { base_seed, flip: None }
    }

    /// Parse the `S<seed>[~<k>.<a>]` spelling (violation reports).
    pub fn parse(s: &str) -> Option<Self> {
        let rest = s.strip_prefix('S')?;
        match rest.split_once('~') {
            None => Some(ScheduleId { base_seed: rest.parse().ok()?, flip: None }),
            Some((seed, flip)) => {
                let (k, a) = flip.split_once('.')?;
                Some(ScheduleId {
                    base_seed: seed.parse().ok()?,
                    flip: Some((k.parse().ok()?, a.parse().ok()?)),
                })
            }
        }
    }
}

impl std::fmt::Display for ScheduleId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "S{}", self.base_seed)?;
        if let Some((k, a)) = self.flip {
            write!(f, "~{k}.{a}")?;
        }
        Ok(())
    }
}

/// A safety violation found in one explored schedule.
#[derive(Debug, Clone)]
pub struct Violation {
    /// The schedule that exhibits it — `atomic-rmi2 check --scenario X
    /// --schedule <this>` replays it exactly.
    pub schedule: String,
    /// What the checker found.
    pub detail: String,
}

/// The result of running one schedule to completion.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    /// Schedule identity (rendered).
    pub schedule: String,
    /// Full rendered history — deterministic: same [`ScheduleId`] ⇒
    /// byte-identical string (the regression property the explorer
    /// rests on).
    pub history: String,
    /// Per-round `(enabled action count, chosen index)` trace.
    pub trace: Vec<(usize, usize)>,
    /// FNV-64 fingerprint of trace + history (distinct-schedule count).
    pub fingerprint: u64,
    /// Checker verdict, if the schedule violated safety.
    pub violation: Option<String>,
    /// Per-declaration usage (lint input).
    pub usages: Vec<DeclUsage>,
    /// Committed transactions in this run.
    pub committed: u64,
    /// Aborted transactions in this run.
    pub aborted: u64,
    /// Operations + probes verified by the opacity checker.
    pub ops_verified: u64,
}

/// Aggregate over a whole exploration.
#[derive(Debug, Clone, Default)]
pub struct ExploreReport {
    /// Scenario name.
    pub scenario: String,
    /// Schedules executed (base seeds + flips).
    pub runs: usize,
    /// Distinct schedule fingerprints observed.
    pub distinct_schedules: usize,
    /// Violations found (capped at 25 samples; see `violations_total`).
    pub violations: Vec<Violation>,
    /// Total violating schedules (uncapped count).
    pub violations_total: usize,
    /// Committed transactions across all runs.
    pub committed: u64,
    /// Aborted transactions across all runs.
    pub aborted: u64,
    /// Operations + probes verified by the opacity checker.
    pub ops_verified: u64,
    /// Declaration lint diagnostics (aggregated across all runs).
    pub lint: Vec<LintDiagnostic>,
}

/// The seed-derived choice stream, with an optional forced prefix for
/// flip schedules.
struct ChoiceStream {
    forced: Vec<usize>,
    alt: Option<usize>,
    prng: Prng,
    round: usize,
}

impl ChoiceStream {
    fn base(seed: u64) -> Self {
        ChoiceStream { forced: Vec::new(), alt: None, prng: Prng::seeded(seed), round: 0 }
    }

    /// Replay `base_trace[..k]`, force alternative `alt` at round `k`,
    /// then continue from a deterministic function of (seed, k, alt).
    fn flip(base_trace: &[(usize, usize)], k: usize, alt: usize, base_seed: u64) -> Self {
        ChoiceStream {
            forced: base_trace.iter().take(k).map(|&(_, c)| c).collect(),
            alt: Some(alt),
            prng: Prng::seeded(base_seed).split(((k as u64) << 32) | alt as u64),
            round: 0,
        }
    }

    fn choose(&mut self, enabled: usize) -> usize {
        let r = self.round;
        self.round += 1;
        if r < self.forced.len() {
            return self.forced[r].min(enabled - 1);
        }
        if r == self.forced.len() {
            if let Some(a) = self.alt {
                return a.min(enabled - 1);
            }
        }
        self.prng.index(enabled)
    }
}

/// One scripted transaction being driven through a schedule.
struct TxRun {
    script: TxScript,
    client: NodeId,
    tx: Option<Transaction>,
    handles: Vec<ObjHandle>,
    next: usize,
    pending_abort: Option<TxError>,
    ops: Vec<OpRecord>,
    outcome: Option<TxOutcome>,
    usages: Vec<DeclUsage>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Action {
    /// Begin transaction `i` (acquire versions, create proxies).
    Begin(usize),
    /// Execute transaction `i`'s next scripted operation.
    Step(usize),
    /// Commit/abort transaction `i`.
    Finish(usize),
    /// Fire the `nth` ready task on node `node`'s executor.
    ExecTask(u16, usize),
}

fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn enabled_actions(runs: &[TxRun], sys: &Arc<AtomicRmi2>, nodes: u16) -> Vec<Action> {
    let mut acts = Vec::new();
    for (i, r) in runs.iter().enumerate() {
        if r.outcome.is_some() {
            continue;
        }
        match &r.tx {
            None => acts.push(Action::Begin(i)),
            Some(tx) => {
                if r.pending_abort.is_some() || r.next >= r.script.steps.len() {
                    if tx.finish_ready() {
                        acts.push(Action::Finish(i));
                    }
                } else {
                    let (d, call) = &r.script.steps[r.next];
                    match tx.call_ready(r.handles[*d], call) {
                        Ok(true) => acts.push(Action::Step(i)),
                        Ok(false) => {}
                        // The call itself will surface the error.
                        Err(_) => acts.push(Action::Step(i)),
                    }
                }
            }
        }
    }
    for n in 0..nodes {
        for nth in 0..sys.executor_of(NodeId(n)).ready_count() {
            acts.push(Action::ExecTask(n, nth));
        }
    }
    acts
}

fn perform(action: Action, runs: &mut [TxRun], sys: &Arc<AtomicRmi2>, commit_seq: &mut u64) {
    match action {
        Action::Begin(i) => {
            let r = &mut runs[i];
            let mut tx = sys.tx(r.client);
            let handles: Vec<ObjHandle> = r
                .script
                .decls
                .iter()
                .map(|(name, sup)| tx.accesses(name, *sup))
                .collect();
            match tx.begin() {
                Ok(()) => {
                    r.tx = Some(tx);
                    r.handles = handles;
                }
                Err(e) => {
                    r.outcome = Some(TxOutcome::Aborted { reason: format!("begin failed: {e}") });
                }
            }
        }
        Action::Step(i) => {
            let r = &mut runs[i];
            let (d, call) = r.script.steps[r.next].clone();
            r.next += 1;
            let name = r.script.decls[d].0;
            let h = r.handles[d];
            match r.tx.as_mut().expect("step on live tx").call(h, call.clone()) {
                Ok(v) => r.ops.push(OpRecord { object: name.into(), call, result: v }),
                Err(e) => r.pending_abort = Some(e),
            }
        }
        Action::Finish(i) => {
            let r = &mut runs[i];
            let mut tx = r.tx.take().expect("finish on live tx");
            // Capture usage before terminating (counters are final here).
            let counts: Vec<(u64, u64, u64)> = r
                .handles
                .iter()
                .map(|&h| tx.proxy(h).counts())
                .collect();
            let outcome = if let Some(e) = r.pending_abort.take() {
                let reason = e.to_string();
                let _ = tx.abort();
                TxOutcome::Aborted { reason }
            } else {
                match r.script.end {
                    TxEnd::Abort => {
                        let _ = tx.abort();
                        TxOutcome::Aborted { reason: "manual abort".into() }
                    }
                    TxEnd::Commit => match tx.commit() {
                        Ok(()) => {
                            let seq = *commit_seq;
                            *commit_seq += 1;
                            TxOutcome::Committed { seq }
                        }
                        Err(e) => TxOutcome::Aborted { reason: e.to_string() },
                    },
                }
            };
            let committed = matches!(outcome, TxOutcome::Committed { .. });
            r.usages = r
                .script
                .decls
                .iter()
                .zip(&counts)
                .map(|((name, sup), &(rc, wc, uc))| DeclUsage {
                    tag: r.script.tag.into(),
                    object: (*name).into(),
                    declared: *sup,
                    used_reads: rc,
                    used_writes: wc,
                    used_updates: uc,
                    committed,
                })
                .collect();
            r.outcome = Some(outcome);
        }
        Action::ExecTask(node, nth) => {
            let fired = sys.executor_of(NodeId(node)).run_ready(nth);
            debug_assert!(fired, "enabled executor task must fire");
        }
    }
}

/// Wait-for edges at a stuck point: a live transaction blocked at the
/// access (commit) condition of an object waits for every earlier-pv
/// transaction on that object that has not released (terminated).
fn build_wait_graph(runs: &[TxRun]) -> WaitGraph {
    // (object name) -> [(tag, pv, released, terminated)]
    let mut holders: BTreeMap<&str, Vec<(&str, u64, bool, bool)>> = BTreeMap::new();
    for r in runs.iter().filter(|r| r.outcome.is_none()) {
        if let Some(tx) = &r.tx {
            for (di, (name, _)) in r.script.decls.iter().enumerate() {
                let p = tx.proxy(r.handles[di]);
                holders.entry(name).or_default().push((
                    r.script.tag,
                    p.pv,
                    p.released(),
                    p.terminated(),
                ));
            }
        }
    }
    let mut g = WaitGraph::new();
    for r in runs.iter().filter(|r| r.outcome.is_none()) {
        let Some(tx) = &r.tx else { continue };
        let finishing = r.pending_abort.is_some() || r.next >= r.script.steps.len();
        for (di, (name, _)) in r.script.decls.iter().enumerate() {
            let p = tx.proxy(r.handles[di]);
            let waits_access = !p.task_done()
                || (!finishing
                    && r.script.steps.get(r.next).is_some_and(|(d, _)| *d == di)
                    && !p.released());
            let waits_commit = finishing && !p.commit_cond_ready();
            if !(waits_access || waits_commit) {
                continue;
            }
            for &(tag, pv, released, terminated) in holders.get(name).into_iter().flatten() {
                if pv >= p.pv {
                    continue;
                }
                if waits_access && !released {
                    g.add(r.script.tag, tag, *name, "access");
                }
                if waits_commit && !terminated {
                    g.add(r.script.tag, tag, *name, "commit");
                }
            }
        }
    }
    g
}

fn render_history(
    scenario: &Scenario,
    id: &ScheduleId,
    runs: &[TxRun],
    probes: &[FinalProbe],
    trace: &[(usize, usize)],
) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "scenario={} schedule={id}", scenario.name);
    for r in runs {
        let outcome = match &r.outcome {
            Some(TxOutcome::Committed { seq }) => format!("committed seq={seq}"),
            Some(TxOutcome::Aborted { reason }) => format!("aborted ({reason})"),
            None => "unfinished".into(),
        };
        let _ = writeln!(out, "{}: {outcome}", r.script.tag);
        for op in &r.ops {
            let args: Vec<String> = op.call.args.iter().map(Value::to_string).collect();
            let _ = writeln!(
                out,
                "  {}.{}({}) -> {}",
                op.object,
                op.call.method,
                args.join(","),
                op.result
            );
        }
    }
    let finals: Vec<String> = probes.iter().map(|p| format!("{}={}", p.object, p.live)).collect();
    let _ = writeln!(out, "final: {}", finals.join(" "));
    let choices: Vec<String> = trace.iter().map(|(e, c)| format!("{e}.{c}")).collect();
    let _ = writeln!(out, "trace: {}", choices.join(" "));
    out
}

fn run_with_chooser(
    scenario: &Scenario,
    mutation: ProtocolMutation,
    mut chooser: ChoiceStream,
    id: &ScheduleId,
    max_rounds: usize,
) -> RunOutcome {
    let nodes = scenario.nodes();
    let cluster = Arc::new(Cluster::new_virtual(nodes, NetworkModel::instant()));
    // A recording trace session stamps events with this run's virtual
    // clock, so the exported timeline is in simulated time.
    if crate::trace::enabled() {
        crate::trace::set_session_clock(Arc::clone(cluster.clock()));
    }
    let sys = AtomicRmi2::for_analysis(
        cluster,
        OptsvaConfig { wait_timeout: Some(Duration::from_secs(30)), asynchrony: true },
        mutation,
    );
    let oids: Vec<_> = scenario
        .objects
        .iter()
        .map(|o| sys.host(NodeId(o.node), o.name, Box::new(Account::with_balance(o.initial))))
        .collect();

    let mut runs: Vec<TxRun> = scenario
        .txs
        .iter()
        .enumerate()
        .map(|(i, script)| TxRun {
            script: script.clone(),
            client: NodeId((i as u16) % nodes),
            tx: None,
            handles: Vec::new(),
            next: 0,
            pending_abort: None,
            ops: Vec::new(),
            outcome: None,
            usages: Vec::new(),
        })
        .collect();

    let mut trace: Vec<(usize, usize)> = Vec::new();
    let mut commit_seq = 0u64;
    let mut stuck: Option<String> = None;
    loop {
        let acts = enabled_actions(&runs, &sys, nodes);
        if acts.is_empty() {
            if runs.iter().all(|r| r.outcome.is_some()) {
                break;
            }
            let graph = build_wait_graph(&runs);
            stuck = Some(match graph.find_cycle() {
                Some(cycle) => {
                    format!("deadlock: cycle {}\n{}", cycle.join(" -> "), graph.render())
                }
                None => format!(
                    "livelock or lost wakeup: no transaction can progress\n{}",
                    graph.render()
                ),
            });
            break;
        }
        if trace.len() >= max_rounds {
            stuck = Some(format!("schedule did not quiesce within {max_rounds} rounds"));
            break;
        }
        let choice = chooser.choose(acts.len());
        trace.push((acts.len(), choice));
        perform(acts[choice], &mut runs, &sys, &mut commit_seq);
    }

    // Force any stragglers down (stuck schedules only): dropping a
    // running transaction aborts it; virtual-time stall escape bounds
    // the commit-condition waits inside that abort.
    for r in &mut runs {
        r.tx = None;
        if r.outcome.is_none() {
            r.outcome =
                Some(TxOutcome::Aborted { reason: "unfinished at stuck schedule".into() });
        }
    }

    // Live final state, probed through object snapshots.
    let probes: Vec<FinalProbe> = scenario
        .objects
        .iter()
        .zip(&oids)
        .map(|(spec, &oid)| {
            let mut snap = sys.with_object(oid, |o| o.snapshot());
            let live = snap.invoke(&ops::balance()).unwrap_or(Value::Unit);
            FinalProbe { object: spec.name.into(), call: ops::balance(), live }
        })
        .collect();

    let history: Vec<HistoryTx> = runs
        .iter()
        .map(|r| HistoryTx {
            tag: r.script.tag.into(),
            ops: r.ops.clone(),
            outcome: r.outcome.clone().expect("all runs finished"),
        })
        .collect();
    let initial: BTreeMap<String, Box<dyn SharedObject>> = scenario
        .objects
        .iter()
        .map(|o| {
            (
                o.name.to_string(),
                Box::new(Account::with_balance(o.initial)) as Box<dyn SharedObject>,
            )
        })
        .collect();

    let mut ops_verified = 0u64;
    let violation = if let Some(s) = stuck {
        Some(s)
    } else {
        match check_last_use_opacity(initial, &history, &probes) {
            Ok(stats) => {
                ops_verified = stats.ops_verified + stats.probes_verified as u64;
                None
            }
            Err(v) => Some(v.to_string()),
        }
    };

    let committed = history
        .iter()
        .filter(|t| matches!(t.outcome, TxOutcome::Committed { .. }))
        .count() as u64;
    let rendered = render_history(scenario, id, &runs, &probes, &trace);
    let fingerprint = fnv64(rendered.as_bytes());
    let usages = runs.iter().flat_map(|r| r.usages.iter().cloned()).collect();
    sys.shutdown();

    RunOutcome {
        schedule: id.to_string(),
        history: rendered,
        trace,
        fingerprint,
        violation,
        usages,
        committed,
        aborted: history.len() as u64 - committed,
        ops_verified,
    }
}

/// Run one named schedule (replay path of `atomic-rmi2 check
/// --schedule`). Flip schedules recompute their base trace first — the
/// id alone is a complete, replayable description.
pub fn run_schedule(
    scenario: &Scenario,
    id: &ScheduleId,
    mutation: ProtocolMutation,
) -> RunOutcome {
    run_schedule_bounded(scenario, id, mutation, ExploreConfig::default().max_rounds)
}

fn run_schedule_bounded(
    scenario: &Scenario,
    id: &ScheduleId,
    mutation: ProtocolMutation,
    max_rounds: usize,
) -> RunOutcome {
    match id.flip {
        None => {
            run_with_chooser(scenario, mutation, ChoiceStream::base(id.base_seed), id, max_rounds)
        }
        Some((k, alt)) => {
            let base = run_with_chooser(
                scenario,
                mutation,
                ChoiceStream::base(id.base_seed),
                &ScheduleId::seed(id.base_seed),
                max_rounds,
            );
            run_with_chooser(
                scenario,
                mutation,
                ChoiceStream::flip(&base.trace, k, alt, id.base_seed),
                id,
                max_rounds,
            )
        }
    }
}

const VIOLATION_SAMPLE_CAP: usize = 25;

fn absorb(report: &mut ExploreReport, seen: &mut BTreeSet<u64>, usages: &mut Vec<DeclUsage>, out: RunOutcome) {
    report.runs += 1;
    seen.insert(out.fingerprint);
    report.committed += out.committed;
    report.aborted += out.aborted;
    report.ops_verified += out.ops_verified;
    if let Some(detail) = out.violation {
        report.violations_total += 1;
        if report.violations.len() < VIOLATION_SAMPLE_CAP {
            report.violations.push(Violation { schedule: out.schedule, detail });
        }
    }
    usages.extend(out.usages);
}

/// Explore `scenario` under `cfg`: base seeds `0..seeds` (extended up to
/// 8× until `min_distinct` distinct schedules were seen), plus
/// depth-bounded delivery-order flips of the first `flip_bases` seeds.
pub fn explore(scenario: &Scenario, cfg: &ExploreConfig) -> ExploreReport {
    let mut report = ExploreReport { scenario: scenario.name.to_string(), ..Default::default() };
    let mut seen: BTreeSet<u64> = BTreeSet::new();
    let mut usages: Vec<DeclUsage> = Vec::new();
    let mut base_traces: Vec<(u64, Vec<(usize, usize)>)> = Vec::new();

    let hard_cap = cfg.seeds.saturating_mul(8).max(cfg.seeds);
    let mut seed = 0u64;
    while seed < cfg.seeds || (seen.len() < cfg.min_distinct && seed < hard_cap) {
        let id = ScheduleId::seed(seed);
        let out = run_with_chooser(
            scenario,
            cfg.mutation,
            ChoiceStream::base(seed),
            &id,
            cfg.max_rounds,
        );
        if seed < cfg.flip_bases {
            base_traces.push((seed, out.trace.clone()));
        }
        absorb(&mut report, &mut seen, &mut usages, out);
        seed += 1;
    }

    for (base_seed, trace) in &base_traces {
        for (k, &(enabled, chosen)) in trace.iter().take(cfg.flip_depth).enumerate() {
            for alt in 0..enabled {
                if alt == chosen {
                    continue;
                }
                let id = ScheduleId { base_seed: *base_seed, flip: Some((k, alt)) };
                let out = run_with_chooser(
                    scenario,
                    cfg.mutation,
                    ChoiceStream::flip(trace, k, alt, *base_seed),
                    &id,
                    cfg.max_rounds,
                );
                absorb(&mut report, &mut seen, &mut usages, out);
            }
        }
    }

    report.distinct_schedules = seen.len();
    report.lint = lint_declarations(&usages);
    // Static interface pass: all scenarios host Accounts, so check its
    // commutativity declarations once per exploration.
    report.lint.extend(lint_interface("Account", Account::with_balance(0).interface()));
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scenarios;

    #[test]
    fn schedule_id_roundtrip() {
        for id in [
            ScheduleId::seed(0),
            ScheduleId::seed(17),
            ScheduleId { base_seed: 17, flip: Some((3, 1)) },
        ] {
            assert_eq!(ScheduleId::parse(&id.to_string()), Some(id));
        }
        assert_eq!(ScheduleId::parse("17"), None);
        assert_eq!(ScheduleId::parse("S17~3"), None);
    }

    #[test]
    fn single_schedule_runs_clean_on_transfers() {
        let s = scenarios::by_name("transfers").unwrap();
        let out = run_schedule(&s, &ScheduleId::seed(1), ProtocolMutation::None);
        assert!(out.violation.is_none(), "{:?}\n{}", out.violation, out.history);
        assert_eq!(out.committed + out.aborted, 3);
        assert!(out.history.contains("final:"));
    }

    #[test]
    fn commute_group_orders_agree_across_schedules() {
        // Property: across ≥ 200 distinct schedules of the `commute`
        // scenario, every intra-group order of the commuting deposits
        // yields the same final balance (100+100+20+10+1 = 231), all
        // three transactions commit, and the opacity verdict is clean.
        let s = scenarios::by_name("commute").unwrap();
        let mut seen = BTreeSet::new();
        let mut seed = 0u64;
        while seen.len() < 200 && seed < 600 {
            let out = run_schedule(&s, &ScheduleId::seed(seed), ProtocolMutation::None);
            assert!(out.violation.is_none(), "S{seed}: {:?}\n{}", out.violation, out.history);
            assert_eq!(out.committed, 3, "S{seed}: not all committed\n{}", out.history);
            assert!(
                out.history.contains("final: hot=231"),
                "S{seed}: schedule-dependent final balance\n{}",
                out.history
            );
            seen.insert(out.fingerprint);
            seed += 1;
        }
        assert!(seen.len() >= 200, "only {} distinct schedules in 600 seeds", seen.len());
    }

    #[test]
    fn bogus_commute_mutation_is_caught_on_commute_scenario() {
        // Trusting the commutativity class alone routes t2's deposit
        // through the group despite its read declaration; its live
        // balance read then observes co-members' unserialized state in
        // at least some schedules.
        let s = scenarios::by_name("commute").unwrap();
        let cfg = ExploreConfig {
            seeds: 96,
            min_distinct: 64,
            mutation: ProtocolMutation::BogusCommute,
            ..ExploreConfig::default()
        };
        let report = explore(&s, &cfg);
        assert!(
            report.violations_total > 0,
            "bogus-commute went undetected over {} runs ({} distinct)",
            report.runs,
            report.distinct_schedules
        );
    }

    #[test]
    fn flip_schedule_replays_deterministically() {
        let s = scenarios::by_name("cascade").unwrap();
        let id = ScheduleId { base_seed: 3, flip: Some((2, 0)) };
        let a = run_schedule(&s, &id, ProtocolMutation::None);
        let b = run_schedule(&s, &id, ProtocolMutation::None);
        assert_eq!(a.history, b.history);
        assert_eq!(a.fingerprint, b.fingerprint);
    }
}
