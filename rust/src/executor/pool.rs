//! Work-stealing executor pool: one task queue (shard) per simulated
//! node, drained by a bounded set of worker threads.
//!
//! The paper runs "one executor thread per JVM" — faithful at 16 nodes,
//! fatal at the 10²–10³ nodes the extended sweeps simulate in one
//! process. The pool keeps the per-node *queues* (shards, so per-node
//! task FIFO order and trace attribution are unchanged) but shares the
//! worker threads: every worker sweeps all shards starting from its own
//! home offset, so a worker whose home shard is idle — or whose peer is
//! parked in a virtual-time sleep inside an action — steals ready tasks
//! from any other shard instead of idling.
//!
//! All shards share one [`Signal`]: version-counter pokes
//! (`ObjectCc::watch`) and submits on any shard wake every parked
//! worker, which then re-sweeps. With `workers == shards` the pool has
//! the same worst-case concurrency as thread-per-node (important for
//! virtual-time latency coalescing); the cap only bites at node counts
//! where thread-per-node would not fit in a process anyway.

use super::{lock_unpoisoned, Executor, Signal};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Upper bound on pool worker threads. Below it the pool behaves exactly
/// like thread-per-node (each worker can park in one node's blocking
/// action while the rest keep draining); above it workers multiplex
/// shards, trading some virtual-time sleep overlap for a bounded thread
/// count at 10²–10³ simulated nodes.
pub const MAX_POOL_WORKERS: usize = 64;

/// A pool of per-node executor shards drained by work-stealing workers.
pub struct ExecutorPool {
    shards: Vec<Arc<Executor>>,
    signal: Arc<Signal>,
    shutdown: Arc<AtomicBool>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl ExecutorPool {
    /// Start a pool with one shard per node and
    /// `min(nodes, MAX_POOL_WORKERS)` workers.
    pub fn start(nodes: usize) -> Arc<ExecutorPool> {
        Self::start_with_workers(nodes, nodes.min(MAX_POOL_WORKERS))
    }

    /// Start a pool with an explicit worker count (tests pin `workers <
    /// nodes` to exercise stealing).
    pub fn start_with_workers(nodes: usize, workers: usize) -> Arc<ExecutorPool> {
        assert!(nodes > 0, "pool needs at least one shard");
        let signal = Arc::new(Signal::new());
        let shards: Vec<Arc<Executor>> =
            (0..nodes).map(|_| Executor::with_signal(Arc::clone(&signal))).collect();
        let shutdown = Arc::new(AtomicBool::new(false));
        let pool = Arc::new(ExecutorPool {
            shards,
            signal: Arc::clone(&signal),
            shutdown: Arc::clone(&shutdown),
            workers: Mutex::new(Vec::new()),
        });
        let mut handles = Vec::with_capacity(workers.max(1));
        for w in 0..workers.max(1) {
            let shards = pool.shards.clone();
            let signal = Arc::clone(&signal);
            let shutdown = Arc::clone(&shutdown);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("executor-pool-{w}"))
                    .spawn(move || worker_loop(w, &shards, &signal, &shutdown))
                    .expect("spawn pool worker"),
            );
        }
        *lock_unpoisoned(&pool.workers) = handles;
        pool
    }

    /// The executor shard serving node `shard` (indexed by `NodeId.0`).
    pub fn executor(&self, shard: usize) -> Arc<Executor> {
        Arc::clone(&self.shards[shard])
    }

    /// Number of shards (simulated nodes).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Number of worker threads draining the shards.
    pub fn worker_count(&self) -> usize {
        lock_unpoisoned(&self.workers).len()
    }

    /// Stop accepting work and join the workers once every shard's queue
    /// has drained (mirrors [`Executor::shutdown`] semantics per shard).
    pub fn shutdown(&self) {
        for shard in &self.shards {
            // Threadless shards: marks the queue shut down, no join.
            shard.shutdown();
        }
        self.shutdown.store(true, Ordering::SeqCst);
        self.signal.poke();
        let workers = std::mem::take(&mut *lock_unpoisoned(&self.workers));
        for h in workers {
            let _ = h.join();
        }
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        // Best-effort: wake the workers so they can observe shutdown; the
        // owner is expected to have called `shutdown` for a clean join.
        self.shutdown.store(true, Ordering::SeqCst);
        self.signal.poke();
    }
}

/// One worker: sweep every shard starting at the worker's home offset
/// (distinct per worker, so uncontended pools degenerate to
/// one-worker-per-shard), run whole ready batches, and park on the
/// shared signal only after a full idle sweep.
fn worker_loop(idx: usize, shards: &[Arc<Executor>], signal: &Signal, shutdown: &AtomicBool) {
    let n = shards.len();
    let mut seen = 0u64;
    loop {
        let mut ran = 0usize;
        for k in 0..n {
            ran += shards[(idx + k) % n].run_all_ready();
        }
        if ran > 0 {
            // A completed task may gate another shard's condition
            // (cross-node operation chains): re-sweep immediately.
            continue;
        }
        if shutdown.load(Ordering::SeqCst) && shards.iter().all(|s| s.pending() == 0) {
            return;
        }
        // Park until any shard is poked; the timeout bounds staleness if
        // a poke races with queue insertion.
        seen = signal.wait_past(seen, Duration::from_millis(50));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn join_within_5s(h: &crate::executor::TaskHandle) {
        let clock = crate::clock::RealClock::shared();
        let deadline = Some(clock.now() + Duration::from_secs(5));
        h.join(clock.as_ref(), deadline).unwrap();
    }

    #[test]
    fn pool_runs_tasks_on_every_shard() {
        let pool = ExecutorPool::start(4);
        assert_eq!(pool.shard_count(), 4);
        let counter = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for shard in 0..4 {
            let c = Arc::clone(&counter);
            handles.push(pool.executor(shard).submit(
                || true,
                move || {
                    c.fetch_add(1, Ordering::SeqCst);
                },
            ));
        }
        for h in &handles {
            join_within_5s(h);
        }
        assert_eq!(counter.load(Ordering::SeqCst), 4);
        pool.shutdown();
    }

    /// Cross-shard work stealing: with a single worker, a chain of tasks
    /// that ping-pongs readiness across shards still completes — the one
    /// worker must pick up ready tasks from every shard, not just its
    /// home shard.
    #[test]
    fn single_worker_steals_across_shards() {
        let pool = ExecutorPool::start_with_workers(8, 1);
        assert_eq!(pool.worker_count(), 1);
        let counter = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        // Task on shard s runs only when counter == s: the readiness
        // cascade hops shards 7→0 in reverse submission order.
        for shard in (0..8u64).rev() {
            let c = Arc::clone(&counter);
            let c2 = Arc::clone(&counter);
            handles.push(pool.executor(shard as usize).submit(
                move || c.load(Ordering::SeqCst) == shard,
                move || {
                    c2.fetch_add(1, Ordering::SeqCst);
                },
            ));
        }
        for h in &handles {
            join_within_5s(h);
        }
        assert_eq!(counter.load(Ordering::SeqCst), 8);
        pool.shutdown();
    }

    /// A panicking task on one shard must not take down the worker or
    /// starve other shards (the pool-level face of the poison-tolerance
    /// satellite).
    #[test]
    fn pool_survives_a_panicking_task() {
        let pool = ExecutorPool::start_with_workers(2, 1);
        let bad = pool.executor(0).submit(|| true, || panic!("shard 0 task panic"));
        join_within_5s(&bad);
        let ran = Arc::new(AtomicU64::new(0));
        let r = Arc::clone(&ran);
        let ok = pool.executor(1).submit(
            || true,
            move || {
                r.fetch_add(1, Ordering::SeqCst);
            },
        );
        join_within_5s(&ok);
        assert_eq!(ran.load(Ordering::SeqCst), 1);
        assert_eq!(pool.executor(0).panicked_tasks(), 1);
        pool.shutdown();
    }
}
