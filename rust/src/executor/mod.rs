//! Per-node executor thread (paper §3.3).
//!
//! "Given the cost of overhead that starting a thread creates, Atomic RMI 2
//! uses one executor thread per JVM. The executor thread is always running
//! and transactions assign it tasks. Each task consists of a condition and
//! code. … Once the thread receives a task, it checks whether it can be
//! immediately executed. If not, it queues up the task and waits until any
//! of the two counters that can impact the condition change value (lv and
//! ltv). When any of the counters change, the thread re-evaluates the
//! relevant conditions and executes the task, if the condition so allows."
//!
//! `ObjectCc` pokes the node's [`Signal`] whenever `lv`/`ltv` change;
//! the executor re-scans its queue on every poke.

pub mod pool;

pub use pool::ExecutorPool;

use crate::clock::{wait_deadline, Clock};
use crate::cluster::NodeId;
use crate::trace::{self, EventKind};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU16, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Poison-tolerant lock acquisition for the executor's internal mutexes.
///
/// A task action that panics unwinds through the executor loop; with
/// plain `lock().unwrap()` that poisons the queue/signal mutexes, every
/// later `submit`/`join`/`shutdown` panics in turn, and `TaskHandle::join`
/// deadlocks across the whole node shard. Every state protected this way
/// is structurally valid between mutations (counters, a task Vec, a done
/// flag), so recovering the guard is always safe.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Generation-counting wakeup signal shared between version counters and
/// the executor loop.
pub struct Signal {
    gen: Mutex<u64>,
    cond: Condvar,
}

impl Default for Signal {
    fn default() -> Self {
        Self::new()
    }
}

impl Signal {
    /// A fresh signal at generation 0.
    pub fn new() -> Self {
        Signal { gen: Mutex::new(0), cond: Condvar::new() }
    }

    /// Wake anyone waiting on the signal.
    pub fn poke(&self) {
        let mut g = lock_unpoisoned(&self.gen);
        *g += 1;
        self.cond.notify_all();
    }

    /// Current generation (monotonically advanced by [`Signal::poke`]).
    pub fn generation(&self) -> u64 {
        *lock_unpoisoned(&self.gen)
    }

    /// Wait until the generation advances past `seen` (or the timeout).
    pub fn wait_past(&self, seen: u64, timeout: Duration) -> u64 {
        let mut g = lock_unpoisoned(&self.gen);
        let deadline = Instant::now() + timeout;
        while *g <= seen {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = self
                .cond
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            g = guard;
        }
        *g
    }
}

/// Completion flag for a scheduled task.
///
/// The `flag` duplicates `done` so [`TaskHandle::is_done`] — polled from
/// executor gate conditions and per-object program-order chains, i.e. the
/// per-operation hot path — is one atomic load instead of a mutex
/// acquisition. `done` + the condvar remain the blocking-join path.
struct TaskDone {
    flag: AtomicBool,
    done: Mutex<bool>,
    cond: Condvar,
}

/// Handle to await a scheduled task's completion (the transaction joins
/// its buffering/release tasks at commit/abort, §2.8.5).
#[derive(Clone)]
pub struct TaskHandle {
    inner: Arc<TaskDone>,
}

impl TaskHandle {
    /// A not-yet-completed handle. Crate-visible so submitters can create
    /// the handle *before* building the action closure that completes it
    /// (see [`Executor::submit_with_handle`]).
    pub(crate) fn new() -> Self {
        TaskHandle {
            inner: Arc::new(TaskDone {
                flag: AtomicBool::new(false),
                done: Mutex::new(false),
                cond: Condvar::new(),
            }),
        }
    }

    /// An already-completed handle — used when asynchrony is disabled
    /// (ablation mode) and the "task" ran inline on the caller's thread.
    pub fn ready() -> Self {
        let h = TaskHandle::new();
        h.complete();
        h
    }

    fn complete(&self) {
        let mut d = lock_unpoisoned(&self.inner.done);
        *d = true;
        // Publish under the mutex, before notify: a joiner that saw
        // `flag == false` is either inside the condvar wait (woken below)
        // or about to re-check `done` under the lock.
        self.inner.flag.store(true, Ordering::Release);
        self.inner.cond.notify_all();
    }

    /// Has the task run? Lock-free; `true` is final (tasks never un-complete).
    pub fn is_done(&self) -> bool {
        self.inner.flag.load(Ordering::Acquire)
    }

    /// Block until the task has run. `deadline` is absolute in `clock`
    /// time; `None` ⇒ wait forever. Poison-tolerant: a panic inside a
    /// *different* joiner cannot wedge this join.
    pub fn join(&self, clock: &dyn Clock, deadline: Option<Duration>) -> Result<(), ()> {
        let mut d = lock_unpoisoned(&self.inner.done);
        while !*d {
            let (g, expired) = wait_deadline(clock, &self.inner.cond, d, deadline);
            d = g;
            if expired && !*d {
                return Err(());
            }
        }
        Ok(())
    }
}

type Cond = Box<dyn Fn() -> bool + Send>;
type Action = Box<dyn FnOnce() + Send>;

struct Task {
    cond: Cond,
    action: Option<Action>,
    handle: TaskHandle,
}

struct ExecutorState {
    queue: Vec<Task>,
    shutdown: bool,
}

/// Sentinel for an executor that was never labeled with a node id.
const UNLABELED: u16 = u16::MAX;

/// One executor per (simulated) node.
pub struct Executor {
    signal: Arc<Signal>,
    state: Mutex<ExecutorState>,
    thread: Mutex<Option<JoinHandle<()>>>,
    /// Node this executor serves, for [`crate::trace`] task events
    /// ([`UNLABELED`] until [`Executor::set_trace_label`] — unlabeled
    /// executors stay silent).
    trace_node: AtomicU16,
    /// Actions that panicked (contained by [`catch_unwind`]; their
    /// handles still completed).
    panics: AtomicU64,
}

impl Executor {
    fn with_parts(signal: Arc<Signal>) -> Executor {
        Executor {
            signal,
            state: Mutex::new(ExecutorState { queue: Vec::new(), shutdown: false }),
            thread: Mutex::new(None),
            trace_node: AtomicU16::new(UNLABELED),
            panics: AtomicU64::new(0),
        }
    }

    /// Spawn the executor thread.
    pub fn spawn() -> Arc<Executor> {
        let exec = Arc::new(Executor::with_parts(Arc::new(Signal::new())));
        let loop_exec = Arc::clone(&exec);
        let handle = std::thread::Builder::new()
            .name("executor".into())
            .spawn(move || loop_exec.run_loop())
            .expect("spawn executor");
        *lock_unpoisoned(&exec.thread) = Some(handle);
        exec
    }

    /// A threadless executor for the schedule explorer (`analysis::`).
    ///
    /// No loop thread is spawned: queued tasks run only when the explorer
    /// explicitly picks one via [`Executor::run_ready`]. That turns each
    /// asynchronous buffering/release task into a first-class scheduling
    /// decision — the "deliverable message" of the permutation — instead
    /// of something the OS thread scheduler fires at an arbitrary moment.
    /// [`Executor::shutdown`] works unchanged (there is no thread to join).
    pub fn manual() -> Arc<Executor> {
        Arc::new(Executor::with_parts(Arc::new(Signal::new())))
    }

    /// A threadless executor driven by an [`ExecutorPool`]: like
    /// [`Executor::manual`] there is no dedicated loop thread, but the
    /// queue is drained by the pool's work-stealing workers, all waiting
    /// on the one `signal` shared across the pool — a version-counter
    /// poke (`ObjectCc::watch`) or a submit on *any* shard wakes them.
    pub(crate) fn with_signal(signal: Arc<Signal>) -> Arc<Executor> {
        Arc::new(Executor::with_parts(signal))
    }

    /// Label this executor with the node it serves so queued/ran tasks can
    /// be attributed in trace sessions ([`crate::trace`]).
    pub(crate) fn set_trace_label(&self, node: NodeId) {
        self.trace_node.store(node.0, Ordering::Relaxed);
    }

    /// Emit a task trace event for this executor's node, if tracing is on
    /// and the executor was labeled. The gate check comes first: a
    /// disabled recorder costs one relaxed atomic load.
    fn t_emit(&self, kind: impl FnOnce(u16) -> EventKind) {
        if trace::enabled() {
            let node = self.trace_node.load(Ordering::Relaxed);
            if node != UNLABELED {
                trace::emit(node, kind(node));
            }
        }
    }

    /// The signal that `ObjectCc::watch` should be given for every object
    /// hosted on this executor's node.
    pub fn signal(&self) -> Arc<Signal> {
        Arc::clone(&self.signal)
    }

    /// Schedule `(condition, action)`. The action runs on the executor
    /// thread the first time the condition is observed true.
    pub fn submit(
        &self,
        cond: impl Fn() -> bool + Send + 'static,
        action: impl FnOnce() + Send + 'static,
    ) -> TaskHandle {
        let handle = TaskHandle::new();
        self.submit_with_handle(handle.clone(), cond, action);
        handle
    }

    /// [`Executor::submit`] with a caller-created [`TaskHandle`]. Lets the
    /// submitter embed the handle in the state the action closure captures
    /// (one shared allocation instead of two) — the handle completes when
    /// the action has run, exactly as with `submit`.
    pub(crate) fn submit_with_handle(
        &self,
        handle: TaskHandle,
        cond: impl Fn() -> bool + Send + 'static,
        action: impl FnOnce() + Send + 'static,
    ) {
        {
            let mut st = lock_unpoisoned(&self.state);
            assert!(!st.shutdown, "submit after shutdown");
            st.queue.push(Task {
                cond: Box::new(cond),
                action: Some(Box::new(action)),
                handle,
            });
        }
        self.t_emit(|node| EventKind::TaskQueue { node });
        self.signal.poke(); // check immediately-runnable tasks
    }

    /// Number of queued (not yet run) tasks.
    pub fn pending(&self) -> usize {
        lock_unpoisoned(&self.state).queue.len()
    }

    /// Number of actions that panicked inside this executor. The panics
    /// are contained ([`catch_unwind`]): the default panic hook still
    /// reports them, their handles complete so joiners never deadlock,
    /// and the executor keeps draining its queue.
    pub fn panicked_tasks(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }

    /// Evaluate a task's condition, containing panics: a condition that
    /// panics is treated as *ready*, so the broken task leaves the queue
    /// through the (also contained) action path instead of poisoning the
    /// queue lock and wedging the shard.
    fn cond_holds(t: &Task) -> bool {
        catch_unwind(AssertUnwindSafe(|| (t.cond)())).unwrap_or(true)
    }

    /// Run one collected action with panic containment: the handle
    /// completes whether or not the action panicked, so `TaskHandle::join`
    /// never deadlocks on a crashed task.
    fn run_action(&self, action: Action, handle: &TaskHandle) {
        self.t_emit(|node| EventKind::TaskRun { node });
        if catch_unwind(AssertUnwindSafe(action)).is_err() {
            self.panics.fetch_add(1, Ordering::Relaxed);
        }
        handle.complete();
    }

    /// Number of queued tasks whose condition currently holds (manual
    /// mode: how many executor actions the explorer may schedule now).
    pub fn ready_count(&self) -> usize {
        let st = lock_unpoisoned(&self.state);
        st.queue.iter().filter(|t| Self::cond_holds(t)).count()
    }

    /// Run the `n`-th currently-ready task (0-based, in submission order
    /// over ready tasks only). Returns `false` if fewer than `n + 1`
    /// tasks are ready. Manual mode's analogue of one `run_loop` firing:
    /// the action runs on the calling thread, outside the queue lock.
    pub fn run_ready(&self, n: usize) -> bool {
        let picked = {
            let mut st = lock_unpoisoned(&self.state);
            let mut ready_seen = 0usize;
            let pos = st.queue.iter().position(|t| {
                if Self::cond_holds(t) {
                    let hit = ready_seen == n;
                    ready_seen += 1;
                    hit
                } else {
                    false
                }
            });
            pos.map(|i| {
                let mut t = st.queue.remove(i);
                (t.action.take().unwrap(), t.handle.clone())
            })
        };
        match picked {
            Some((action, handle)) => {
                self.run_action(action, &handle);
                true
            }
            None => false,
        }
    }

    /// Remove every currently-runnable task from the queue in one lock
    /// pass — the batched collect shared by the spawned loop and the
    /// pool's work-stealing workers.
    fn take_runnable(&self) -> Vec<(Action, TaskHandle)> {
        let mut st = lock_unpoisoned(&self.state);
        let mut runnable: Vec<(Action, TaskHandle)> = Vec::new();
        let mut i = 0;
        while i < st.queue.len() {
            if Self::cond_holds(&st.queue[i]) {
                let mut t = st.queue.remove(i);
                runnable.push((t.action.take().unwrap(), t.handle.clone()));
            } else {
                i += 1;
            }
        }
        runnable
    }

    /// Collect and run every currently-ready task (actions run outside
    /// the queue lock, on the calling thread). Returns how many ran. The
    /// per-shard drain step of [`ExecutorPool`] workers.
    pub fn run_all_ready(&self) -> usize {
        let runnable = self.take_runnable();
        let n = runnable.len();
        for (action, handle) in runnable {
            self.run_action(action, &handle);
        }
        n
    }

    fn run_loop(&self) {
        let mut seen_gen = 0u64;
        loop {
            {
                let st = lock_unpoisoned(&self.state);
                if st.shutdown && st.queue.is_empty() {
                    return;
                }
            }
            // Collect runnable tasks under the lock, run them outside it
            // (actions may take object locks / run kernels).
            if self.run_all_ready() > 0 {
                // A completed task may be exactly what a queued task's
                // condition was gated on (submitted operations chain per
                // object): rescan immediately instead of waiting for a
                // poke or the staleness timeout.
                continue;
            }
            // Sleep until a counter changes or a task arrives; the timeout
            // bounds staleness if a poke races with queue insertion.
            seen_gen = self.signal.wait_past(seen_gen, Duration::from_millis(50));
        }
    }

    /// Stop the executor once its queue drains.
    pub fn shutdown(&self) {
        lock_unpoisoned(&self.state).shutdown = true;
        self.signal.poke();
        if let Some(h) = lock_unpoisoned(&self.thread).take() {
            let _ = h.join();
        }
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        // Best-effort: if the owner forgot to call shutdown, stop the
        // thread without joining (we may be on the executor thread itself).
        lock_unpoisoned(&self.state).shutdown = true;
        self.signal.poke();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::RealClock;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

    /// Join with a generous real-time deadline (test hangs become failures).
    fn join_within_5s(h: &TaskHandle) {
        let clock = RealClock::shared();
        let deadline = Some(clock.now() + Duration::from_secs(5));
        h.join(clock.as_ref(), deadline).unwrap();
    }

    #[test]
    fn immediately_true_condition_runs() {
        let ex = Executor::spawn();
        let ran = Arc::new(AtomicBool::new(false));
        let r = Arc::clone(&ran);
        let h = ex.submit(|| true, move || r.store(true, Ordering::SeqCst));
        join_within_5s(&h);
        assert!(ran.load(Ordering::SeqCst));
        ex.shutdown();
    }

    #[test]
    fn task_waits_for_condition() {
        let ex = Executor::spawn();
        let gate = Arc::new(AtomicBool::new(false));
        let ran = Arc::new(AtomicBool::new(false));
        let (g, r) = (Arc::clone(&gate), Arc::clone(&ran));
        let h = ex.submit(
            move || g.load(Ordering::SeqCst),
            move || r.store(true, Ordering::SeqCst),
        );
        std::thread::sleep(Duration::from_millis(30));
        assert!(!h.is_done(), "must not run before the condition holds");
        gate.store(true, Ordering::SeqCst);
        ex.signal().poke();
        join_within_5s(&h);
        assert!(ran.load(Ordering::SeqCst));
        ex.shutdown();
    }

    #[test]
    fn tasks_run_in_submission_order_when_ready_together() {
        let ex = Executor::spawn();
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut handles = vec![];
        for i in 0..5 {
            let o = Arc::clone(&order);
            handles.push(ex.submit(|| true, move || o.lock().unwrap().push(i)));
        }
        for h in &handles {
            join_within_5s(h);
        }
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
        ex.shutdown();
    }

    #[test]
    fn join_timeout_on_never_true_condition() {
        let ex = Executor::spawn();
        let h = ex.submit(|| false, || {});
        let clock = RealClock::shared();
        let deadline = Some(clock.now() + Duration::from_millis(50));
        let r = h.join(clock.as_ref(), deadline);
        assert!(r.is_err());
        // unblock shutdown: drop the task by flipping shutdown with queue
        // non-empty is fine — run_loop exits only when queue empties, so
        // poke a trivially-true replacement path: directly clear via drop.
        ex.state.lock().unwrap().queue.clear();
        ex.shutdown();
    }

    /// The poison-tolerance satellite: a panicking action must not wedge
    /// `TaskHandle::join`, poison the queue, or stop later tasks from
    /// running on the same executor.
    #[test]
    fn panicking_task_completes_its_handle_and_spares_the_shard() {
        let ex = Executor::spawn();
        let h_bad = ex.submit(|| true, || panic!("task blew up"));
        join_within_5s(&h_bad);
        assert!(h_bad.is_done(), "panicked task still completes (contained)");
        // The executor keeps draining: a later task runs normally.
        let ran = Arc::new(AtomicBool::new(false));
        let r = Arc::clone(&ran);
        let h_ok = ex.submit(|| true, move || r.store(true, Ordering::SeqCst));
        join_within_5s(&h_ok);
        assert!(ran.load(Ordering::SeqCst));
        assert_eq!(ex.panicked_tasks(), 1);
        ex.shutdown();
    }

    /// A panicking *condition* must not poison the queue either: the task
    /// is treated as ready, drained through the contained action path,
    /// and the shard stays live.
    #[test]
    fn panicking_condition_drains_instead_of_poisoning() {
        let ex = Executor::spawn();
        let h = ex.submit(|| panic!("condition blew up"), || {});
        join_within_5s(&h);
        assert!(h.is_done());
        assert_eq!(ex.pending(), 0, "broken task left the queue");
        ex.shutdown();
    }

    #[test]
    fn signal_generation_advances() {
        let s = Signal::new();
        let g = s.generation();
        s.poke();
        assert_eq!(s.generation(), g + 1);
        let waited = s.wait_past(g, Duration::from_millis(10));
        assert!(waited > g);
    }

    #[test]
    fn manual_executor_runs_tasks_only_on_request() {
        let ex = Executor::manual();
        let ran = Arc::new(AtomicU64::new(0));
        let (r1, r2) = (Arc::clone(&ran), Arc::clone(&ran));
        let gate = Arc::new(AtomicBool::new(false));
        let g = Arc::clone(&gate);
        let h1 = ex.submit(
            || true,
            move || {
                r1.fetch_add(1, Ordering::SeqCst);
            },
        );
        let h2 = ex.submit(
            move || g.load(Ordering::SeqCst),
            move || {
                r2.fetch_add(10, Ordering::SeqCst);
            },
        );
        assert_eq!(ex.pending(), 2);
        assert_eq!(ex.ready_count(), 1, "gated task must not count as ready");
        assert!(!h1.is_done(), "no thread: nothing runs until run_ready");
        assert!(ex.run_ready(0));
        assert!(h1.is_done());
        assert_eq!(ran.load(Ordering::SeqCst), 1);
        assert!(!ex.run_ready(0), "only ready tasks are schedulable");
        gate.store(true, Ordering::SeqCst);
        assert_eq!(ex.ready_count(), 1);
        assert!(ex.run_ready(0));
        assert!(h2.is_done());
        assert_eq!(ran.load(Ordering::SeqCst), 11);
        ex.shutdown();
    }

    #[test]
    fn many_tasks_with_interleaved_conditions() {
        let ex = Executor::spawn();
        let counter = Arc::new(AtomicU64::new(0));
        let mut handles = vec![];
        for i in 0..20u64 {
            let c = Arc::clone(&counter);
            let c2 = Arc::clone(&counter);
            // task i runs only when counter == i → forces sequential cascade
            handles.push(ex.submit(
                move || c.load(Ordering::SeqCst) == i,
                move || {
                    c2.fetch_add(1, Ordering::SeqCst);
                },
            ));
        }
        // each completion pokes nothing by itself — poke via a ticker
        for _ in 0..100 {
            ex.signal().poke();
            if counter.load(Ordering::SeqCst) == 20 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        for h in &handles {
            join_within_5s(h);
        }
        assert_eq!(counter.load(Ordering::SeqCst), 20);
        ex.shutdown();
    }
}
