//! Small self-contained utilities (the offline build has no `rand`,
//! `hdrhistogram`, or `parking_lot`, so these are implemented in-repo).

pub mod hist;
pub mod prng;
pub mod work;

pub use hist::Histogram;
pub use prng::Prng;
pub use work::{busy_work_us, calibrate};
