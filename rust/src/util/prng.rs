//! Deterministic, splittable pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we implement xoshiro256++
//! (Blackman & Vigna) by hand. Eigenbench needs *splittable* determinism:
//! every client thread derives an independent stream from a scenario seed
//! so runs are reproducible regardless of thread interleaving.

/// xoshiro256++ generator. Not cryptographic; statistically solid and fast.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

/// splitmix64, used to seed xoshiro from a single u64 (reference practice).
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Create a generator from a 64-bit seed.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Derive an independent stream for a sub-entity (client id, node id).
    /// Mixes the label into the seed space via splitmix64 so streams from
    /// the same parent do not overlap in practice.
    pub fn split(&self, label: u64) -> Prng {
        let mut sm = self
            .s[0]
            .wrapping_mul(0xA24B_AED4_963E_E407)
            .wrapping_add(label ^ 0x9FB2_1C65_1E98_DF25);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Next raw 64-bit value (xoshiro256++ core).
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 top bits → [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Prng::seeded(42);
        let mut b = Prng::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let root = Prng::seeded(7);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "split streams should be independent");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut p = Prng::seeded(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let v = p.below(10);
            assert!(v < 10);
            counts[v as usize] += 1;
        }
        for &c in &counts {
            // each bucket expects 10_000; allow ±10 %
            assert!((9_000..=11_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::seeded(9);
        for _ in 0..10_000 {
            let v = p.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn chance_matches_probability() {
        let mut p = Prng::seeded(11);
        let hits = (0..100_000).filter(|_| p.chance(0.3)).count();
        assert!((28_000..=32_000).contains(&hits), "hits {hits}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::seeded(5);
        let mut v: Vec<u32> = (0..100).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }
}
