//! Calibrated synthetic work.
//!
//! Eigenbench operations take "around 3 ms" in the paper ("fairly long,
//! which represents the complex computations"). We model operation cost
//! two ways:
//!   * `busy_work_us` — a calibrated spin that burns CPU (used when the
//!     operation should contend for cores like a real computation);
//!   * `std::thread::sleep` — used by the workload when simulating I/O- or
//!     remote-compute-bound operations on the oversubscribed 1-core CI box,
//!     where spinning would serialize everything and hide the algorithmic
//!     parallelism the paper measures.
//! The `ComputeObject` runs real XLA kernel work instead (see `runtime`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Iterations of the spin loop per microsecond, measured once.
static ITERS_PER_US: AtomicU64 = AtomicU64::new(0);

#[inline(never)]
fn spin_chunk(iters: u64) -> u64 {
    // A data-dependent loop the optimizer cannot elide or vectorize away.
    let mut acc: u64 = 0x9E37_79B9_7F4A_7C15;
    for i in 0..iters {
        acc = acc.rotate_left(7) ^ i;
        acc = acc.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    }
    acc
}

/// Measure spin-loop speed. Called lazily by `busy_work_us`; call it
/// eagerly from benchmark setup to keep calibration out of timed regions.
pub fn calibrate() -> u64 {
    let cached = ITERS_PER_US.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    // Time a fixed chunk, take the median of 5 runs for robustness.
    let mut rates = [0u64; 5];
    for r in rates.iter_mut() {
        let iters = 2_000_000u64;
        let t0 = Instant::now();
        std::hint::black_box(spin_chunk(iters));
        let us = t0.elapsed().as_micros().max(1) as u64;
        *r = iters / us;
    }
    rates.sort();
    let rate = rates[2].max(1);
    ITERS_PER_US.store(rate, Ordering::Relaxed);
    rate
}

/// Burn roughly `us` microseconds of CPU.
pub fn busy_work_us(us: u64) {
    let rate = calibrate();
    std::hint::black_box(spin_chunk(rate.saturating_mul(us)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn calibrate_is_nonzero_and_cached() {
        let a = calibrate();
        let b = calibrate();
        assert!(a > 0);
        assert_eq!(a, b, "second call should hit the cache");
    }

    #[test]
    fn busy_work_takes_roughly_the_requested_time() {
        calibrate();
        let t0 = Instant::now();
        busy_work_us(2_000);
        let took = t0.elapsed().as_micros() as u64;
        // Only a lower bound is meaningful: on the oversubscribed 1-core
        // test box, wall time under `cargo test`'s parallel load can be
        // many times the requested CPU time.
        assert!(took >= 500, "took {took}us, expected >= 500us");
    }
}
