//! Latency histogram with logarithmic buckets (HdrHistogram-lite).
//!
//! Offline build has no external histogram crate; this gives ~5 % relative
//! error quantiles over a microsecond..minutes range, merge support for
//! per-thread recording, and zero allocation on the record path.

use std::time::Duration;

const SUB_BUCKET_BITS: u32 = 5; // 32 sub-buckets per octave → ≤ ~3 % error
const SUB_BUCKETS: usize = 1 << SUB_BUCKET_BITS;
const OCTAVES: usize = 40; // up to 2^40 µs ≈ 12.7 days

/// Log-bucketed histogram of microsecond values.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    max: u64,
    min: u64,
    sum: u128,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; OCTAVES * SUB_BUCKETS],
            total: 0,
            max: 0,
            min: u64::MAX,
            sum: 0,
        }
    }

    fn bucket_of(value: u64) -> usize {
        let v = value.max(1);
        let octave = 63 - v.leading_zeros();
        if octave < SUB_BUCKET_BITS {
            return v as usize;
        }
        let shift = octave - SUB_BUCKET_BITS;
        let sub = (v >> shift) as usize & (SUB_BUCKETS - 1);
        ((octave - SUB_BUCKET_BITS + 1) as usize) * SUB_BUCKETS + sub
    }

    /// Lower edge of a bucket (inverse of `bucket_of` up to bucket width).
    fn bucket_floor(idx: usize) -> u64 {
        if idx < SUB_BUCKETS {
            return idx as u64;
        }
        let octave = (idx / SUB_BUCKETS) as u32 + SUB_BUCKET_BITS - 1;
        let sub = (idx % SUB_BUCKETS) as u64;
        (1u64 << octave) | (sub << (octave - SUB_BUCKET_BITS))
    }

    /// Record one microsecond value.
    pub fn record(&mut self, micros: u64) {
        let idx = Self::bucket_of(micros).min(self.counts.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
        self.max = self.max.max(micros);
        self.min = self.min.min(micros);
        self.sum += micros as u128;
    }

    /// Record a `Duration`.
    pub fn record_duration(&mut self, d: Duration) {
        self.record(d.as_micros() as u64);
    }

    /// Merge another histogram into this one (per-thread aggregation).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.max = self.max.max(other.max);
        self.min = self.min.min(other.min);
        self.sum += other.sum;
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Largest recorded value (exact, not bucketed).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Smallest recorded value (exact); 0 when empty.
    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Arithmetic mean of the recorded values (exact); 0 when empty.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Quantile in `[0,1]`, returned as microseconds (bucket lower edge).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.total as f64).ceil() as u64;
        let target = target.max(1);
        let mut seen = 0;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_floor(idx);
            }
        }
        self.max
    }

    /// Compact single-line summary, e.g. for bench output.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}us p50={}us p95={}us p99={}us max={}us",
            self.total,
            self.mean(),
            self.quantile(0.50),
            self.quantile(0.95),
            self.quantile(0.99),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
    }

    #[test]
    fn single_value() {
        let mut h = Histogram::new();
        h.record(1000);
        assert_eq!(h.count(), 1);
        assert_eq!(h.max(), 1000);
        assert_eq!(h.min(), 1000);
        // bucket resolution: p50 within ~3 % of 1000
        let p50 = h.quantile(0.5);
        assert!((960..=1000).contains(&p50), "p50={p50}");
    }

    #[test]
    fn quantiles_are_ordered_and_close() {
        let mut h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!((4500..=5200).contains(&p50), "p50={p50}");
        assert!((9000..=9700).contains(&p95), "p95={p95}");
    }

    #[test]
    fn all_equal_samples_collapse_to_one_bucket() {
        let mut h = Histogram::new();
        for _ in 0..500 {
            h.record(777);
        }
        assert_eq!(h.count(), 500);
        assert_eq!(h.min(), 777);
        assert_eq!(h.max(), 777);
        assert_eq!(h.mean(), 777.0);
        // Every quantile lands in the same bucket, so p0 = p50 = p99 = p100
        // (all at that bucket's floor, within resolution below 777).
        let p0 = h.quantile(0.0);
        assert_eq!(p0, h.quantile(0.5));
        assert_eq!(p0, h.quantile(0.99));
        assert_eq!(p0, h.quantile(1.0));
        assert!((753..=777).contains(&p0), "p0={p0}");
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        for v in 0..1000u64 {
            if v % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            c.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.quantile(0.9), c.quantile(0.9));
        assert_eq!(a.max(), c.max());
    }

    #[test]
    fn bucket_floor_roundtrip() {
        for v in [1u64, 2, 31, 32, 33, 100, 1023, 1024, 123_456, 10_000_000] {
            let idx = Histogram::bucket_of(v);
            let floor = Histogram::bucket_floor(idx);
            assert!(floor <= v, "floor {floor} > value {v}");
            // width of bucket ≤ v / 16 for v ≥ 32
            if v >= 32 {
                assert!(v - floor <= v / 16, "v={v} floor={floor}");
            }
        }
    }
}
