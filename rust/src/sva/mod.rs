//! **SVA / Atomic RMI 1** — the paper's direct predecessor baseline (§4.1).
//!
//! The bare Supremum Versioning Algorithm [Wojciechowski, PPDP'05; Siek &
//! Wojciechowski, IJPP'16]: the same `pv`/`lv`/`ltv` counters and access /
//! commit conditions as OptSVA-CF, but **operation-type agnostic**:
//!
//!   * one *total* supremum per object (reads+writes+updates collapsed);
//!   * every operation — even a pure write — waits at the access condition
//!     and executes in place on the live object;
//!   * no copy/log buffers (except the abort checkpoint), no read-only
//!     optimization, no asynchronous release;
//!   * early release happens only when the total call count reaches the
//!     supremum (or at commit).
//!
//! Because SVA perceives every operation as a potential conflict, it
//! serializes where OptSVA-CF parallelizes — this gap is exactly what the
//! paper's evaluation measures (Atomic RMI vs Atomic RMI 2, Figs 10–12).

use crate::api::{run_with_retries, Dtm, ObjHandle, OpFuture, TxCtx, TxError, TxSpec, TxStats};
use crate::buffers::CopyBuffer;
use crate::clock::Clock;
use crate::cluster::{Cluster, NodeId, Oid};
use crate::object::{OpCall, SharedObject, Value};
use crate::versioning::{acquire_start_locks, ObjectCc};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// A hosted object under SVA control.
struct Slot {
    oid: Oid,
    cc: ObjectCc,
    object: Mutex<Box<dyn SharedObject>>,
    crashed: AtomicBool,
}

/// The Atomic RMI 1 system.
pub struct AtomicRmi1 {
    cluster: Arc<Cluster>,
    slots: Vec<RwLock<Vec<Arc<Slot>>>>,
    /// Committed transactions.
    pub commits: AtomicU64,
    /// Programmatic aborts ([`crate::api::TxError::ManualAbort`]).
    pub manual_aborts: AtomicU64,
    /// Cascading aborts forced by an aborting predecessor.
    pub forced_aborts: AtomicU64,
    wait_timeout: Option<Duration>,
}

impl AtomicRmi1 {
    /// An SVA system over `cluster` (no objects hosted yet).
    pub fn new(cluster: Arc<Cluster>) -> Arc<Self> {
        let slots = cluster.node_ids().map(|_| RwLock::new(Vec::new())).collect();
        Arc::new(AtomicRmi1 {
            cluster,
            slots,
            commits: AtomicU64::new(0),
            manual_aborts: AtomicU64::new(0),
            forced_aborts: AtomicU64::new(0),
            wait_timeout: Some(Duration::from_secs(60)),
        })
    }

    /// Host `object` on `node` under `name`.
    pub fn host(&self, node: NodeId, name: &str, object: Box<dyn SharedObject>) -> Oid {
        let mut slots = self.slots[node.0 as usize].write().unwrap();
        let oid = Oid::new(node, slots.len() as u32);
        slots.push(Arc::new(Slot {
            oid,
            cc: ObjectCc::with_clock(Arc::clone(self.cluster.clock())),
            object: Mutex::new(object),
            crashed: AtomicBool::new(false),
        }));
        drop(slots);
        self.cluster.registry.bind(name, oid);
        oid
    }

    fn slot(&self, oid: Oid) -> Arc<Slot> {
        let slots = self.slots[oid.node.0 as usize].read().unwrap();
        Arc::clone(&slots[oid.index as usize])
    }

    /// Peek at an object's state (non-transactional test helper).
    pub fn with_object<R>(&self, oid: Oid, f: impl FnOnce(&dyn SharedObject) -> R) -> R {
        let slot = self.slot(oid);
        let obj = slot.object.lock().unwrap();
        f(obj.as_ref())
    }

    /// The cluster this system runs on.
    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    /// Begin a transaction from `client`.
    pub fn tx(self: &Arc<Self>, client: NodeId) -> SvaTransaction {
        SvaTransaction {
            sys: Arc::clone(self),
            client,
            wait_timeout: self.wait_timeout,
            decls: Vec::new(),
            objs: Vec::new(),
            phase: Phase::Preamble,
        }
    }
}

#[derive(PartialEq, Eq)]
enum Phase {
    Preamble,
    Running,
    Done,
}

/// Per-object transaction state: total supremum, call counter, checkpoint.
struct TxObj {
    slot: Arc<Slot>,
    pv: u64,
    ub: u64,
    cc_count: u64,
    accessed: bool,
    released: bool,
    modified: bool,
    st: Option<CopyBuffer>,
    st_epoch: u64,
}

/// An SVA transaction: agnostic versioning with a single total supremum.
pub struct SvaTransaction {
    sys: Arc<AtomicRmi1>,
    client: NodeId,
    /// Per-transaction failure-suspicion deadline (defaults to the
    /// system-wide setting; `None` disables suspicion).
    wait_timeout: Option<Duration>,
    decls: Vec<(String, u64)>,
    objs: Vec<TxObj>,
    phase: Phase,
}

impl SvaTransaction {
    /// Preamble: declare access with a total supremum (`u64::MAX` if
    /// unknown). SVA has no per-mode bounds.
    pub fn accesses(&mut self, name: &str, ub: u64) -> ObjHandle {
        assert!(self.phase == Phase::Preamble);
        self.decls.push((name.to_string(), ub));
        ObjHandle(self.decls.len() - 1)
    }

    /// Atomically acquire private versions for the whole access set.
    pub fn begin(&mut self) -> Result<(), TxError> {
        assert!(self.phase == Phase::Preamble);
        let cluster = Arc::clone(&self.sys.cluster);
        let mut resolved = Vec::with_capacity(self.decls.len());
        for (name, ub) in &self.decls {
            let oid = cluster
                .registry
                .locate(name)
                .ok_or_else(|| TxError::NotDeclared(name.clone()))?;
            resolved.push((oid, *ub));
        }
        let mut order: Vec<usize> = (0..resolved.len()).collect();
        order.sort_by_key(|&i| resolved[i].0);
        let slots: Vec<_> = order.iter().map(|&i| self.sys.slot(resolved[i].0)).collect();
        let lock_view: Vec<_> = order
            .iter()
            .zip(&slots)
            .map(|(&i, s)| (resolved[i].0, &s.cc))
            .collect();
        let client = self.client;
        let pvs = acquire_start_locks(&lock_view, |oid| {
            cluster.rpc(client, oid.node, 24, || ((), 16));
        });
        let mut objs: Vec<Option<TxObj>> = (0..resolved.len()).map(|_| None).collect();
        for (pos, &i) in order.iter().enumerate() {
            objs[i] = Some(TxObj {
                slot: Arc::clone(&slots[pos]),
                pv: pvs[pos],
                ub: resolved[i].1,
                cc_count: 0,
                accessed: false,
                released: false,
                modified: false,
                st: None,
                st_epoch: 0,
            });
        }
        self.objs = objs.into_iter().map(Option::unwrap).collect();
        self.phase = Phase::Running;
        Ok(())
    }

    /// Per-transaction failure-suspicion deadline override (§3.4).
    pub fn timeout(mut self, t: Duration) -> Self {
        assert!(self.phase == Phase::Preamble, "timeout() after begin");
        self.wait_timeout = Some(t);
        self
    }

    fn deadline(&self) -> Option<Duration> {
        let clock = self.sys.cluster.clock();
        self.wait_timeout.map(|t| clock.now() + t)
    }

    /// Execute one operation: wait at the access condition (first call),
    /// checkpoint, run in place, release at the supremum.
    fn invoke(&mut self, h: ObjHandle, call: &OpCall) -> Result<Value, TxError> {
        if self.phase != Phase::Running {
            return Err(TxError::Completed);
        }
        let o = &mut self.objs[h.0];
        if o.slot.crashed.load(Ordering::Acquire) {
            return Err(TxError::ObjectCrashed(o.slot.oid));
        }
        o.cc_count += 1;
        if o.cc_count > o.ub {
            return Err(TxError::SupremaExceeded {
                oid: o.slot.oid,
                mode: "any",
                count: o.cc_count,
                bound: o.ub,
            });
        }
        let deadline = self
            .wait_timeout
            .map(|t| self.sys.cluster.clock().now() + t);
        if !o.accessed {
            o.slot.cc.wait_access(o.pv, deadline)?;
            o.accessed = true;
        }
        if o.slot.cc.doomed(o.pv) {
            return Err(TxError::ForcedAbort(format!(
                "object {} invalidated",
                o.slot.oid
            )));
        }
        let mut obj = o.slot.object.lock().unwrap();
        // Re-check invalidation under the object lock (an earlier abort's
        // mark + restore is atomic under this lock).
        if o.slot.cc.doomed(o.pv) {
            return Err(TxError::ForcedAbort(format!(
                "object {} invalidated",
                o.slot.oid
            )));
        }
        if o.st.is_none() {
            o.st_epoch = o.slot.cc.epoch();
            o.st = Some(CopyBuffer::capture(obj.as_ref()));
        }
        let v = obj.invoke(call)?;
        o.modified = true; // agnostic: every call may have modified state
        if o.cc_count == o.ub {
            drop(obj);
            o.slot.cc.release(o.pv);
            o.released = true;
        }
        Ok(v)
    }

    /// Commit: wait the commit condition everywhere, check invalidation,
    /// release and terminate.
    pub fn commit(&mut self) -> Result<(), TxError> {
        assert!(self.phase == Phase::Running);
        let cluster = Arc::clone(&self.sys.cluster);
        let client = self.client;
        let deadline = self.deadline();
        for o in &self.objs {
            cluster.rpc(client, o.slot.oid.node, 24, || {
                (o.slot.cc.wait_commit_cond(o.pv, deadline), 16)
            })?;
        }
        let doomed = self.objs.iter().any(|o| o.slot.cc.doomed(o.pv));
        if doomed {
            self.rollback_all();
            self.phase = Phase::Done;
            self.sys.forced_aborts.fetch_add(1, Ordering::Relaxed);
            return Err(TxError::ForcedAbort("invalidated at commit".into()));
        }
        for o in &mut self.objs {
            if !o.released {
                o.slot.cc.release(o.pv);
                o.released = true;
            }
        }
        for o in &self.objs {
            o.slot.cc.terminate(o.pv);
        }
        self.phase = Phase::Done;
        self.sys.commits.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Manual abort: restore checkpoints (oldest aborter wins), release,
    /// terminate.
    pub fn abort(&mut self) -> Result<(), TxError> {
        self.abort_with(&TxError::ManualAbort);
        Ok(())
    }

    /// Abort, attributing the cause: manual aborts and retries count as
    /// `manual_aborts`, everything else (cascades, object errors) as
    /// `forced_aborts`. (The pre-driver code counted a manual abort twice
    /// — once here and once in the retry loop.)
    fn abort_with(&mut self, cause: &TxError) {
        assert!(self.phase == Phase::Running);
        let cluster = Arc::clone(&self.sys.cluster);
        let client = self.client;
        let deadline = self.deadline();
        for o in &self.objs {
            let _ = cluster.rpc(client, o.slot.oid.node, 24, || {
                (o.slot.cc.wait_commit_cond(o.pv, deadline), 16)
            });
        }
        self.rollback_all();
        self.phase = Phase::Done;
        match cause {
            TxError::ManualAbort | TxError::Retry => {
                self.sys.manual_aborts.fetch_add(1, Ordering::Relaxed);
            }
            _ => {
                self.sys.forced_aborts.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    fn rollback_all(&mut self) {
        for o in &mut self.objs {
            let mut obj = o.slot.object.lock().unwrap();
            if o.modified {
                o.slot.cc.mark_invalid(o.pv);
                let should_restore =
                    o.st.is_some() && o.st_epoch == o.slot.cc.epoch();
                if should_restore {
                    if let Some(st) = &o.st {
                        st.restore_into(obj.as_mut());
                        o.slot.cc.note_restored(o.pv);
                    }
                }
            }
            drop(obj);
            if !o.released {
                o.slot.cc.release(o.pv);
                o.released = true;
            }
            o.slot.cc.terminate(o.pv);
        }
    }

    fn ops(&self) -> u64 {
        self.objs.iter().map(|o| o.cc_count).sum()
    }
}

impl TxCtx for SvaTransaction {
    /// SVA has no asynchronous machinery (every operation synchronizes at
    /// the access condition, §4.1): `submit` executes inline and returns a
    /// resolved future, so `call` (the trait default) is unchanged.
    fn submit(&mut self, h: ObjHandle, call: OpCall) -> Result<OpFuture, TxError> {
        let (node, req) = {
            let o = &self.objs[h.0];
            (o.slot.oid.node, call.wire_size())
        };
        let client = self.client;
        let cluster = Arc::clone(&self.sys.cluster);
        // Pay the RMI round trip; the handler runs at the object's home.
        Ok(OpFuture::ready(cluster.rpc(client, node, req, || {
            let r = self.invoke(h, &call);
            let resp = match &r {
                Ok(v) => v.wire_size(),
                Err(_) => 16,
            };
            (r, resp)
        })))
    }

    fn client(&self) -> NodeId {
        self.client
    }
}

impl Drop for SvaTransaction {
    fn drop(&mut self) {
        if self.phase == Phase::Running {
            let _ = self.abort();
        }
    }
}

impl Dtm for Arc<AtomicRmi1> {
    fn framework_name(&self) -> &'static str {
        "atomic-rmi (SVA)"
    }

    // SVA has no irrevocable mode (versioning is already abort-free absent
    // manual aborts) and no asynchrony: those spec knobs are ignored.
    fn run_tx(
        &self,
        client: NodeId,
        spec: &TxSpec,
        body: &mut dyn FnMut(&mut dyn TxCtx) -> Result<(), TxError>,
    ) -> Result<TxStats, TxError> {
        run_with_retries(
            spec.max_attempts.unwrap_or(crate::api::DEFAULT_MAX_ATTEMPTS),
            || {
                let mut tx = self.tx(client);
                if let Some(t) = spec.wait_timeout {
                    tx.wait_timeout = t;
                }
                for d in &spec.decls {
                    // SVA is operation-agnostic: collapse per-mode suprema.
                    tx.accesses(&d.name, d.suprema.total());
                }
                tx.begin()?;
                match body(&mut tx) {
                    Ok(()) => {
                        let ops = tx.ops();
                        tx.commit()?;
                        Ok(ops)
                    }
                    Err(e) => {
                        tx.abort_with(&e);
                        Err(e)
                    }
                }
            },
            |_, _| {},
        )
    }

    fn aborts(&self) -> u64 {
        self.manual_aborts.load(Ordering::Relaxed) + self.forced_aborts.load(Ordering::Relaxed)
    }

    fn commits(&self) -> u64 {
        self.commits.load(Ordering::Relaxed)
    }
}
