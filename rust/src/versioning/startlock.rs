//! Atomic private-version acquisition at transaction start (§2.1, §2.10.2).
//!
//! "In order for this to be done atomically, transactions lock a series of
//! locks before getting private versions, and release the locks afterwards.
//! These locks are always acquired in accordance to an arbitrary global
//! order" — here, `Oid` order. The start locks are dedicated mutexes,
//! *separate* from the condition mutexes, so a transaction sleeping on
//! network latency during start never blocks release/terminate traffic.

use super::ObjectCc;
use crate::cluster::Oid;
use std::sync::MutexGuard;

/// Acquire all start locks in global `Oid` order, assign a private version
/// from each object, release the locks, and return the pvs (parallel to
/// the input slice).
///
/// `charge` is invoked once per object *before* its lock is taken, with the
/// object's `Oid` — the caller uses it to charge network latency for the
/// remote lock acquisition. The input **must** be sorted by `Oid` and free
/// of duplicates; this is asserted.
pub fn acquire_start_locks(
    objects: &[(Oid, &ObjectCc)],
    mut charge: impl FnMut(Oid),
) -> Vec<u64> {
    debug_assert!(
        objects.windows(2).all(|w| w[0].0 < w[1].0),
        "access set must be sorted by Oid and deduplicated"
    );
    let mut guards: Vec<MutexGuard<'_, ()>> = Vec::with_capacity(objects.len());
    for (oid, cc) in objects {
        charge(*oid);
        guards.push(cc.start_lock.lock().unwrap());
    }
    // All locks held: draw private versions atomically.
    let pvs: Vec<u64> = objects.iter().map(|(_, cc)| cc.assign_pv()).collect();
    drop(guards);
    pvs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeId;
    use std::sync::Arc;
    use std::thread;

    fn oid(i: u32) -> Oid {
        Oid::new(NodeId(0), i)
    }

    #[test]
    fn assigns_one_pv_per_object() {
        let a = ObjectCc::new();
        let b = ObjectCc::new();
        let pvs = acquire_start_locks(&[(oid(0), &a), (oid(1), &b)], |_| {});
        assert_eq!(pvs, vec![1, 1]);
        let pvs = acquire_start_locks(&[(oid(0), &a)], |_| {});
        assert_eq!(pvs, vec![2]);
    }

    #[test]
    fn charge_called_in_oid_order() {
        let a = ObjectCc::new();
        let b = ObjectCc::new();
        let mut seen = vec![];
        acquire_start_locks(&[(oid(0), &a), (oid(5), &b)], |o| seen.push(o));
        assert_eq!(seen, vec![oid(0), oid(5)]);
    }

    /// Property (c) of §2.1: pv orders agree across objects — if
    /// pv_i(x) < pv_j(x) then pv_i(y) < pv_j(y) for all shared y.
    #[test]
    fn concurrent_starts_yield_consistent_pv_orders() {
        let a = Arc::new(ObjectCc::new());
        let b = Arc::new(ObjectCc::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let (a, b) = (Arc::clone(&a), Arc::clone(&b));
            handles.push(thread::spawn(move || {
                let pvs = acquire_start_locks(&[(oid(0), &a), (oid(1), &b)], |_| {});
                (pvs[0], pvs[1])
            }));
        }
        let mut got: Vec<(u64, u64)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        got.sort();
        // Consistent ordering ⇒ sorted by pv(a), the pv(b) column is also
        // strictly increasing; with identical access sets they are equal.
        for w in got.windows(2) {
            assert!(w[0].1 < w[1].1, "inconsistent pv order: {got:?}");
        }
        for (x, y) in &got {
            assert_eq!(x, y, "same access set ⇒ same pv on both objects");
        }
    }
}
