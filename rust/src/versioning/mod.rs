//! Supremum-versioning concurrency control primitives (paper §2.1–§2.3).
//!
//! Every shared object carries a concurrency-control block ([`ObjectCc`])
//! with three counters:
//!
//!   * `next_pv` — the per-object *version source*: transactions draw their
//!     private versions `pv_i(x)` from it at start, atomically across the
//!     whole access set (under per-object start locks taken in global
//!     `Oid` order — this is what makes properties (a)–(d) of §2.1 hold);
//!   * `lv`  — *local version*: the pv of the transaction that most
//!     recently **released** the object (commit, abort, or early release);
//!   * `ltv` — *local terminal version*: the pv of the transaction that
//!     most recently **terminated** (committed or aborted).
//!
//! The **access condition** is `pv - 1 == lv`; the **commit (termination)
//! condition** is `pv - 1 == ltv`. Both are awaited on the block's condvar.
//!
//! The block additionally tracks *invalidation marks* for cascading aborts
//! (§2.3): an aborting transaction `T_i` marks the object with
//! `(marker = pv_i, up_to = max pv granted access so far)`; any transaction
//! with `marker < pv ≤ up_to` is doomed and must abort instead of
//! committing. Marks are pruned once `ltv` passes `up_to`.

pub mod startlock;

pub use startlock::acquire_start_locks;

use crate::clock::{wait_deadline, Clock, RealClock};
use crate::executor::Signal;
use std::fmt;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Error returned when a versioning wait exceeds its deadline. Used by the
/// fault-tolerance layer (§3.4) to suspect crashed transactions, and by
/// tests to detect deadlock regressions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WaitTimeout {
    /// Which wait timed out (`"access"`, `"commit"`, …).
    pub what: &'static str,
    /// How long the waiter blocked before giving up.
    pub waited_ms: u64,
}

impl fmt::Display for WaitTimeout {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "versioning wait timed out after {} ms ({})",
            self.waited_ms, self.what
        )
    }
}

impl std::error::Error for WaitTimeout {}

/// An invalidation mark left by an aborted transaction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InvalidMark {
    /// pv of the transaction that aborted (and restored the state).
    pub marker_pv: u64,
    /// Highest pv that had been granted access when the mark was placed;
    /// every pv in `(marker_pv, up_to]` observed potentially-invalid state.
    pub up_to: u64,
}

#[derive(Debug, Default)]
struct CcState {
    next_pv: u64,
    lv: u64,
    ltv: u64,
    /// Highest pv that passed the access condition (or buffered the state).
    max_granted: u64,
    marks: Vec<InvalidMark>,
    /// Restore epoch: bumped every time an aborter reverts the object's
    /// state. A checkpoint taken at epoch `e` is from the valid lineage
    /// iff the epoch is still `e` when its owner aborts.
    epoch: u64,
}

/// Per-object concurrency-control block.
pub struct ObjectCc {
    state: Mutex<CcState>,
    cond: Condvar,
    /// Time source for deadline-bounded waits (the hosting cluster's
    /// clock; real or virtual).
    clock: Arc<dyn Clock>,
    /// Start-lock for atomic pv acquisition (never held while waiting on
    /// conditions; see `startlock`).
    pub start_lock: Mutex<()>,
    /// Executor signals to poke whenever `lv`/`ltv` change (§3.3).
    watchers: Mutex<Vec<Arc<Signal>>>,
}

impl Default for ObjectCc {
    fn default() -> Self {
        Self::new()
    }
}

impl ObjectCc {
    /// Block on the shared wall clock (unit tests, microbenches).
    pub fn new() -> Self {
        Self::with_clock(RealClock::shared())
    }

    /// Block whose deadline waits run against `clock` — the hosting
    /// cluster's clock, so virtual-time systems time out in virtual time.
    pub fn with_clock(clock: Arc<dyn Clock>) -> Self {
        ObjectCc {
            state: Mutex::new(CcState::default()),
            cond: Condvar::new(),
            clock,
            start_lock: Mutex::new(()),
            watchers: Mutex::new(Vec::new()),
        }
    }

    /// The clock this block waits against.
    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    /// Absolute deadline `timeout` from now, in this block's clock time.
    pub fn deadline_in(&self, timeout: Option<Duration>) -> Option<Duration> {
        timeout.map(|t| self.clock.now() + t)
    }

    /// Register an executor signal to be poked on counter changes.
    pub fn watch(&self, signal: Arc<Signal>) {
        self.watchers.lock().unwrap().push(signal);
    }

    fn poke_watchers(&self) {
        for w in self.watchers.lock().unwrap().iter() {
            w.poke();
        }
    }

    /// Draw the next private version. Caller must hold this object's
    /// start lock (enforced structurally by [`acquire_start_locks`]).
    pub fn assign_pv(&self) -> u64 {
        let mut s = self.state.lock().unwrap();
        s.next_pv += 1;
        s.next_pv
    }

    /// Current `(lv, ltv)` snapshot (diagnostics, executor conditions).
    pub fn versions(&self) -> (u64, u64) {
        let s = self.state.lock().unwrap();
        (s.lv, s.ltv)
    }

    /// Non-blocking access-condition check: `pv - 1 == lv`.
    pub fn access_ready(&self, pv: u64) -> bool {
        self.state.lock().unwrap().lv == pv - 1
    }

    /// Non-blocking commit-condition check: `pv - 1 == ltv`.
    pub fn commit_ready(&self, pv: u64) -> bool {
        self.state.lock().unwrap().ltv == pv - 1
    }

    /// Block until the access condition holds, then record the grant in
    /// `max_granted`. `deadline` is absolute, in this block's clock time;
    /// `None` waits forever.
    pub fn wait_access(&self, pv: u64, deadline: Option<Duration>) -> Result<(), WaitTimeout> {
        let started = self.clock.now();
        let mut s = self.state.lock().unwrap();
        while s.lv != pv - 1 {
            let (g, expired) = wait_deadline(self.clock.as_ref(), &self.cond, s, deadline);
            s = g;
            // A wake-up racing the deadline: the condition wins.
            if expired && s.lv != pv - 1 {
                return Err(self.timeout(started, "access condition"));
            }
        }
        s.max_granted = s.max_granted.max(pv);
        Ok(())
    }

    /// Block until the commit/termination condition holds. Used by commit
    /// and abort, and — for *irrevocable* transactions (§2.4) — in place
    /// of every access-condition wait, so they never observe early-released
    /// state. On success also records the grant.
    pub fn wait_commit_cond(&self, pv: u64, deadline: Option<Duration>) -> Result<(), WaitTimeout> {
        let started = self.clock.now();
        let mut s = self.state.lock().unwrap();
        while s.ltv != pv - 1 {
            let (g, expired) = wait_deadline(self.clock.as_ref(), &self.cond, s, deadline);
            s = g;
            if expired && s.ltv != pv - 1 {
                return Err(self.timeout(started, "commit condition"));
            }
        }
        s.max_granted = s.max_granted.max(pv);
        Ok(())
    }

    fn timeout(&self, started: Duration, what: &'static str) -> WaitTimeout {
        WaitTimeout {
            what,
            waited_ms: self.clock.now().saturating_sub(started).as_millis() as u64,
        }
    }

    /// Release the object on behalf of `pv`: set `lv = pv` (early release,
    /// commit, or abort). Idempotent: later calls with the same pv no-op.
    pub fn release(&self, pv: u64) {
        let mut s = self.state.lock().unwrap();
        debug_assert!(
            s.lv == pv - 1 || s.lv >= pv,
            "release out of order: lv={} pv={}",
            s.lv,
            pv
        );
        if s.lv < pv {
            s.lv = pv;
            self.cond.notify_all();
            drop(s);
            self.poke_watchers();
        }
    }

    /// Terminate on behalf of `pv`: set `ltv = pv` and prune stale marks.
    pub fn terminate(&self, pv: u64) {
        let mut s = self.state.lock().unwrap();
        debug_assert!(
            s.ltv == pv - 1 || s.ltv >= pv,
            "terminate out of order: ltv={} pv={}",
            s.ltv,
            pv
        );
        if s.ltv < pv {
            s.ltv = pv;
            let ltv = s.ltv;
            s.marks.retain(|m| m.up_to > ltv);
            self.cond.notify_all();
            drop(s);
            self.poke_watchers();
        }
    }

    /// Record that `pv` observed the object without passing through
    /// `wait_access` (asynchronous buffering path): update `max_granted`.
    pub fn note_granted(&self, pv: u64) {
        let mut s = self.state.lock().unwrap();
        s.max_granted = s.max_granted.max(pv);
    }

    /// Place an invalidation mark for an aborting transaction: every pv in
    /// `(marker_pv, max_granted]` observed potentially-invalid state and is
    /// doomed (§2.3).
    pub fn mark_invalid(&self, marker_pv: u64) {
        let mut s = self.state.lock().unwrap();
        let up_to = s.max_granted;
        if up_to > marker_pv {
            s.marks.push(InvalidMark { marker_pv, up_to });
        }
    }

    /// Current restore epoch. Sampled (under the object's lock) when a
    /// checkpoint is captured; compared at abort time to decide whether the
    /// checkpoint is from the valid lineage (§2.8.6: restore "unless some
    /// other transaction that previously aborted already restored it to an
    /// older version" — an intervening restore means a preceding aborter
    /// already reverted past our checkpoint, which captured
    /// since-invalidated state).
    pub fn epoch(&self) -> u64 {
        self.state.lock().unwrap().epoch
    }

    /// Record that an aborter restored the object's state.
    pub fn note_restored(&self) {
        self.state.lock().unwrap().epoch += 1;
    }

    /// Is the transaction holding `pv` doomed by an invalidation mark?
    pub fn doomed(&self, pv: u64) -> bool {
        let s = self.state.lock().unwrap();
        s.marks
            .iter()
            .any(|m| m.marker_pv < pv && pv <= m.up_to)
    }

    /// Active marks (diagnostics/tests).
    pub fn marks(&self) -> Vec<InvalidMark> {
        self.state.lock().unwrap().marks.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn pv_assignment_is_sequential() {
        let cc = ObjectCc::new();
        assert_eq!(cc.assign_pv(), 1);
        assert_eq!(cc.assign_pv(), 2);
        assert_eq!(cc.assign_pv(), 3);
    }

    #[test]
    fn access_condition_gates_in_pv_order() {
        let cc = Arc::new(ObjectCc::new());
        let pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        assert!(cc.access_ready(pv1));
        assert!(!cc.access_ready(pv2));

        let cc2 = Arc::clone(&cc);
        let waiter = thread::spawn(move || {
            let deadline = cc2.deadline_in(Some(Duration::from_secs(5)));
            cc2.wait_access(pv2, deadline)
                .expect("pv2 should eventually be granted");
        });
        thread::sleep(Duration::from_millis(20));
        cc.wait_access(pv1, None).unwrap();
        cc.release(pv1);
        waiter.join().unwrap();
        assert!(cc.access_ready(pv2));
    }

    #[test]
    fn commit_condition_follows_terminate_not_release() {
        let cc = ObjectCc::new();
        let pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        cc.release(pv1); // early release: lv=1 but ltv=0
        assert!(cc.access_ready(pv2));
        assert!(!cc.commit_ready(pv2));
        cc.terminate(pv1);
        assert!(cc.commit_ready(pv2));
    }

    #[test]
    fn wait_times_out() {
        let cc = ObjectCc::new();
        let _pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        let deadline = cc.deadline_in(Some(Duration::from_millis(30)));
        let r = cc.wait_access(pv2, deadline);
        assert!(r.is_err());
    }

    #[test]
    fn wait_times_out_in_virtual_time_without_real_sleeping() {
        use crate::clock::VirtualClock;
        let cc = ObjectCc::with_clock(Arc::new(VirtualClock::new()));
        let _pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        // A 30-second *virtual* deadline on a stalled clock must fire in
        // bounded real time via the stall escape hatch.
        let deadline = cc.deadline_in(Some(Duration::from_secs(30)));
        let t0 = std::time::Instant::now();
        let r = cc.wait_access(pv2, deadline);
        assert!(r.is_err());
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "virtual timeout must not consume real time"
        );
        assert!(cc.clock().now() >= Duration::from_secs(30));
    }

    #[test]
    fn release_is_idempotent() {
        let cc = ObjectCc::new();
        let pv = cc.assign_pv();
        cc.release(pv);
        cc.release(pv); // second release must not panic or regress lv
        assert_eq!(cc.versions().0, pv);
    }

    #[test]
    fn invalidation_dooms_only_the_granted_window() {
        let cc = ObjectCc::new();
        let pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        let pv3 = cc.assign_pv();
        // T1 accesses and releases early; T2 accesses.
        cc.wait_access(pv1, None).unwrap();
        cc.release(pv1);
        cc.wait_access(pv2, None).unwrap();
        // T1 aborts: marks invalid. T2 (already granted) is doomed; T3 is not.
        cc.mark_invalid(pv1);
        assert!(cc.doomed(pv2));
        assert!(!cc.doomed(pv3), "pv3 never observed invalid state");
        assert!(!cc.doomed(pv1), "the marker itself is not doomed");
    }

    #[test]
    fn marks_prune_after_doomed_window_terminates() {
        let cc = ObjectCc::new();
        let pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        cc.wait_access(pv1, None).unwrap();
        cc.release(pv1);
        cc.wait_access(pv2, None).unwrap();
        cc.mark_invalid(pv1);
        assert_eq!(cc.marks().len(), 1);
        cc.terminate(pv1);
        cc.release(pv2);
        cc.terminate(pv2); // ltv reaches up_to → mark pruned
        assert!(cc.marks().is_empty());
    }

    #[test]
    fn restore_epoch_distinguishes_lineages() {
        let cc = ObjectCc::new();
        let pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        cc.wait_access(pv1, None).unwrap();
        cc.release(pv1);
        // T2 checkpoints while T1's (dirty) state is visible.
        cc.wait_access(pv2, None).unwrap();
        let t2_epoch = cc.epoch();
        // T1 aborts: restores, bumping the epoch.
        cc.mark_invalid(pv1);
        cc.note_restored();
        // T2's checkpoint is from the invalidated lineage: must not restore.
        assert_ne!(t2_epoch, cc.epoch());
        // A fresh transaction checkpointing *after* the restore holds a
        // valid-lineage checkpoint and restores on abort.
        let pv3 = cc.assign_pv();
        cc.terminate(pv1);
        cc.release(pv2);
        cc.wait_access(pv3, None).unwrap();
        assert_eq!(cc.epoch(), cc.epoch());
        let t3_epoch = cc.epoch();
        assert_eq!(t3_epoch, cc.epoch(), "no restore since T3's checkpoint");
    }

    #[test]
    fn watchers_poked_on_release_and_terminate() {
        let cc = ObjectCc::new();
        let sig = Arc::new(Signal::new());
        cc.watch(Arc::clone(&sig));
        let g0 = sig.generation();
        let pv = cc.assign_pv();
        cc.release(pv);
        assert!(sig.generation() > g0);
        let g1 = sig.generation();
        cc.terminate(pv);
        assert!(sig.generation() > g1);
    }
}
