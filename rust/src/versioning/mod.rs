//! Supremum-versioning concurrency control primitives (paper §2.1–§2.3).
//!
//! Every shared object carries a concurrency-control block ([`ObjectCc`])
//! with three counters:
//!
//!   * `next_pv` — the per-object *version source*: transactions draw their
//!     private versions `pv_i(x)` from it at start, atomically across the
//!     whole access set (under per-object start locks taken in global
//!     `Oid` order — this is what makes properties (a)–(d) of §2.1 hold);
//!   * `lv`  — *local version*: the pv of the transaction that most
//!     recently **released** the object (commit, abort, or early release);
//!   * `ltv` — *local terminal version*: the pv of the transaction that
//!     most recently **terminated** (committed or aborted).
//!
//! The **access condition** is `pv - 1 == lv`; the **commit (termination)
//! condition** is `pv - 1 == ltv`. Both are awaited on the block's condvar.
//!
//! The block additionally tracks *invalidation marks* for cascading aborts
//! (§2.3): an aborting transaction `T_i` marks the object with
//! `(marker = pv_i, up_to = max pv granted access so far)`; any transaction
//! with `marker < pv ≤ up_to` is doomed and must abort instead of
//! committing. Marks are pruned once `ltv` passes `up_to`.

pub mod startlock;

pub use startlock::acquire_start_locks;

use crate::clock::{wait_deadline, Clock, RealClock};
use crate::executor::Signal;
use std::fmt;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Error returned when a versioning wait exceeds its deadline. Used by the
/// fault-tolerance layer (§3.4) to suspect crashed transactions, and by
/// tests to detect deadlock regressions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WaitTimeout {
    /// Which wait timed out (`"access"`, `"commit"`, …).
    pub what: &'static str,
    /// How long the waiter blocked before giving up.
    pub waited_ms: u64,
}

impl fmt::Display for WaitTimeout {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "versioning wait timed out after {} ms ({})",
            self.waited_ms, self.what
        )
    }
}

impl std::error::Error for WaitTimeout {}

/// An invalidation mark left by an aborted transaction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InvalidMark {
    /// pv of the transaction that aborted (and restored the state).
    pub marker_pv: u64,
    /// Highest pv that had been granted access when the mark was placed;
    /// every pv in `(marker_pv, up_to]` observed potentially-invalid state.
    pub up_to: u64,
}

/// An open pv-group of commuting acquisitions (docs/COMMUTATIVITY.md):
/// consecutive same-class transactions share one logical version slot —
/// all members hold access concurrently, and the chain advances past the
/// whole group (`lv = last_pv`) only when the last member releases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupState {
    /// Commutativity class every member declared.
    pub class: u8,
    /// First member's pv — the group's position in the version chain.
    pub first_pv: u64,
    /// Last (highest) member pv admitted so far.
    pub last_pv: u64,
    /// Members granted access and not yet released.
    pub active: u64,
    /// Members not yet terminated (commit/abort complete).
    pub unterminated: u64,
}

/// A positional record of a state reversion: a full checkpoint restore
/// (`full`, `pv` = the restorer, state reverted to before `pv`'s
/// operations) or a commuting-inverse application (`pv` = the aborting
/// group member, only its own contribution surgically reverted; `ops` are
/// the inverse calls as applied). The *position* is what matters: a full
/// reversion at `pv` wipes the work of transactions later than `pv`, so
/// their own rollbacks must stand down; a surgical reversion removes one
/// transaction's contribution only, so a later transaction restoring its
/// checkpoint (which re-instates that contribution) must replay the
/// surgical `ops` on top (docs/COMMUTATIVITY.md §abort).
#[derive(Debug, Clone)]
struct RevertNote {
    seq: u64,
    pv: u64,
    full: bool,
    ops: Vec<crate::object::OpCall>,
}

#[derive(Debug, Default)]
struct CcState {
    next_pv: u64,
    lv: u64,
    ltv: u64,
    /// Highest pv that passed the access condition (or buffered the state).
    max_granted: u64,
    marks: Vec<InvalidMark>,
    /// Restore epoch: bumped every time an aborter reverts the object's
    /// state. A checkpoint taken at epoch `e` is from the valid lineage
    /// iff the epoch is still `e` when its owner aborts.
    epoch: u64,
    /// The open commuting pv-group, if any. At most one at a time; a new
    /// group can only open once the previous one fully terminates.
    group: Option<GroupState>,
    /// Monotone counter of reversion events ([`RevertNote`]).
    revert_seq: u64,
    /// Reversion log, newest last. Bounded by the run's abort count (one
    /// entry per restore/inverse application); never pruned, because an
    /// old note can still matter to any live transaction that sampled
    /// [`ObjectCc::revert_seq`] before it.
    reverts: Vec<RevertNote>,
}

/// Per-object concurrency-control block.
pub struct ObjectCc {
    state: Mutex<CcState>,
    cond: Condvar,
    /// Time source for deadline-bounded waits (the hosting cluster's
    /// clock; real or virtual).
    clock: Arc<dyn Clock>,
    /// Start-lock for atomic pv acquisition (never held while waiting on
    /// conditions; see `startlock`).
    pub start_lock: Mutex<()>,
    /// Executor signals to poke whenever `lv`/`ltv` change (§3.3).
    watchers: Mutex<Vec<Arc<Signal>>>,
}

impl Default for ObjectCc {
    fn default() -> Self {
        Self::new()
    }
}

impl ObjectCc {
    /// Block on the shared wall clock (unit tests, microbenches).
    pub fn new() -> Self {
        Self::with_clock(RealClock::shared())
    }

    /// Block whose deadline waits run against `clock` — the hosting
    /// cluster's clock, so virtual-time systems time out in virtual time.
    pub fn with_clock(clock: Arc<dyn Clock>) -> Self {
        ObjectCc {
            state: Mutex::new(CcState::default()),
            cond: Condvar::new(),
            clock,
            start_lock: Mutex::new(()),
            watchers: Mutex::new(Vec::new()),
        }
    }

    /// The clock this block waits against.
    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    /// Absolute deadline `timeout` from now, in this block's clock time.
    pub fn deadline_in(&self, timeout: Option<Duration>) -> Option<Duration> {
        timeout.map(|t| self.clock.now() + t)
    }

    /// Register an executor signal to be poked on counter changes.
    pub fn watch(&self, signal: Arc<Signal>) {
        self.watchers.lock().unwrap().push(signal);
    }

    fn poke_watchers(&self) {
        for w in self.watchers.lock().unwrap().iter() {
            w.poke();
        }
    }

    /// Draw the next private version. Caller must hold this object's
    /// start lock (enforced structurally by [`acquire_start_locks`]).
    pub fn assign_pv(&self) -> u64 {
        let mut s = self.state.lock().unwrap();
        s.next_pv += 1;
        s.next_pv
    }

    /// Current `(lv, ltv)` snapshot (diagnostics, executor conditions).
    pub fn versions(&self) -> (u64, u64) {
        let s = self.state.lock().unwrap();
        (s.lv, s.ltv)
    }

    /// Non-blocking access-condition check: `pv - 1 == lv`.
    pub fn access_ready(&self, pv: u64) -> bool {
        self.state.lock().unwrap().lv == pv - 1
    }

    /// Non-blocking commit-condition check: `pv - 1 == ltv`.
    pub fn commit_ready(&self, pv: u64) -> bool {
        self.state.lock().unwrap().ltv == pv - 1
    }

    /// Block until the access condition holds, then record the grant in
    /// `max_granted`. `deadline` is absolute, in this block's clock time;
    /// `None` waits forever.
    pub fn wait_access(&self, pv: u64, deadline: Option<Duration>) -> Result<(), WaitTimeout> {
        let started = self.clock.now();
        let mut s = self.state.lock().unwrap();
        while s.lv != pv - 1 {
            let (g, expired) = wait_deadline(self.clock.as_ref(), &self.cond, s, deadline);
            s = g;
            // A wake-up racing the deadline: the condition wins.
            if expired && s.lv != pv - 1 {
                return Err(self.timeout(started, "access condition"));
            }
        }
        s.max_granted = s.max_granted.max(pv);
        Ok(())
    }

    /// Block until the commit/termination condition holds. Used by commit
    /// and abort, and — for *irrevocable* transactions (§2.4) — in place
    /// of every access-condition wait, so they never observe early-released
    /// state. On success also records the grant.
    pub fn wait_commit_cond(&self, pv: u64, deadline: Option<Duration>) -> Result<(), WaitTimeout> {
        let started = self.clock.now();
        let mut s = self.state.lock().unwrap();
        while s.ltv != pv - 1 {
            let (g, expired) = wait_deadline(self.clock.as_ref(), &self.cond, s, deadline);
            s = g;
            if expired && s.ltv != pv - 1 {
                return Err(self.timeout(started, "commit condition"));
            }
        }
        s.max_granted = s.max_granted.max(pv);
        Ok(())
    }

    // ------------------------------------------------------------------
    // Group grants: commuting acquisitions share one version slot.
    // ------------------------------------------------------------------

    /// Non-blocking [`ObjectCc::join_group`] admission check (explorer
    /// gates need exactness; see `Proxy::ready_for`).
    pub fn group_joinable(&self, pv: u64, class: u8) -> bool {
        let s = self.state.lock().unwrap();
        Self::group_admission(&s, pv, class)
    }

    fn group_admission(s: &CcState, pv: u64, class: u8) -> bool {
        if let Some(g) = &s.group {
            // Extend an open group: same class, consecutive pv, and at
            // least one member still holds access (once all released, the
            // chain has already advanced past the group).
            return g.class == class && g.active > 0 && pv == g.last_pv + 1;
        }
        // Open a new group at the head of the chain. A fully-released but
        // not fully-terminated group blocks this (handled above by the
        // `group.is_some()` arm failing): group-to-group admission waits
        // for the previous group's termination so `ltv` bookkeeping stays
        // a single range.
        s.lv == pv - 1
    }

    /// Block until `pv` can join (or open) a commuting pv-group of
    /// `class`, then record the grant. Returns the group's `first_pv` —
    /// the member's commit condition becomes `ltv == first_pv - 1`
    /// ([`ObjectCc::wait_commit_cond_group`]). Admission is immediate for
    /// consecutive same-class acquisitions even though `lv < pv - 1`:
    /// that concurrency is the whole point (docs/COMMUTATIVITY.md).
    pub fn join_group(
        &self,
        pv: u64,
        class: u8,
        deadline: Option<Duration>,
    ) -> Result<u64, WaitTimeout> {
        let started = self.clock.now();
        let mut s = self.state.lock().unwrap();
        while !Self::group_admission(&s, pv, class) {
            let (g, expired) = wait_deadline(self.clock.as_ref(), &self.cond, s, deadline);
            s = g;
            if expired && !Self::group_admission(&s, pv, class) {
                return Err(self.timeout(started, "group admission"));
            }
        }
        let first_pv = match &mut s.group {
            Some(g) => {
                g.last_pv = pv;
                g.active += 1;
                g.unterminated += 1;
                g.first_pv
            }
            None => {
                s.group = Some(GroupState {
                    class,
                    first_pv: pv,
                    last_pv: pv,
                    active: 1,
                    unterminated: 1,
                });
                pv
            }
        };
        s.max_granted = s.max_granted.max(pv);
        Ok(first_pv)
    }

    /// Release a group member's access. When the *last* active member
    /// releases, the group retires: the chain advances past the whole
    /// group (`lv = last_pv`) in one step. Returns whether this call
    /// retired the group (trace: `GroupRetire`).
    pub fn release_group(&self, pv: u64) -> bool {
        let mut s = self.state.lock().unwrap();
        let g = s
            .group
            .as_mut()
            .expect("release_group: no open group (member released twice?)");
        debug_assert!(
            g.first_pv <= pv && pv <= g.last_pv,
            "release_group: pv {pv} outside group [{}, {}]",
            g.first_pv,
            g.last_pv
        );
        debug_assert!(g.active > 0, "release_group: no active members");
        g.active -= 1;
        if g.active > 0 {
            return false;
        }
        let last = g.last_pv;
        if s.lv < last {
            s.lv = last;
            self.cond.notify_all();
            drop(s);
            self.poke_watchers();
        }
        true
    }

    /// Group-member commit (termination) condition: every transaction
    /// *before the group* has terminated. Intra-group termination order
    /// is free — the members commute.
    pub fn wait_commit_cond_group(
        &self,
        first_pv: u64,
        deadline: Option<Duration>,
    ) -> Result<(), WaitTimeout> {
        let started = self.clock.now();
        let mut s = self.state.lock().unwrap();
        while s.ltv + 1 < first_pv {
            let (g, expired) = wait_deadline(self.clock.as_ref(), &self.cond, s, deadline);
            s = g;
            if expired && s.ltv + 1 < first_pv {
                return Err(self.timeout(started, "group commit condition"));
            }
        }
        Ok(())
    }

    /// Non-blocking group commit-condition check (explorer gate).
    pub fn commit_ready_group(&self, first_pv: u64) -> bool {
        self.state.lock().unwrap().ltv + 1 >= first_pv
    }

    /// Terminate a group member. When the *last* member terminates, the
    /// group dissolves: `ltv` advances past the whole group and stale
    /// invalidation marks are pruned. Waiters are notified so the next
    /// group (or chain successor) can proceed.
    pub fn terminate_group(&self, pv: u64) -> bool {
        let mut s = self.state.lock().unwrap();
        let g = s
            .group
            .as_mut()
            .expect("terminate_group: no open group (member terminated twice?)");
        debug_assert!(
            g.first_pv <= pv && pv <= g.last_pv,
            "terminate_group: pv {pv} outside group [{}, {}]",
            g.first_pv,
            g.last_pv
        );
        debug_assert!(g.unterminated > 0, "terminate_group: no unterminated members");
        g.unterminated -= 1;
        if g.unterminated > 0 {
            return false;
        }
        debug_assert_eq!(g.active, 0, "all members release before the last terminates");
        let last = g.last_pv;
        s.group = None;
        if s.ltv < last {
            s.ltv = last;
            let ltv = s.ltv;
            s.marks.retain(|m| m.up_to > ltv);
        }
        self.cond.notify_all();
        drop(s);
        self.poke_watchers();
        true
    }

    /// The open pv-group, if any (tests, diagnostics).
    pub fn group(&self) -> Option<GroupState> {
        self.state.lock().unwrap().group
    }

    fn timeout(&self, started: Duration, what: &'static str) -> WaitTimeout {
        WaitTimeout {
            what,
            waited_ms: self.clock.now().saturating_sub(started).as_millis() as u64,
        }
    }

    /// Release the object on behalf of `pv`: set `lv = pv` (early release,
    /// commit, or abort). Idempotent: later calls with the same pv no-op.
    pub fn release(&self, pv: u64) {
        let mut s = self.state.lock().unwrap();
        debug_assert!(
            s.lv == pv - 1 || s.lv >= pv,
            "release out of order: lv={} pv={}",
            s.lv,
            pv
        );
        if s.lv < pv {
            s.lv = pv;
            self.cond.notify_all();
            drop(s);
            self.poke_watchers();
        }
    }

    /// Terminate on behalf of `pv`: set `ltv = pv` and prune stale marks.
    pub fn terminate(&self, pv: u64) {
        let mut s = self.state.lock().unwrap();
        debug_assert!(
            s.ltv == pv - 1 || s.ltv >= pv,
            "terminate out of order: ltv={} pv={}",
            s.ltv,
            pv
        );
        if s.ltv < pv {
            s.ltv = pv;
            let ltv = s.ltv;
            s.marks.retain(|m| m.up_to > ltv);
            self.cond.notify_all();
            drop(s);
            self.poke_watchers();
        }
    }

    /// Record that `pv` observed the object without passing through
    /// `wait_access` (asynchronous buffering path): update `max_granted`.
    pub fn note_granted(&self, pv: u64) {
        let mut s = self.state.lock().unwrap();
        s.max_granted = s.max_granted.max(pv);
    }

    /// Place an invalidation mark for an aborting transaction: every pv in
    /// `(marker_pv, max_granted]` observed potentially-invalid state and is
    /// doomed (§2.3).
    pub fn mark_invalid(&self, marker_pv: u64) {
        let mut s = self.state.lock().unwrap();
        let up_to = s.max_granted;
        if up_to > marker_pv {
            s.marks.push(InvalidMark { marker_pv, up_to });
        }
    }

    /// Current restore epoch. Sampled (under the object's lock) when a
    /// checkpoint is captured; compared at abort time to decide whether the
    /// checkpoint is from the valid lineage (§2.8.6: restore "unless some
    /// other transaction that previously aborted already restored it to an
    /// older version" — an intervening restore means a preceding aborter
    /// already reverted past our checkpoint, which captured
    /// since-invalidated state).
    pub fn epoch(&self) -> u64 {
        self.state.lock().unwrap().epoch
    }

    /// Record that an aborter at `pv` restored the object's state from its
    /// checkpoint (a *full* reversion: everything at or after `pv` is
    /// rewound).
    pub fn note_restored(&self, pv: u64) {
        let mut s = self.state.lock().unwrap();
        s.epoch += 1;
        s.revert_seq += 1;
        let seq = s.revert_seq;
        s.reverts.push(RevertNote { seq, pv, full: true, ops: Vec::new() });
    }

    /// Current reversion sequence number. Sampled (under the object's
    /// lock) alongside a checkpoint or a group join; compared via
    /// [`ObjectCc::wiped_since`] / replayed via
    /// [`ObjectCc::surgical_reverts_since`] at abort time.
    pub fn revert_seq(&self) -> u64 {
        self.state.lock().unwrap().revert_seq
    }

    /// Record a *surgical* positional reversion at `pv`: a commuting group
    /// member applied its inverse `ops`, reverting its own contribution
    /// only. Deliberately does not bump the restore epoch — the lineage is
    /// intact, so earlier transactions' checkpoints stay valid.
    pub fn note_reverted(&self, pv: u64, ops: Vec<crate::object::OpCall>) {
        let mut s = self.state.lock().unwrap();
        s.revert_seq += 1;
        let seq = s.revert_seq;
        s.reverts.push(RevertNote { seq, pv, full: false, ops });
    }

    /// Did a *full* restore positioned before `below_pv` happen after
    /// sequence number `since`? If so, that restore already rewound the
    /// asker's work wholesale: an exclusive-chain aborter must not restore
    /// its (since-invalidated) checkpoint, and a group member whose group
    /// sits above the restorer must not apply its inverses.
    pub fn wiped_since(&self, since: u64, below_pv: u64) -> bool {
        let s = self.state.lock().unwrap();
        s.reverts
            .iter()
            .rev()
            .take_while(|n| n.seq > since)
            .any(|n| n.full && n.pv < below_pv)
    }

    /// Did any reversion (full or surgical) positioned before `below_pv`
    /// happen after sequence number `since`? Diagnostics/tests.
    pub fn reverted_since(&self, since: u64, below_pv: u64) -> bool {
        let s = self.state.lock().unwrap();
        s.reverts
            .iter()
            .rev()
            .take_while(|n| n.seq > since)
            .any(|n| n.pv < below_pv)
    }

    /// The inverse operations of surgical reversions positioned before
    /// `below_pv` recorded after sequence number `since`, in application
    /// order. An aborter that restores a checkpoint taken at `since`
    /// re-instates those members' contributions (the snapshot predates
    /// their reverts), so it must replay these on top of the restore.
    pub fn surgical_reverts_since(&self, since: u64, below_pv: u64) -> Vec<crate::object::OpCall> {
        let s = self.state.lock().unwrap();
        s.reverts
            .iter()
            .filter(|n| n.seq > since && !n.full && n.pv < below_pv)
            .flat_map(|n| n.ops.iter().cloned())
            .collect()
    }

    /// Is the transaction holding `pv` doomed by an invalidation mark?
    pub fn doomed(&self, pv: u64) -> bool {
        let s = self.state.lock().unwrap();
        s.marks
            .iter()
            .any(|m| m.marker_pv < pv && pv <= m.up_to)
    }

    /// Active marks (diagnostics/tests).
    pub fn marks(&self) -> Vec<InvalidMark> {
        self.state.lock().unwrap().marks.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn pv_assignment_is_sequential() {
        let cc = ObjectCc::new();
        assert_eq!(cc.assign_pv(), 1);
        assert_eq!(cc.assign_pv(), 2);
        assert_eq!(cc.assign_pv(), 3);
    }

    #[test]
    fn access_condition_gates_in_pv_order() {
        let cc = Arc::new(ObjectCc::new());
        let pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        assert!(cc.access_ready(pv1));
        assert!(!cc.access_ready(pv2));

        let cc2 = Arc::clone(&cc);
        let waiter = thread::spawn(move || {
            let deadline = cc2.deadline_in(Some(Duration::from_secs(5)));
            cc2.wait_access(pv2, deadline)
                .expect("pv2 should eventually be granted");
        });
        thread::sleep(Duration::from_millis(20));
        cc.wait_access(pv1, None).unwrap();
        cc.release(pv1);
        waiter.join().unwrap();
        assert!(cc.access_ready(pv2));
    }

    #[test]
    fn commit_condition_follows_terminate_not_release() {
        let cc = ObjectCc::new();
        let pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        cc.release(pv1); // early release: lv=1 but ltv=0
        assert!(cc.access_ready(pv2));
        assert!(!cc.commit_ready(pv2));
        cc.terminate(pv1);
        assert!(cc.commit_ready(pv2));
    }

    #[test]
    fn wait_times_out() {
        let cc = ObjectCc::new();
        let _pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        let deadline = cc.deadline_in(Some(Duration::from_millis(30)));
        let r = cc.wait_access(pv2, deadline);
        assert!(r.is_err());
    }

    #[test]
    fn wait_times_out_in_virtual_time_without_real_sleeping() {
        use crate::clock::VirtualClock;
        let cc = ObjectCc::with_clock(Arc::new(VirtualClock::new()));
        let _pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        // A 30-second *virtual* deadline on a stalled clock must fire in
        // bounded real time via the stall escape hatch.
        let deadline = cc.deadline_in(Some(Duration::from_secs(30)));
        let t0 = std::time::Instant::now();
        let r = cc.wait_access(pv2, deadline);
        assert!(r.is_err());
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "virtual timeout must not consume real time"
        );
        assert!(cc.clock().now() >= Duration::from_secs(30));
    }

    #[test]
    fn release_is_idempotent() {
        let cc = ObjectCc::new();
        let pv = cc.assign_pv();
        cc.release(pv);
        cc.release(pv); // second release must not panic or regress lv
        assert_eq!(cc.versions().0, pv);
    }

    #[test]
    fn invalidation_dooms_only_the_granted_window() {
        let cc = ObjectCc::new();
        let pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        let pv3 = cc.assign_pv();
        // T1 accesses and releases early; T2 accesses.
        cc.wait_access(pv1, None).unwrap();
        cc.release(pv1);
        cc.wait_access(pv2, None).unwrap();
        // T1 aborts: marks invalid. T2 (already granted) is doomed; T3 is not.
        cc.mark_invalid(pv1);
        assert!(cc.doomed(pv2));
        assert!(!cc.doomed(pv3), "pv3 never observed invalid state");
        assert!(!cc.doomed(pv1), "the marker itself is not doomed");
    }

    #[test]
    fn marks_prune_after_doomed_window_terminates() {
        let cc = ObjectCc::new();
        let pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        cc.wait_access(pv1, None).unwrap();
        cc.release(pv1);
        cc.wait_access(pv2, None).unwrap();
        cc.mark_invalid(pv1);
        assert_eq!(cc.marks().len(), 1);
        cc.terminate(pv1);
        cc.release(pv2);
        cc.terminate(pv2); // ltv reaches up_to → mark pruned
        assert!(cc.marks().is_empty());
    }

    #[test]
    fn restore_epoch_distinguishes_lineages() {
        let cc = ObjectCc::new();
        let pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        cc.wait_access(pv1, None).unwrap();
        cc.release(pv1);
        // T2 checkpoints while T1's (dirty) state is visible.
        cc.wait_access(pv2, None).unwrap();
        let t2_epoch = cc.epoch();
        // T1 aborts: restores, bumping the epoch.
        cc.mark_invalid(pv1);
        cc.note_restored(pv1);
        // T2's checkpoint is from the invalidated lineage: must not restore.
        assert_ne!(t2_epoch, cc.epoch());
        // A fresh transaction checkpointing *after* the restore holds a
        // valid-lineage checkpoint and restores on abort.
        let pv3 = cc.assign_pv();
        cc.terminate(pv1);
        cc.release(pv2);
        cc.wait_access(pv3, None).unwrap();
        assert_eq!(cc.epoch(), cc.epoch());
        let t3_epoch = cc.epoch();
        assert_eq!(t3_epoch, cc.epoch(), "no restore since T3's checkpoint");
    }

    #[test]
    fn group_members_admitted_concurrently() {
        let cc = ObjectCc::new();
        let pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        let pv3 = cc.assign_pv();
        // pv1 opens the group at the chain head; pv2/pv3 extend it without
        // waiting for pv1 to release — the whole point of group grants.
        assert_eq!(cc.join_group(pv1, 0, None).unwrap(), pv1);
        assert_eq!(cc.join_group(pv2, 0, None).unwrap(), pv1);
        assert_eq!(cc.join_group(pv3, 0, None).unwrap(), pv1);
        let g = cc.group().unwrap();
        assert_eq!((g.first_pv, g.last_pv, g.active, g.unterminated), (pv1, pv3, 3, 3));
        // A plain (non-commuting) successor is NOT admitted: lv is still 0.
        let pv4 = cc.assign_pv();
        assert!(!cc.access_ready(pv4));
    }

    #[test]
    fn group_rejects_other_class_and_gap() {
        let cc = ObjectCc::new();
        let pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        let pv3 = cc.assign_pv();
        cc.join_group(pv1, 0, None).unwrap();
        // Different class cannot extend the open group.
        assert!(!cc.group_joinable(pv2, 1));
        // Non-consecutive pv cannot extend it either (pv2 skipped).
        assert!(!cc.group_joinable(pv3, 0));
        assert!(cc.group_joinable(pv2, 0));
    }

    #[test]
    fn group_retires_on_last_release_and_dissolves_on_last_terminate() {
        let cc = ObjectCc::new();
        let pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        let pv3 = cc.assign_pv(); // plain successor
        cc.join_group(pv1, 0, None).unwrap();
        cc.join_group(pv2, 0, None).unwrap();
        // Releases in arbitrary intra-group order; chain advances only on
        // the last one, straight to last_pv.
        assert!(!cc.release_group(pv2));
        assert_eq!(cc.versions().0, 0);
        assert!(!cc.access_ready(pv3));
        assert!(cc.release_group(pv1));
        assert_eq!(cc.versions().0, pv2);
        assert!(cc.access_ready(pv3), "successor admitted after group retire");
        // Termination likewise: ltv jumps past the whole group at the end.
        assert!(!cc.terminate_group(pv1));
        assert!(!cc.commit_ready(pv3));
        assert!(cc.terminate_group(pv2));
        assert_eq!(cc.versions().1, pv2);
        assert!(cc.commit_ready(pv3));
        assert!(cc.group().is_none(), "group dissolved");
    }

    #[test]
    fn group_commit_condition_ignores_intra_group_order() {
        let cc = ObjectCc::new();
        let pv0 = cc.assign_pv(); // plain predecessor
        let pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        cc.wait_access(pv0, None).unwrap();
        cc.release(pv0);
        let first = cc.join_group(pv1, 0, None).unwrap();
        assert_eq!(cc.join_group(pv2, 0, None).unwrap(), first);
        // Neither member may commit until the predecessor terminates…
        assert!(!cc.commit_ready_group(first));
        cc.terminate(pv0);
        // …after which BOTH are ready, regardless of intra-group order.
        assert!(cc.commit_ready_group(first));
        let deadline = cc.deadline_in(Some(Duration::from_secs(1)));
        cc.wait_commit_cond_group(first, deadline).unwrap();
    }

    #[test]
    fn new_group_waits_for_previous_group_termination() {
        let cc = ObjectCc::new();
        let pv1 = cc.assign_pv();
        cc.join_group(pv1, 0, None).unwrap();
        cc.release_group(pv1);
        // Group released but not terminated: a new acquisition (even of the
        // same class) must not open a second group yet.
        let pv2 = cc.assign_pv();
        assert!(!cc.group_joinable(pv2, 0));
        cc.terminate_group(pv1);
        assert!(cc.group_joinable(pv2, 0));
        assert_eq!(cc.join_group(pv2, 0, None).unwrap(), pv2);
    }

    #[test]
    fn group_members_doomed_by_member_abort_mark() {
        let cc = ObjectCc::new();
        let pv1 = cc.assign_pv();
        let pv2 = cc.assign_pv();
        cc.join_group(pv1, 0, None).unwrap();
        cc.join_group(pv2, 0, None).unwrap();
        // pv1 aborts: inverse applied by the proxy, then the usual mark.
        // max_granted is pv2, so the co-member is doomed conservatively.
        cc.mark_invalid(pv1);
        assert!(cc.doomed(pv2));
        cc.release_group(pv1);
        cc.release_group(pv2);
        cc.terminate_group(pv2);
        cc.terminate_group(pv1);
        assert!(cc.marks().is_empty(), "marks pruned when ltv passes up_to");
    }

    #[test]
    fn revert_notes_are_positional() {
        use crate::object::account::ops;
        let cc = ObjectCc::new();
        let seq0 = cc.revert_seq();
        cc.note_reverted(5, vec![ops::withdraw(40)]);
        // A surgical reversion at pv=5 is visible to later positions only.
        assert!(cc.reverted_since(seq0, 7), "pv 7 sampled before the revert at 5");
        assert!(!cc.reverted_since(seq0, 5), "pv ≤ 5 unaffected");
        assert!(!cc.reverted_since(seq0, 3));
        // Surgical reverts never wipe — a later aborter still restores its
        // checkpoint, then replays the recorded inverse ops on top.
        assert!(!cc.wiped_since(seq0, 7));
        let replay = cc.surgical_reverts_since(seq0, 7);
        assert_eq!(replay.len(), 1);
        assert_eq!(replay[0].method, "withdraw");
        assert!(cc.surgical_reverts_since(seq0, 5).is_empty(), "own position excluded");
        // Events at or before the sampled seq are invisible.
        let seq1 = cc.revert_seq();
        assert!(!cc.reverted_since(seq1, 100));
        cc.note_reverted(10, vec![ops::withdraw(1)]);
        assert!(cc.reverted_since(seq1, 11));
        // A full restore at pv=3 wipes positions above it.
        cc.note_restored(3);
        assert!(cc.wiped_since(seq1, 7), "full restore below pv 7 wipes it");
        assert!(!cc.wiped_since(seq1, 3), "restorer's own position unaffected");
        assert!(
            cc.surgical_reverts_since(seq1, 7).iter().all(|c| c.method == "withdraw"),
            "full notes carry no replay ops"
        );
    }

    #[test]
    fn watchers_poked_on_release_and_terminate() {
        let cc = ObjectCc::new();
        let sig = Arc::new(Signal::new());
        cc.watch(Arc::clone(&sig));
        let g0 = sig.generation();
        let pv = cc.assign_pv();
        cc.release(pv);
        assert!(sig.generation() > g0);
        let g1 = sig.generation();
        cc.terminate(pv);
        assert!(sig.generation() > g1);
    }
}
