//! A blocking readers–writer lock with explicit lock/unlock (no guards).
//!
//! `std::sync::RwLock` returns RAII guards tied to the acquiring thread's
//! borrow; distributed 2PL needs locks that are acquired in one call and
//! released in another, potentially interleaved with long waits. This is a
//! plain condvar-based implementation with writer preference (a waiting
//! writer blocks new readers), which is what a fair distributed lock
//! service would provide.
//!
//! The wait loops here are purely notify-driven — no timeouts, no
//! sleeping — so they are virtual-time neutral by construction: under a
//! [`crate::clock::VirtualClock`] a waiter blocks only until the holder
//! (whose simulated work costs zero wall time) releases. The *latency* of
//! acquiring a remote lock is charged by the caller through
//! [`crate::cluster::Cluster::rpc`], which runs on the cluster clock.

use std::sync::{Condvar, Mutex};

/// Shared (read) or exclusive (write) acquisition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LockMode {
    /// Shared acquisition: any number of concurrent readers.
    Shared,
    /// Exclusive acquisition: one writer, no readers.
    Exclusive,
}

#[derive(Default)]
struct State {
    readers: u64,
    writer: bool,
    writers_waiting: u64,
}

/// Explicit-release readers–writer lock (also used as a mutex by always
/// acquiring `Exclusive`).
pub struct DistRwLock {
    state: Mutex<State>,
    cond: Condvar,
}

impl Default for DistRwLock {
    fn default() -> Self {
        Self::new()
    }
}

impl DistRwLock {
    /// An unlocked lock.
    pub fn new() -> Self {
        DistRwLock { state: Mutex::new(State::default()), cond: Condvar::new() }
    }

    /// Block until the lock is held in `mode`.
    pub fn lock(&self, mode: LockMode) {
        let mut s = self.state.lock().unwrap();
        match mode {
            LockMode::Shared => {
                while s.writer || s.writers_waiting > 0 {
                    s = self.cond.wait(s).unwrap();
                }
                s.readers += 1;
            }
            LockMode::Exclusive => {
                s.writers_waiting += 1;
                while s.writer || s.readers > 0 {
                    s = self.cond.wait(s).unwrap();
                }
                s.writers_waiting -= 1;
                s.writer = true;
            }
        }
    }

    /// Try to acquire without blocking. Returns `true` on success.
    pub fn try_lock(&self, mode: LockMode) -> bool {
        let mut s = self.state.lock().unwrap();
        match mode {
            LockMode::Shared => {
                if s.writer || s.writers_waiting > 0 {
                    return false;
                }
                s.readers += 1;
                true
            }
            LockMode::Exclusive => {
                if s.writer || s.readers > 0 {
                    return false;
                }
                s.writer = true;
                true
            }
        }
    }

    /// Release a previously acquired lock.
    pub fn unlock(&self, mode: LockMode) {
        let mut s = self.state.lock().unwrap();
        match mode {
            LockMode::Shared => {
                assert!(s.readers > 0, "unlock(Shared) without readers");
                s.readers -= 1;
            }
            LockMode::Exclusive => {
                assert!(s.writer, "unlock(Exclusive) without a writer");
                s.writer = false;
            }
        }
        self.cond.notify_all();
    }

    /// Current holder counts `(readers, writer)` — diagnostics.
    pub fn holders(&self) -> (u64, bool) {
        let s = self.state.lock().unwrap();
        (s.readers, s.writer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn readers_share_writers_exclude() {
        let l = DistRwLock::new();
        l.lock(LockMode::Shared);
        l.lock(LockMode::Shared);
        assert_eq!(l.holders(), (2, false));
        assert!(!l.try_lock(LockMode::Exclusive));
        l.unlock(LockMode::Shared);
        l.unlock(LockMode::Shared);
        assert!(l.try_lock(LockMode::Exclusive));
        assert!(!l.try_lock(LockMode::Shared));
        l.unlock(LockMode::Exclusive);
    }

    #[test]
    fn writer_waits_for_readers() {
        let l = Arc::new(DistRwLock::new());
        l.lock(LockMode::Shared);
        let l2 = Arc::clone(&l);
        let w = thread::spawn(move || {
            l2.lock(LockMode::Exclusive);
            l2.unlock(LockMode::Exclusive);
        });
        thread::sleep(Duration::from_millis(30));
        assert!(!w.is_finished());
        l.unlock(LockMode::Shared);
        w.join().unwrap();
    }

    #[test]
    fn waiting_writer_blocks_new_readers() {
        let l = Arc::new(DistRwLock::new());
        l.lock(LockMode::Shared);
        let l2 = Arc::clone(&l);
        let w = thread::spawn(move || {
            l2.lock(LockMode::Exclusive);
            l2.unlock(LockMode::Exclusive);
        });
        thread::sleep(Duration::from_millis(20));
        // Writer is queued: a new reader must not starve it.
        assert!(!l.try_lock(LockMode::Shared));
        l.unlock(LockMode::Shared);
        w.join().unwrap();
    }

    #[test]
    fn many_threads_mutex_discipline() {
        let l = Arc::new(DistRwLock::new());
        let counter = Arc::new(Mutex::new(0u64));
        let mut hs = vec![];
        for _ in 0..16 {
            let (l, c) = (Arc::clone(&l), Arc::clone(&counter));
            hs.push(thread::spawn(move || {
                for _ in 0..50 {
                    l.lock(LockMode::Exclusive);
                    let mut g = c.lock().unwrap();
                    *g += 1;
                    drop(g);
                    l.unlock(LockMode::Exclusive);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(*counter.lock().unwrap(), 16 * 50);
    }
}
