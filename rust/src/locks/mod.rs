//! Distributed lock-based concurrency control baselines (paper §4.1).
//!
//! Five configurations, all custom-built on the simulated RMI substrate:
//!
//! | name          | lock per object | acquisition      | release          |
//! |---------------|-----------------|------------------|------------------|
//! | `Mutex S2PL`  | mutual exclusion| all at start     | all at commit    |
//! | `Mutex 2PL`   | mutual exclusion| all at start     | after last use   |
//! | `R/W S2PL`    | readers–writer  | all at start     | all at commit    |
//! | `R/W 2PL`     | readers–writer  | all at start     | after last use   |
//! | `GLock`       | one global lock | at start         | at commit        |
//!
//! S2PL is conservative (strong) strict two-phase locking and satisfies
//! opacity; 2PL releases each lock as soon as the transaction's declared
//! last access to the object has happened (the paper's programmer-
//! determined early unlock), satisfying last-use opacity. Locks are always
//! acquired in global `Oid` order, so no deadlock is possible. None of the
//! lock baselines ever abort (other than by manual request, which simply
//! re-raises after releasing — there is no rollback: like the paper's lock
//! variants, state restoration is the programmer's problem, so workloads
//! using them must be abort-free).

mod rwlock;

pub use rwlock::{DistRwLock, LockMode};

use crate::api::{Dtm, ObjHandle, OpFuture, TxCtx, TxError, TxSpec, TxStats};
use crate::cluster::{Cluster, NodeId, Oid};
use crate::object::{OpCall, SharedObject, Value};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Which lock structure guards each object.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LockKind {
    /// One mutual-exclusion lock per object.
    Mutex,
    /// One readers–writer lock per object: read-only access sets take the
    /// shared mode.
    ReadWrite,
    /// A single global mutual-exclusion lock (fully serial baseline).
    Global,
}

/// Release discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Discipline {
    /// Conservative strict 2PL: hold everything until commit.
    S2pl,
    /// Early unlock after the declared last access (suprema reached).
    Tpl,
}

/// A hosted object guarded by a lock.
struct Slot {
    oid: Oid,
    lock: DistRwLock,
    object: Mutex<Box<dyn SharedObject>>,
}

/// The lock-based "framework".
pub struct LockSystem {
    cluster: Arc<Cluster>,
    kind: LockKind,
    discipline: Discipline,
    slots: Vec<RwLock<Vec<Arc<Slot>>>>,
    glock: DistRwLock,
    /// Committed transactions.
    pub commits: AtomicU64,
    /// Programmatic aborts ([`crate::api::TxError::ManualAbort`]).
    pub manual_aborts: AtomicU64,
}

impl LockSystem {
    /// A lock-based system over `cluster` with the given lock kind and
    /// locking discipline.
    pub fn new(cluster: Arc<Cluster>, kind: LockKind, discipline: Discipline) -> Arc<Self> {
        let slots = cluster.node_ids().map(|_| RwLock::new(Vec::new())).collect();
        Arc::new(LockSystem {
            cluster,
            kind,
            discipline,
            slots,
            glock: DistRwLock::new(),
            commits: AtomicU64::new(0),
            manual_aborts: AtomicU64::new(0),
        })
    }

    /// Host `object` on `node` under `name`.
    pub fn host(&self, node: NodeId, name: &str, object: Box<dyn SharedObject>) -> Oid {
        let mut slots = self.slots[node.0 as usize].write().unwrap();
        let oid = Oid::new(node, slots.len() as u32);
        slots.push(Arc::new(Slot {
            oid,
            lock: DistRwLock::new(),
            object: Mutex::new(object),
        }));
        drop(slots);
        self.cluster.registry.bind(name, oid);
        oid
    }

    fn slot(&self, oid: Oid) -> Arc<Slot> {
        let slots = self.slots[oid.node.0 as usize].read().unwrap();
        Arc::clone(&slots[oid.index as usize])
    }

    /// Peek at an object's state (non-transactional test helper).
    pub fn with_object<R>(&self, oid: Oid, f: impl FnOnce(&dyn SharedObject) -> R) -> R {
        let slot = self.slot(oid);
        let obj = slot.object.lock().unwrap();
        f(obj.as_ref())
    }

    /// The cluster this system runs on.
    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    fn label(&self) -> &'static str {
        match (self.kind, self.discipline) {
            (LockKind::Mutex, Discipline::S2pl) => "mutex-s2pl",
            (LockKind::Mutex, Discipline::Tpl) => "mutex-2pl",
            (LockKind::ReadWrite, Discipline::S2pl) => "rw-s2pl",
            (LockKind::ReadWrite, Discipline::Tpl) => "rw-2pl",
            (LockKind::Global, _) => "glock",
        }
    }
}

struct HeldLock {
    slot: Arc<Slot>,
    /// `None` under GLock: no per-object lock is held, the global lock
    /// covers everything.
    mode: Option<LockMode>,
    /// Total declared accesses; lock released once `count` reaches it
    /// under the 2PL discipline.
    ub: u64,
    count: u64,
    released: bool,
}

struct LockTx<'a> {
    sys: &'a LockSystem,
    client: NodeId,
    held: Vec<HeldLock>,
    glock_held: bool,
    ops: u64,
}

impl LockTx<'_> {
    fn invoke(&mut self, h: ObjHandle, call: &OpCall) -> Result<Value, TxError> {
        let hl = &mut self.held[h.0];
        if hl.released && hl.mode.is_some() {
            return Err(TxError::SupremaExceeded {
                oid: hl.slot.oid,
                mode: "any",
                count: hl.count + 1,
                bound: hl.ub,
            });
        }
        let mut obj = hl.slot.object.lock().unwrap();
        let v = obj.invoke(call)?;
        drop(obj);
        hl.count += 1;
        self.ops += 1;
        // 2PL: programmer-determined last access ⇒ early unlock.
        if self.sys.discipline == Discipline::Tpl && hl.count == hl.ub {
            if let Some(mode) = hl.mode {
                hl.slot.lock.unlock(mode);
            }
            hl.released = true;
        }
        Ok(v)
    }

    fn release_all(&mut self) {
        for hl in &mut self.held {
            if !hl.released {
                if let Some(mode) = hl.mode {
                    hl.slot.lock.unlock(mode);
                }
                hl.released = true;
            }
        }
        if self.glock_held {
            self.sys.glock.unlock(LockMode::Exclusive);
            self.glock_held = false;
        }
    }
}

impl TxCtx for LockTx<'_> {
    /// Lock-based transactions hold their locks for the duration anyway:
    /// `submit` executes inline and returns a resolved future, so `call`
    /// (the trait default) is unchanged.
    fn submit(&mut self, h: ObjHandle, call: OpCall) -> Result<OpFuture, TxError> {
        let node = self.held[h.0].slot.oid.node;
        let req = call.wire_size();
        let client = self.client;
        let cluster = Arc::clone(&self.sys.cluster);
        Ok(OpFuture::ready(cluster.rpc(client, node, req, || {
            let r = self.invoke(h, &call);
            let resp = match &r {
                Ok(v) => v.wire_size(),
                Err(_) => 16,
            };
            (r, resp)
        })))
    }

    fn client(&self) -> NodeId {
        self.client
    }
}

impl Dtm for Arc<LockSystem> {
    fn framework_name(&self) -> &'static str {
        self.label()
    }

    // Locks never abort (everything is effectively irrevocable) and never
    // retry: the spec's irrevocable/timeout/asynchrony knobs are ignored
    // and `attempts` is always 1.
    fn run_tx(
        &self,
        client: NodeId,
        spec: &TxSpec,
        body: &mut dyn FnMut(&mut dyn TxCtx) -> Result<(), TxError>,
    ) -> Result<TxStats, TxError> {
        let cluster = Arc::clone(&self.cluster);
        let decls = &spec.decls;

        // Resolve and sort the access set by Oid — the global lock order.
        let mut resolved: Vec<(usize, Oid)> = Vec::with_capacity(decls.len());
        for (i, d) in decls.iter().enumerate() {
            let oid = cluster
                .registry
                .locate(&d.name)
                .ok_or_else(|| TxError::NotDeclared(d.name.clone()))?;
            resolved.push((i, oid));
        }
        let mut order: Vec<usize> = (0..resolved.len()).collect();
        order.sort_by_key(|&k| resolved[k].1);

        let mut tx = LockTx { sys: self, client, held: Vec::new(), glock_held: false, ops: 0 };

        if self.kind == LockKind::Global {
            // The global lock lives on node 0.
            cluster.rpc(client, NodeId(0), 24, || {
                self.glock.lock(LockMode::Exclusive);
                ((), 16)
            });
            tx.glock_held = true;
        }

        // Acquire per-object locks in global order (deadlock-free).
        let mut held: Vec<Option<HeldLock>> = (0..decls.len()).map(|_| None).collect();
        for &k in &order {
            let (i, oid) = resolved[k];
            let slot = self.slot(oid);
            let mode = match self.kind {
                LockKind::Global => None, // covered by the global lock
                LockKind::ReadWrite if decls[i].suprema.read_only() => Some(LockMode::Shared),
                _ => Some(LockMode::Exclusive),
            };
            if let Some(mode) = mode {
                cluster.rpc(client, oid.node, 24, || {
                    slot.lock.lock(mode);
                    ((), 16)
                });
            }
            held[i] = Some(HeldLock {
                slot,
                mode,
                ub: decls[i].suprema.total(),
                count: 0,
                released: false,
            });
        }
        tx.held = held.into_iter().map(Option::unwrap).collect();

        let r = body(&mut tx);
        // Commit = release everything (one message per remote object).
        for hl in &tx.held {
            if !hl.released && hl.mode.is_some() {
                cluster.send(client, hl.slot.oid.node, 24);
            }
        }
        tx.release_all();
        match r {
            Ok(()) => {
                self.commits.fetch_add(1, Ordering::Relaxed);
                Ok(TxStats { ops: tx.ops, attempts: 1 })
            }
            Err(e) => {
                // No rollback support: surface the error as-is.
                self.manual_aborts.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    fn aborts(&self) -> u64 {
        self.manual_aborts.load(Ordering::Relaxed)
    }

    fn commits(&self) -> u64 {
        self.commits.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{AccessDecl, Suprema};
    use crate::cluster::NetworkModel;
    use crate::object::{account::ops, Account};

    /// Run a body over a declaration list through the builder front end.
    fn run(
        sys: &Arc<LockSystem>,
        client: NodeId,
        decls: &[AccessDecl],
        body: impl FnMut(&mut dyn TxCtx) -> Result<(), TxError>,
    ) -> Result<TxStats, TxError> {
        (sys as &dyn Dtm)
            .tx(client)
            .with_decls(decls)
            .run(body)
            .map(|((), stats)| stats)
    }

    fn run_transfer(kind: LockKind, discipline: Discipline) {
        let cluster = Arc::new(Cluster::new(2, NetworkModel::instant()));
        let sys = LockSystem::new(cluster, kind, discipline);
        let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(100)));
        let b = sys.host(NodeId(1), "B", Box::new(Account::with_balance(0)));
        let decls = vec![
            AccessDecl::new("A", Suprema::new(0, 0, 1)),
            AccessDecl::new("B", Suprema::new(0, 0, 1)),
        ];
        let stats = run(&sys, NodeId(0), &decls, |t| {
            t.call(ObjHandle(0), ops::withdraw(30))?;
            t.call(ObjHandle(1), ops::deposit(30))?;
            Ok(())
        })
        .unwrap();
        assert_eq!(stats.ops, 2);
        assert_eq!(sys.with_object(a, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()), 70);
        assert_eq!(sys.with_object(b, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()), 30);
    }

    #[test]
    fn all_lock_variants_run_a_transfer() {
        run_transfer(LockKind::Mutex, Discipline::S2pl);
        run_transfer(LockKind::Mutex, Discipline::Tpl);
        run_transfer(LockKind::ReadWrite, Discipline::S2pl);
        run_transfer(LockKind::ReadWrite, Discipline::Tpl);
        run_transfer(LockKind::Global, Discipline::S2pl);
    }

    #[test]
    fn concurrent_increments_are_serialized() {
        let cluster = Arc::new(Cluster::new(1, NetworkModel::instant()));
        let sys = LockSystem::new(cluster, LockKind::Mutex, Discipline::Tpl);
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
        let mut handles = vec![];
        for _ in 0..8 {
            let sys = Arc::clone(&sys);
            handles.push(std::thread::spawn(move || {
                let decls = vec![AccessDecl::new("A", Suprema::new(1, 0, 1))];
                run(&sys, NodeId(0), &decls, |t| {
                    let v = t.call(ObjHandle(0), ops::balance())?.as_int();
                    t.call(ObjHandle(0), ops::deposit(v + 1 - v))?; // +1
                    Ok(())
                })
                .unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let oid = sys.cluster().registry.locate("A").unwrap();
        assert_eq!(sys.with_object(oid, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()), 8);
        assert_eq!(sys.commits(), 8);
    }

    #[test]
    fn rw_s2pl_allows_parallel_readers() {
        let cluster = Arc::new(Cluster::new(1, NetworkModel::instant()));
        let sys = LockSystem::new(cluster, LockKind::ReadWrite, Discipline::S2pl);
        sys.host(NodeId(0), "A", Box::new(Account::with_balance(42)));
        // Two read-only transactions run concurrently without blocking:
        // verify by holding one open while the other completes.
        let decls = vec![AccessDecl::new("A", Suprema::reads(1))];
        let sys2 = Arc::clone(&sys);
        let d2 = decls.clone();
        let t = std::thread::spawn(move || {
            run(&sys2, NodeId(0), &d2, |t| {
                t.call(ObjHandle(0), ops::balance())?;
                std::thread::sleep(std::time::Duration::from_millis(100));
                Ok(())
            })
            .unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        let t0 = std::time::Instant::now();
        run(&sys, NodeId(0), &decls, |t| {
            t.call(ObjHandle(0), ops::balance())?;
            Ok(())
        })
        .unwrap();
        assert!(t0.elapsed() < std::time::Duration::from_millis(60), "reader blocked by reader");
        t.join().unwrap();
    }
}
