//! Cross-framework consistency: every framework must serialize the same
//! bank workload; the versioning frameworks must additionally survive
//! manual aborts, cascades, and concurrent irrevocable audits with full
//! money conservation; committed histories must replay serially
//! (serializability by replay, via `checker`).

use atomic_rmi2::api::{AccessDecl, ObjHandle, Suprema, TxCtx, TxError};
use atomic_rmi2::checker::{replay_final, OpRecord, Recorder};
use atomic_rmi2::object::{account::ops, Account, SharedObject};
use atomic_rmi2::util::prng::Prng;
use atomic_rmi2::workload::{FrameworkKind, ALL_FRAMEWORKS};
use atomic_rmi2::{Cluster, NetworkModel, NodeId};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const ACCOUNTS: usize = 8;
const INITIAL: i64 = 100;

/// Transfers conserve money under every framework — and the committed
/// history must replay serially to exactly the live final state
/// (serial-replay verification on by default, not just in the dedicated
/// replay test below).
#[test]
fn all_frameworks_conserve_money_under_concurrency() {
    for kind in ALL_FRAMEWORKS {
        let cluster = Arc::new(Cluster::new(2, NetworkModel::instant()));
        let fw = Arc::new(kind.build(cluster));
        for i in 0..ACCOUNTS {
            fw.host(
                NodeId((i % 2) as u16),
                &format!("a{i}"),
                Box::new(Account::with_balance(INITIAL)),
            );
        }
        let recorder = Arc::new(Recorder::new());
        let mut threads = vec![];
        for c in 0..4u64 {
            let fw = Arc::clone(&fw);
            let recorder = Arc::clone(&recorder);
            threads.push(std::thread::spawn(move || {
                let mut rng = Prng::seeded(0xC0 ^ c);
                for n in 0..15 {
                    let from = rng.index(ACCOUNTS);
                    let to = (from + 1 + rng.index(ACCOUNTS - 1)) % ACCOUNTS;
                    let amt = 1 + rng.below(30) as i64;
                    let decls = vec![
                        AccessDecl::new(format!("a{from}"), Suprema::updates(1)),
                        AccessDecl::new(format!("a{to}"), Suprema::updates(1)),
                    ];
                    // The observation record is the body's return value.
                    let (obs, _) = fw
                        .dtm()
                        .tx(NodeId(0))
                        .with_decls(&decls)
                        .run(|t| {
                            let mut obs: Vec<OpRecord> = Vec::new();
                            let w = t.call(ObjHandle(0), ops::withdraw(amt))?;
                            obs.push(OpRecord {
                                object: format!("a{from}"),
                                call: ops::withdraw(amt),
                                result: w,
                            });
                            let d = t.call(ObjHandle(1), ops::deposit(amt))?;
                            obs.push(OpRecord {
                                object: format!("a{to}"),
                                call: ops::deposit(amt),
                                result: d,
                            });
                            Ok(obs)
                        })
                        .unwrap();
                    recorder.commit(format!("c{c}-t{n}"), obs);
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        let total: i64 = (0..ACCOUNTS)
            .map(|i| {
                let oid = fw_registry(&fw, &format!("a{i}"));
                fw.with_object(oid, |o| {
                    o.as_any().downcast_ref::<Account>().unwrap().balance()
                })
            })
            .sum();
        assert_eq!(total, INITIAL * ACCOUNTS as i64, "{}", kind.label());

        // Serial-replay verification: the committed history, replayed in
        // commit order against fresh objects, must land on the live state.
        let mut initial: BTreeMap<String, Box<dyn SharedObject>> = BTreeMap::new();
        for i in 0..ACCOUNTS {
            initial.insert(format!("a{i}"), Box::new(Account::with_balance(INITIAL)));
        }
        let records = recorder.take();
        assert_eq!(records.len(), 4 * 15, "{}: a transfer went unrecorded", kind.label());
        let replayed = replay_final(initial, &records)
            .unwrap_or_else(|e| panic!("{}: {e}", kind.label()));
        for (name, obj) in &replayed {
            let live_oid = fw_registry(&fw, name);
            let live = fw.with_object(live_oid, |o| {
                o.as_any().downcast_ref::<Account>().unwrap().balance()
            });
            let want = obj.as_any().downcast_ref::<Account>().unwrap().balance();
            assert_eq!(
                live, want,
                "{}: {name} diverged from serial replay of the committed history",
                kind.label()
            );
        }
        fw.shutdown();
    }
}

fn fw_registry(fw: &atomic_rmi2::workload::Framework, name: &str) -> atomic_rmi2::Oid {
    match fw {
        atomic_rmi2::workload::Framework::Optsva(s) => s.cluster().registry.locate(name).unwrap(),
        atomic_rmi2::workload::Framework::Sva(s) => s.cluster().registry.locate(name).unwrap(),
        atomic_rmi2::workload::Framework::Tfa(s) => s.cluster().registry.locate(name).unwrap(),
        atomic_rmi2::workload::Framework::Locks(s) => s.cluster().registry.locate(name).unwrap(),
    }
}

/// The hardened cascade stress: manual aborts + cascades + a concurrent
/// irrevocable auditor, for both versioning frameworks. This is the
/// scenario that exposed the restore-epoch bug during development.
#[test]
fn versioning_frameworks_survive_aborts_and_cascades() {
    for kind in [FrameworkKind::Optsva, FrameworkKind::OptsvaNoAsync, FrameworkKind::Sva] {
        for round in 0..5u64 {
            run_cascade_stress(kind, round);
        }
    }
}

fn run_cascade_stress(kind: FrameworkKind, round: u64) {
    let cluster = Arc::new(Cluster::new(2, NetworkModel::instant()));
    let fw = Arc::new(kind.build(Arc::clone(&cluster)));
    for i in 0..ACCOUNTS {
        fw.host(
            NodeId((i % 2) as u16),
            &format!("a{i}"),
            Box::new(Account::with_balance(INITIAL)),
        );
    }
    let stop = Arc::new(AtomicBool::new(false));

    // Irrevocable auditor (only meaningful for OptSVA-CF; SVA runs it as a
    // plain transaction — versioning still guarantees consistency).
    let auditor = {
        let fw = Arc::clone(&fw);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                let decls: Vec<_> = (0..ACCOUNTS)
                    .map(|i| AccessDecl::new(format!("a{i}"), Suprema::reads(1)))
                    .collect();
                // The audited total is the body's return value — re-executed
                // bodies (SVA runs this non-irrevocably and can join a
                // cascade) recompute it from scratch, no out-param reset.
                let r = fw.dtm().tx(NodeId(0)).with_decls(&decls).irrevocable().run(|t| {
                    let mut total = 0i64;
                    for i in 0..ACCOUNTS {
                        total += t.call(ObjHandle(i), ops::balance())?.as_int();
                    }
                    Ok(total)
                });
                let total = match r {
                    Ok((total, _)) => total,
                    Err(e) => panic!("audit failed: {e}"),
                };
                assert_eq!(total, INITIAL * ACCOUNTS as i64, "inconsistent audit");
            }
        })
    };

    let mut threads = vec![];
    for c in 0..4u64 {
        let fw = Arc::clone(&fw);
        threads.push(std::thread::spawn(move || {
            let mut rng = Prng::seeded(round * 1000 + c);
            for _ in 0..15 {
                let from = rng.index(ACCOUNTS);
                let to = (from + 1 + rng.index(ACCOUNTS - 1)) % ACCOUNTS;
                // Large amounts force frequent overdraw → manual aborts.
                let amt = 1 + rng.below(150) as i64;
                let decls = vec![
                    AccessDecl::new(format!("a{from}"), Suprema::new(1, 0, 1)),
                    AccessDecl::new(format!("a{to}"), Suprema::updates(1)),
                ];
                let r = fw.dtm().tx(NodeId(0)).with_decls(&decls).run(|t| {
                    t.call(ObjHandle(0), ops::withdraw(amt))?;
                    t.call(ObjHandle(1), ops::deposit(amt))?;
                    if t.call(ObjHandle(0), ops::balance())?.as_int() < 0 {
                        return t.abort();
                    }
                    Ok(())
                });
                match r {
                    Ok(_) | Err(TxError::ManualAbort) => {}
                    Err(e) => panic!("transfer failed: {e}"),
                }
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    stop.store(true, Ordering::Release);
    auditor.join().unwrap();

    let total: i64 = (0..ACCOUNTS)
        .map(|i| {
            let oid = fw_registry(&fw, &format!("a{i}"));
            fw.with_object(oid, |o| o.as_any().downcast_ref::<Account>().unwrap().balance())
        })
        .sum();
    assert_eq!(
        total,
        INITIAL * ACCOUNTS as i64,
        "{} round {round}: money not conserved",
        kind.label()
    );
    fw.shutdown();
}

/// Effect-durability by replay: replay the committed transfers serially
/// and require the final object states to match the live system exactly.
/// (Transfers commute, so this is robust to the commit-order
/// approximation; it catches lost or duplicated committed effects — the
/// failure mode of the restore-lineage bug found during development.)
#[test]
fn committed_histories_replay_serially() {
    for kind in [
        FrameworkKind::Optsva,
        FrameworkKind::Sva,
        FrameworkKind::Tfa,
        FrameworkKind::Mutex2pl,
    ] {
        let cluster = Arc::new(Cluster::new(2, NetworkModel::instant()));
        let fw = Arc::new(kind.build(cluster));
        for i in 0..4 {
            fw.host(NodeId(i % 2), &format!("a{i}"), Box::new(Account::with_balance(INITIAL)));
        }
        let recorder = Arc::new(Recorder::new());
        let mut threads = vec![];
        for c in 0..3u64 {
            let fw = Arc::clone(&fw);
            let recorder = Arc::clone(&recorder);
            threads.push(std::thread::spawn(move || {
                let mut rng = Prng::seeded(0x5E ^ c);
                for n in 0..10 {
                    let from = rng.index(4);
                    let to = (from + 1 + rng.index(3)) % 4;
                    let amt = 1 + rng.below(20) as i64;
                    let decls = vec![
                        AccessDecl::new(format!("a{from}"), Suprema::new(1, 0, 1)),
                        AccessDecl::new(format!("a{to}"), Suprema::updates(1)),
                    ];
                    // The observation record is the body's return value.
                    let r = fw.dtm().tx(NodeId(0)).with_decls(&decls).run(|t| {
                        let mut obs: Vec<OpRecord> = Vec::new();
                        let w = t.call(ObjHandle(0), ops::withdraw(amt))?;
                        obs.push(OpRecord {
                            object: format!("a{from}"),
                            call: ops::withdraw(amt),
                            result: w,
                        });
                        let d = t.call(ObjHandle(1), ops::deposit(amt))?;
                        obs.push(OpRecord {
                            object: format!("a{to}"),
                            call: ops::deposit(amt),
                            result: d,
                        });
                        Ok(obs)
                    });
                    if let Ok((obs, _)) = r {
                        recorder.commit(format!("c{c}-t{n}"), obs);
                    }
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        let mut initial: BTreeMap<String, Box<dyn SharedObject>> = BTreeMap::new();
        for i in 0..4 {
            initial.insert(format!("a{i}"), Box::new(Account::with_balance(INITIAL)));
        }
        let records = recorder.take();
        assert!(!records.is_empty(), "{}: nothing committed", kind.label());
        let replayed = replay_final(initial, &records)
            .unwrap_or_else(|e| panic!("{}: {e}", kind.label()));
        for (name, obj) in &replayed {
            let live_oid = fw_registry(&fw, name);
            let live = fw.with_object(live_oid, |o| {
                o.as_any().downcast_ref::<Account>().unwrap().balance()
            });
            let want = obj.as_any().downcast_ref::<Account>().unwrap().balance();
            assert_eq!(
                live, want,
                "{}: {name} diverged from serial replay (lost/duplicated committed effect)",
                kind.label()
            );
        }
        fw.shutdown();
    }
}
