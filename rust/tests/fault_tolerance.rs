//! Fault-tolerance integration tests (§3.4): crash-stop object failures
//! and crashed-client recovery via the failure detector, under load.

use atomic_rmi2::api::{Suprema, TxCtx, TxError};
use atomic_rmi2::faults::Detector;
use atomic_rmi2::object::{account::ops, Account};
use atomic_rmi2::optsva::{AtomicRmi2, OptsvaConfig};
use atomic_rmi2::{Cluster, NetworkModel, NodeId};
use std::sync::Arc;
use std::time::Duration;

fn sys() -> Arc<AtomicRmi2> {
    let cluster = Arc::new(Cluster::new(2, NetworkModel::instant()));
    AtomicRmi2::with_config(
        cluster,
        OptsvaConfig { wait_timeout: Some(Duration::from_secs(10)), asynchrony: true },
    )
}

/// A crashed object surfaces as an exception in every later transaction
/// (crash-stop model), and the name is unbound.
#[test]
fn object_crash_stop_is_visible_and_permanent() {
    let sys = sys();
    let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(5)));
    sys.host(NodeId(1), "B", Box::new(Account::with_balance(5)));
    sys.crash_object(a);

    // Begin on the crashed object fails (the registry entry is gone).
    let mut tx = sys.tx(NodeId(0));
    tx.updates("A", 1);
    assert!(matches!(tx.begin(), Err(TxError::NotDeclared(_))));

    // Other objects keep working.
    let mut tx = sys.tx(NodeId(0));
    let hb = tx.updates("B", 1);
    tx.run(|t| {
        t.call(hb, ops::deposit(1))?;
        Ok(())
    })
    .unwrap();
    sys.shutdown();
}

/// A client that crashes mid-transaction (no Drop, no abort) is detected;
/// its objects roll themselves back; waiting transactions proceed; and the
/// overall state stays consistent under continued load.
#[test]
fn crashed_client_recovery_under_load() {
    let sys = sys();
    for i in 0..4 {
        sys.host(NodeId(i % 2), &format!("a{i}"), Box::new(Account::with_balance(100)));
    }
    let det = Detector::start(
        Arc::clone(&sys),
        Duration::from_millis(60),
        Duration::from_millis(15),
    );

    // Crash two clients mid-flight, holding different objects.
    for victim in 0..2 {
        let mut dead = sys.tx(NodeId(0));
        let h = dead.updates(&format!("a{victim}"), 2);
        dead.begin().unwrap();
        dead.call(h, ops::withdraw(37)).unwrap();
        std::mem::forget(dead);
    }

    // Live clients keep transferring across all four accounts.
    let mut threads = vec![];
    for c in 0..3u64 {
        let sys = Arc::clone(&sys);
        threads.push(std::thread::spawn(move || {
            let mut rng = atomic_rmi2::util::prng::Prng::seeded(c);
            for _ in 0..10 {
                let from = rng.index(4);
                let to = (from + 1 + rng.index(3)) % 4;
                let amt = 1 + rng.below(20) as i64;
                loop {
                    let mut tx = sys.tx(NodeId(0));
                    let hf = tx.updates(&format!("a{from}"), 1);
                    let ht = tx.updates(&format!("a{to}"), 1);
                    let r = tx.run(|t| {
                        t.call(hf, ops::withdraw(amt))?;
                        t.call(ht, ops::deposit(amt))?;
                        Ok(())
                    });
                    match r {
                        Ok(_) => break,
                        // Cascades from the victims' rollbacks: retry.
                        Err(TxError::ForcedAbort(_)) => continue,
                        Err(e) => panic!("{e}"),
                    }
                }
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    assert!(det.evictions() >= 2, "both victims detected");
    det.stop();

    // The victims' withdrawals were rolled back; transfers conserved.
    let total: i64 = (0..4)
        .map(|i| {
            let oid = sys.cluster().registry.locate(&format!("a{i}")).unwrap();
            sys.with_object(oid, |o| o.as_any().downcast_ref::<Account>().unwrap().balance())
        })
        .sum();
    assert_eq!(total, 400, "crashed clients' effects must be rolled back");
    sys.shutdown();
}

/// An undetected crash (no detector) is still bounded by the versioning
/// wait timeout: the blocked transaction reports `Timeout` rather than
/// hanging forever.
#[test]
fn waits_are_bounded_by_failure_suspicion_timeout() {
    let cluster = Arc::new(Cluster::new(1, NetworkModel::instant()));
    let sys = AtomicRmi2::with_config(
        cluster,
        OptsvaConfig { wait_timeout: Some(Duration::from_millis(120)), asynchrony: true },
    );
    sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));

    let mut dead = sys.tx(NodeId(0));
    let h = dead.updates("A", 2);
    dead.begin().unwrap();
    dead.call(h, ops::deposit(1)).unwrap();
    std::mem::forget(dead);

    let mut tx = sys.tx(NodeId(0));
    let h2 = tx.updates("A", 1);
    tx.begin().unwrap();
    let r = tx.call(h2, ops::deposit(1));
    assert!(matches!(r, Err(TxError::Timeout(_))), "got {r:?}");
    sys.shutdown();
}
