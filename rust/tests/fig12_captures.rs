//! Fig 12 regression: `state_size`-aware copy-buffer capture accounting.
//!
//! Two capture-skip paths must hold, and keep holding, because they are
//! what the hot-account speedups in `BENCH_micro.json` rest on:
//!
//!   * a blind single `WRITE` commits without a checkpoint — the log
//!     buffer either applies atomically or not at all, so no restore
//!     point is needed;
//!   * commuting updates admitted through a group grant never capture —
//!     aborts are undone by the declared inverse, not by restoring a
//!     snapshot.
//!
//! Both show up in `SysStats::captures`/`capture_bytes`, which every
//! capture site routes through (`Proxy::capture`).

use atomic_rmi2::api::{Suprema, TxCtx};
use atomic_rmi2::object::{account::ops, Account, SharedObject};
use atomic_rmi2::optsva::{AtomicRmi2, OptsvaConfig};
use atomic_rmi2::{Cluster, NetworkModel, NodeId};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn sys() -> Arc<AtomicRmi2> {
    let cluster = Arc::new(Cluster::new(1, NetworkModel::instant()));
    AtomicRmi2::with_config(
        cluster,
        OptsvaConfig { wait_timeout: Some(Duration::from_secs(10)), asynchrony: true },
    )
}

fn captures(sys: &AtomicRmi2) -> u64 {
    sys.stats.captures.load(Ordering::Relaxed)
}

fn balance(sys: &AtomicRmi2, oid: atomic_rmi2::Oid) -> i64 {
    sys.with_object(oid, |o| o.as_any().downcast_ref::<Account>().unwrap().balance())
}

#[test]
fn single_blind_write_commits_without_capture() {
    // The write supremum is declared one higher than used, so the log is
    // applied at commit time (`finalize_commit`), not by the §2.8.4 async
    // task — the async task keeps its checkpoint because the transaction
    // can still abort afterwards; the commit-time apply cannot.
    let sys = sys();
    let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(77)));

    let mut tx = sys.tx(NodeId(0));
    let h = tx.writes("A", 2);
    tx.begin().unwrap();
    tx.call(h, ops::reset()).unwrap();
    tx.commit().unwrap();

    assert_eq!(balance(&sys, a), 0, "the buffered write must still apply");
    assert_eq!(captures(&sys), 0, "a single-entry log applies atomically: no checkpoint");
    assert_eq!(sys.stats.capture_bytes.load(Ordering::Relaxed), 0);
    sys.shutdown();
}

#[test]
fn multi_entry_log_keeps_its_safety_checkpoint() {
    // With more than one buffered entry, a mid-apply failure could leave
    // the object partially written — the checkpoint stays.
    let sys = sys();
    let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(77)));

    let mut tx = sys.tx(NodeId(0));
    let h = tx.writes("A", 3);
    tx.begin().unwrap();
    tx.call(h, ops::reset()).unwrap();
    tx.call(h, ops::reset()).unwrap();
    tx.commit().unwrap();

    assert_eq!(balance(&sys, a), 0);
    assert_eq!(captures(&sys), 1);
    assert_eq!(
        sys.stats.capture_bytes.load(Ordering::Relaxed),
        8,
        "Account::state_size() bytes accounted per capture"
    );
    sys.shutdown();
}

#[test]
fn commuting_deposits_capture_nothing_exclusive_chain_captures_per_tx() {
    let sys = sys();
    let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));

    // Group path: update-only commuting deposits never checkpoint.
    for _ in 0..4 {
        let mut tx = sys.tx(NodeId(0));
        let h = tx.updates("A", 1);
        tx.begin().unwrap();
        tx.call(h, ops::deposit(10)).unwrap();
        tx.commit().unwrap();
    }
    assert_eq!(captures(&sys), 0, "group grants skip the copy buffer entirely");

    // Exclusive chain: the same deposits behind a read declaration pay
    // two snapshots per transaction (the abort checkpoint `st` at first
    // access, plus the read buffer `buf` at early release) — the Fig 12
    // baseline cost the group path avoids.
    for _ in 0..4 {
        let mut tx = sys.tx(NodeId(0));
        let h = tx.accesses("A", Suprema::new(1, 0, 1));
        tx.begin().unwrap();
        tx.call(h, ops::deposit(10)).unwrap();
        tx.call(h, ops::balance()).unwrap();
        tx.commit().unwrap();
    }
    assert_eq!(captures(&sys), 8, "exclusive updates snapshot twice per transaction");
    assert_eq!(balance(&sys, a), 80);
    sys.shutdown();
}
