//! Trace subsystem acceptance:
//!
//!  * same seed ⇒ byte-identical exported Perfetto JSON (the CI
//!    regression runs the same comparison on the built binary);
//!  * tracing must not perturb what the protocol does — a traced replay
//!    and an untraced replay of the same [`ScheduleId`] agree on
//!    fingerprint, history, and commit/abort counts;
//!  * the headline observability claim: on `async_buffering` the last
//!    early release lands strictly inside the transaction interval, so
//!    `release_shrinkage < 1`.

use atomic_rmi2::analysis::{run_schedule, scenarios, ScheduleId};
use atomic_rmi2::bench::Json;
use atomic_rmi2::optsva::ProtocolMutation;
use atomic_rmi2::trace::{aggregate, perfetto, TraceEvent, TraceSession};
use std::sync::{Mutex, MutexGuard};

/// The trace recorder is process-global: a session opened by one test
/// would capture another test's (intentionally untraced) runs. Serialize
/// every test in this binary through one lock.
static EXCLUSIVE: Mutex<()> = Mutex::new(());

fn exclusive() -> MutexGuard<'static, ()> {
    EXCLUSIVE.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn traced_export(name: &str, seed: u64) -> (String, Vec<TraceEvent>) {
    let scenario = scenarios::by_name(name).unwrap();
    let session = TraceSession::start();
    let out = run_schedule(&scenario, &ScheduleId::seed(seed), ProtocolMutation::None);
    let events = session.finish();
    assert!(out.violation.is_none(), "{name}: clean protocol must replay clean");
    (perfetto::export(&events).render(), events)
}

#[test]
fn same_seed_exports_byte_identical_perfetto_json() {
    let _g = exclusive();
    let (a, events) = traced_export("cascade", 3);
    let (b, _) = traced_export("cascade", 3);
    assert!(!events.is_empty(), "a traced cascade replay must record events");
    assert_eq!(a, b, "same seed must export byte-identical JSON");
    // The export is valid JSON — the same self-check the CLI applies
    // before writing the file.
    Json::parse(&a).expect("exported trace must parse");
}

#[test]
fn tracing_does_not_perturb_schedule_outcomes() {
    let _g = exclusive();
    for name in ["transfers", "cascade", "async_buffering"] {
        let scenario = scenarios::by_name(name).unwrap();
        let id = ScheduleId::seed(11);
        let plain = run_schedule(&scenario, &id, ProtocolMutation::None);
        let session = TraceSession::start();
        let traced = run_schedule(&scenario, &id, ProtocolMutation::None);
        let events = session.finish();
        assert!(!events.is_empty(), "{name}");
        assert_eq!(traced.fingerprint, plain.fingerprint, "{name}");
        assert_eq!(traced.history, plain.history, "{name}");
        assert_eq!(traced.committed, plain.committed, "{name}");
        assert_eq!(traced.aborted, plain.aborted, "{name}");
    }
}

#[test]
fn async_buffering_trace_shows_early_release_shrinkage() {
    let _g = exclusive();
    let (_, events) = traced_export("async_buffering", 0);
    let s = aggregate::summarize(&events);
    assert!(s.commits > 0);
    assert!(s.early_releases > 0, "async_buffering must early-release");
    assert!(
        s.release_shrinkage < 1.0,
        "early release must shrink the effective hold interval, got {}",
        s.release_shrinkage
    );
}
