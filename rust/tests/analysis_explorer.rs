//! Explorer acceptance: deterministic replay, a clean bill for the real
//! protocol, and — the harness validating itself — seeded protocol
//! mutations caught within a modest seed budget, with replayable
//! violation schedules. Budgets here are scaled down from the CLI
//! defaults to stay well inside the CI test timeout; the `check` CI job
//! runs the full budget.

use atomic_rmi2::analysis::{explore, run_schedule, scenarios, ExploreConfig, LintKind, ScheduleId};
use atomic_rmi2::optsva::ProtocolMutation;

fn small(mutation: ProtocolMutation) -> ExploreConfig {
    ExploreConfig {
        seeds: 48,
        flip_depth: 4,
        flip_bases: 2,
        min_distinct: 40,
        mutation,
        ..ExploreConfig::default()
    }
}

/// Satellite regression test: the same explorer seed must reproduce the
/// same schedule — byte-identical history renders, equal fingerprints —
/// for both plain seeds and delivery-order flips.
#[test]
fn same_schedule_id_is_byte_identical() {
    for name in ["transfers", "cascade", "async_buffering"] {
        let s = scenarios::by_name(name).unwrap();
        for id in [
            ScheduleId::seed(0),
            ScheduleId::seed(41),
            ScheduleId { base_seed: 7, flip: Some((1, 0)) },
        ] {
            let a = run_schedule(&s, &id, ProtocolMutation::None);
            let b = run_schedule(&s, &id, ProtocolMutation::None);
            assert_eq!(a.history, b.history, "{name}/{id}: history diverged between runs");
            assert_eq!(a.fingerprint, b.fingerprint, "{name}/{id}");
            assert_eq!(a.trace, b.trace, "{name}/{id}");
        }
    }
}

/// Transport-refactor regression: replaying a schedule after *other*
/// scenarios have run (each constructing its own sharded inboxes and
/// draining batches) must not perturb the history — the explorer's
/// determinism depends on per-cluster transport state only, never on
/// process-global sequencing.
#[test]
fn fingerprints_are_stable_across_interleaved_scenarios() {
    let id = ScheduleId::seed(11);
    let mut first = Vec::new();
    for name in ["transfers", "cascade", "async_buffering"] {
        let s = scenarios::by_name(name).unwrap();
        first.push(run_schedule(&s, &id, ProtocolMutation::None));
    }
    // Re-run in reverse order, with the other scenarios' runs in between.
    for (i, name) in ["transfers", "cascade", "async_buffering"].iter().enumerate().rev() {
        let s = scenarios::by_name(name).unwrap();
        let again = run_schedule(&s, &id, ProtocolMutation::None);
        assert_eq!(again.history, first[i].history, "{name}: history changed on re-run");
        assert_eq!(again.fingerprint, first[i].fingerprint, "{name}");
    }
}

/// Different seeds must actually explore: the schedule space of every
/// scenario is large, so a modest seed budget yields many distinct runs.
#[test]
fn distinct_seeds_explore_distinct_schedules() {
    let s = scenarios::by_name("transfers").unwrap();
    let report = explore(&s, &small(ProtocolMutation::None));
    assert!(
        report.distinct_schedules >= 40,
        "only {} distinct schedules in {} runs",
        report.distinct_schedules,
        report.runs
    );
}

/// The real protocol is clean: no opacity violation, no deadlock, in any
/// explored schedule of any built-in scenario.
#[test]
fn real_protocol_has_no_violations() {
    for s in scenarios::builtin() {
        let report = explore(&s, &small(ProtocolMutation::None));
        assert!(
            report.violations.is_empty(),
            "{}: {} violating schedule(s), first: {} — {}",
            s.name,
            report.violations_total,
            report.violations[0].schedule,
            report.violations[0].detail
        );
        assert!(report.committed > 0, "{}: nothing ever committed", s.name);
        assert!(report.ops_verified > 0, "{}: checker verified nothing", s.name);
    }
}

/// Mutation validation #1: releasing an object one update early must be
/// caught (stale copy-buffer reads diverge from any committed-order
/// replay), and the reported schedule must replay to the same violation.
#[test]
fn premature_release_mutation_is_caught_and_replayable() {
    let s = scenarios::by_name("async_buffering").unwrap();
    let mutation = ProtocolMutation::PrematureRelease;
    let cfg = ExploreConfig { seeds: 16, min_distinct: 8, ..small(mutation) };
    let report = explore(&s, &cfg);
    assert!(report.violations_total > 0, "premature-release escaped {} schedules", report.runs);

    let v = &report.violations[0];
    let id = ScheduleId::parse(&v.schedule).expect("violation schedule parses");
    let replay = run_schedule(&s, &id, mutation);
    assert_eq!(
        replay.violation.as_deref(),
        Some(v.detail.as_str()),
        "replay of {} did not reproduce the violation",
        v.schedule
    );
}

/// Mutation validation #2: skipping invalidation on rollback lets a
/// reader consume (and commit) a dirty early-released write — caught
/// only under the right interleavings, which is exactly what the
/// exploration is for.
#[test]
fn skip_invalidation_mutation_is_caught_and_replayable() {
    let s = scenarios::by_name("cascade").unwrap();
    let mutation = ProtocolMutation::SkipInvalidation;
    let report = explore(&s, &ExploreConfig { seeds: 96, min_distinct: 60, ..small(mutation) });
    assert!(report.violations_total > 0, "skip-invalidation escaped {} schedules", report.runs);

    let v = &report.violations[0];
    let id = ScheduleId::parse(&v.schedule).expect("violation schedule parses");
    let replay = run_schedule(&s, &id, mutation);
    assert_eq!(replay.violation.as_deref(), Some(v.detail.as_str()));
}

/// The declaration lint flags all four defect classes on the showcase
/// scenario — and correctly blames the specific (tx, object) pairs.
#[test]
fn lint_demo_produces_all_diagnostic_kinds() {
    let s = scenarios::by_name("lint_demo").unwrap();
    let report = explore(&s, &ExploreConfig { seeds: 24, min_distinct: 10, ..small(ProtocolMutation::None) });
    let has = |kind: LintKind, tag: &str, object: &str| {
        report.lint.iter().any(|d| d.kind == kind && d.tag == tag && d.object == object)
    };
    assert!(has(LintKind::OverDeclared, "t0", "a"), "{:?}", report.lint);
    assert!(has(LintKind::UnusedDeclaration, "t1", "b"), "{:?}", report.lint);
    assert!(has(LintKind::UnboundedSupremum, "t1", "b"), "{:?}", report.lint);
    assert!(has(LintKind::UnderDeclared, "t2", "a"), "{:?}", report.lint);
    // The mis-declarations are warnings, not violations: the runtime
    // contains them (SupremaExceeded → abort), so opacity still holds.
    assert!(report.violations.is_empty(), "{:?}", report.violations);
}
