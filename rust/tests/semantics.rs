//! Remaining API-semantics coverage: `retry()`, log-buffer apply failures
//! surfacing at commit, complex objects (KvStore, Queue, ComputeObject)
//! under transactions, and network accounting — exercised through the
//! builder/futures API where a framework-agnostic path exists.

use atomic_rmi2::api::{AccessDecl, Dtm, ObjHandle, Suprema, TxCtx, TxError, TxStats};
use atomic_rmi2::object::{
    refs::{KvRef, QueueRef},
    ComputeObject, KvStore, OpCall, QueueObject, SpinBackend, Value,
};
use atomic_rmi2::optsva::AtomicRmi2;
use atomic_rmi2::{Cluster, NetworkModel, NodeId};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

fn sys() -> (Arc<Cluster>, Arc<AtomicRmi2>) {
    let cluster = Arc::new(Cluster::new(2, NetworkModel::instant()));
    let sys = AtomicRmi2::new(Arc::clone(&cluster));
    (cluster, sys)
}

/// Builder-API front end over the OptSVA system.
fn run<R>(
    sys: &Arc<AtomicRmi2>,
    decls: &[AccessDecl],
    body: impl FnMut(&mut dyn TxCtx) -> Result<R, TxError>,
) -> Result<(R, TxStats), TxError> {
    (sys as &dyn Dtm).tx(NodeId(0)).with_decls(decls).run(body)
}

/// `retry()` aborts the attempt (rolling back its effects) and re-executes
/// the body from scratch (paper Fig 8).
#[test]
fn retry_reexecutes_the_body_with_clean_state() {
    let (_c, sys) = sys();
    sys.host(NodeId(0), "kv", Box::new(KvStore::from_pairs(&[("n", 0)])));
    let attempts = Arc::new(AtomicU64::new(0));
    let decls = vec![AccessDecl::new("kv", Suprema::unknown())];
    let a = Arc::clone(&attempts);
    let kv = KvRef::new(ObjHandle(0));
    let ((), stats) = run(&sys, &decls, |t| {
        let n = a.fetch_add(1, Ordering::SeqCst);
        kv.put(t, "n", n as i64 + 10)?;
        if n < 2 {
            return t.retry();
        }
        Ok(())
    })
    .unwrap();
    assert_eq!(stats.attempts, 3);
    let oid = sys.cluster().registry.locate("kv").unwrap();
    // Only the final attempt's put survives (earlier ones rolled back).
    let v = sys.with_object(oid, |o| {
        o.as_any()
            .downcast_ref::<KvStore>()
            .unwrap()
            .peek("n")
            .unwrap()
    });
    assert_eq!(v, 12);
    sys.shutdown();
}

/// A pure write recorded in the log buffer that *fails on replay* (bad
/// arguments) surfaces at commit and aborts the transaction cleanly.
#[test]
fn log_buffer_replay_failure_aborts_at_commit() {
    let (_c, sys) = sys();
    sys.host(NodeId(0), "q", Box::new(QueueObject::new()));
    let mut tx = sys.tx(NodeId(0));
    // Declare more writes than we perform so the log is applied at commit
    // (the last-write async path never fires).
    let h = tx.writes("q", 5);
    tx.begin().unwrap();
    // "push" with no argument: records fine (no synchronization), fails
    // on replay.
    tx.call(h, OpCall::nullary("push")).unwrap();
    let r = tx.commit();
    assert!(matches!(r, Err(TxError::Object(_))), "got {r:?}");
    let oid = sys.cluster().registry.locate("q").unwrap();
    assert!(sys.with_object(oid, |o| o
        .as_any()
        .downcast_ref::<QueueObject>()
        .unwrap()
        .is_empty()));
    sys.shutdown();
}

/// Transactional FIFO handoff through a QueueObject: concurrent producers
/// and one consumer; nothing lost, nothing duplicated.
#[test]
fn queue_handoff_is_exactly_once() {
    let (_c, sys) = sys();
    sys.host(NodeId(0), "q", Box::new(QueueObject::new()));
    let mut producers = vec![];
    for p in 0..4i64 {
        let sys = Arc::clone(&sys);
        producers.push(std::thread::spawn(move || {
            let q = QueueRef::new(ObjHandle(0));
            for i in 0..10i64 {
                let decls = vec![AccessDecl::new("q", Suprema::writes(1))];
                run(&sys, &decls, |t| q.push(t, p * 100 + i)).unwrap();
            }
        }));
    }
    for p in producers {
        p.join().unwrap();
    }
    // Drain transactionally; the body *returns* the popped element instead
    // of smuggling it through a captured out-variable.
    let q = QueueRef::new(ObjHandle(0));
    let mut seen = Vec::new();
    loop {
        let decls = vec![AccessDecl::new("q", Suprema::unknown())];
        let (got, _) = run(&sys, &decls, |t| {
            if q.len(t)? > 0 {
                q.pop(t)
            } else {
                Ok(None)
            }
        })
        .unwrap();
        match got {
            Some(v) => seen.push(v),
            None => break,
        }
    }
    assert_eq!(seen.len(), 40);
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len(), 40, "duplicated or lost queue elements");
    sys.shutdown();
}

/// ComputeObject transactions: the mix/digest operations behave
/// transactionally — an aborted mix leaves the state untouched.
#[test]
fn compute_object_mix_is_transactional() {
    let (_c, sys) = sys();
    let backend = Arc::new(SpinBackend::new(8, 2));
    sys.host(NodeId(0), "c", Box::new(ComputeObject::new(backend)));
    let oid = sys.cluster().registry.locate("c").unwrap();
    let before = sys.with_object(oid, |o| {
        o.as_any().downcast_ref::<ComputeObject>().unwrap().state().to_vec()
    });

    // Aborted mix: no effect.
    let mut tx = sys.tx(NodeId(0));
    let h = tx.updates("c", 2);
    tx.begin().unwrap();
    tx.call(h, OpCall::new("mix", vec![Value::Floats(vec![0.5; 8])])).unwrap();
    tx.abort().unwrap();
    let after_abort = sys.with_object(oid, |o| {
        o.as_any().downcast_ref::<ComputeObject>().unwrap().state().to_vec()
    });
    assert_eq!(before, after_abort, "aborted mix must be rolled back");

    // Committed mix: the digest (returned from the body) changes.
    let decls = vec![AccessDecl::new("c", Suprema::new(1, 0, 1))];
    let (digest, _) = run(&sys, &decls, |t| {
        t.call(ObjHandle(0), OpCall::new("mix", vec![Value::Floats(vec![0.5; 8])]))?;
        Ok(t.call(ObjHandle(0), OpCall::nullary("digest"))?.try_float()?)
    })
    .unwrap();
    assert!(digest.is_finite() && digest > 0.0);
    sys.shutdown();
}

/// The network model charges every remote interaction and none of the
/// co-located ones.
#[test]
fn network_accounting_matches_interaction_pattern() {
    let cluster = Arc::new(Cluster::new(2, NetworkModel::instant()));
    let sys = AtomicRmi2::new(Arc::clone(&cluster));
    sys.host(NodeId(0), "local", Box::new(KvStore::from_pairs(&[("k", 1)])));
    sys.host(NodeId(1), "remote", Box::new(KvStore::from_pairs(&[("k", 2)])));

    // Local-only transaction: zero messages.
    let decls = vec![AccessDecl::new("local", Suprema::reads(1))];
    run(&sys, &decls, |t| {
        t.call(ObjHandle(0), OpCall::unary("get", "k"))?;
        Ok(())
    })
    .unwrap();
    let (msgs, _, local) = cluster.stats.snapshot();
    assert_eq!(msgs, 0, "co-located transaction must not touch the network");
    assert!(local >= 3, "start + op + commit accounted as local calls");

    // Remote transaction: start + op + commit ⇒ ≥ 3 round trips.
    let decls = vec![AccessDecl::new("remote", Suprema::reads(1))];
    run(&sys, &decls, |t| {
        t.call(ObjHandle(0), OpCall::unary("get", "k"))?;
        Ok(())
    })
    .unwrap();
    let (msgs, bytes, _) = cluster.stats.snapshot();
    assert!(msgs >= 6, "expected ≥3 round trips (6 messages), got {msgs}");
    assert!(bytes > 0);
    sys.shutdown();
}

/// Suprema of zero in one mode are enforced independently per mode.
#[test]
fn per_mode_suprema_are_independent() {
    let (_c, sys) = sys();
    sys.host(NodeId(0), "kv", Box::new(KvStore::from_pairs(&[("k", 7)])));
    let mut tx = sys.tx(NodeId(0));
    let h = tx.accesses("kv", Suprema::new(2, 0, 0)); // reads only
    tx.begin().unwrap();
    assert_eq!(tx.call(h, OpCall::unary("get", "k")).unwrap().as_int(), 7);
    // A write against a read-only declaration must be rejected.
    let err = tx
        .call(h, OpCall::new("put", vec![Value::from("k"), Value::from(9i64)]))
        .unwrap_err();
    assert!(matches!(err, TxError::SupremaExceeded { mode: "write", .. }), "got {err:?}");
    let _ = tx.abort();
    sys.shutdown();
}
