//! Property-based tests (hand-rolled harness over the deterministic
//! splittable PRNG — `proptest` is not in the offline mirror).
//!
//! Random transaction programs over random object graphs, checked against
//! the §2.1 versioning properties and the system-level invariants the
//! paper claims: zero forced aborts absent manual aborts, deadlock
//! freedom (bounded-time completion), conservation, and OptSVA-CF/SVA
//! final-state agreement on identical serializable programs.

use atomic_rmi2::api::{AccessDecl, Dtm, ObjHandle, Suprema, TxCtx, TxError};
use atomic_rmi2::object::{OpCall, RegisterObject};
use atomic_rmi2::util::prng::Prng;
use atomic_rmi2::versioning::ObjectCc;
use atomic_rmi2::workload::FrameworkKind;
use atomic_rmi2::{Cluster, NetworkModel, NodeId};
use std::sync::Arc;

/// One randomly generated transaction program.
#[derive(Debug, Clone)]
struct Program {
    /// (object index, op) — op ∈ {get, set k, add k}.
    ops: Vec<(usize, OpCall)>,
}

fn gen_program(rng: &mut Prng, n_objects: usize, max_ops: usize) -> Program {
    let n_ops = 1 + rng.index(max_ops);
    let mut ops = Vec::with_capacity(n_ops);
    for _ in 0..n_ops {
        let obj = rng.index(n_objects);
        let op = match rng.index(3) {
            0 => OpCall::nullary("get"),
            1 => OpCall::unary("set", rng.below(100) as i64),
            _ => OpCall::unary("add", rng.below(10) as i64),
        };
        ops.push((obj, op));
    }
    Program { ops }
}

/// Exact per-mode suprema for a program (perfect a-priori knowledge).
fn decls_for(prog: &Program, n_objects: usize) -> Vec<AccessDecl> {
    let mut sup = vec![Suprema::new(0, 0, 0); n_objects];
    for (o, call) in &prog.ops {
        match call.method {
            "get" => sup[*o].reads += 1,
            "set" => sup[*o].writes += 1,
            _ => sup[*o].updates += 1,
        }
    }
    (0..n_objects)
        .map(|i| AccessDecl::new(format!("r{i}"), sup[i]))
        .collect()
}

/// §2.1 properties (a)–(d) under concurrent starts.
#[test]
fn prop_private_version_assignment() {
    for case in 0..30u64 {
        let mut rng = Prng::seeded(0x9906 ^ case);
        let n_objects = 2 + rng.index(4);
        let ccs: Vec<Arc<ObjectCc>> = (0..n_objects).map(|_| Arc::new(ObjectCc::new())).collect();
        let n_threads = 2 + rng.index(6);
        let mut handles = vec![];
        for _ in 0..n_threads {
            let ccs: Vec<_> = ccs.iter().map(Arc::clone).collect();
            handles.push(std::thread::spawn(move || {
                let view: Vec<_> = ccs
                    .iter()
                    .enumerate()
                    .map(|(i, cc)| {
                        (atomic_rmi2::Oid::new(NodeId(0), i as u32), cc.as_ref())
                    })
                    .collect();
                atomic_rmi2::versioning::acquire_start_locks(&view, |_| {})
            }));
        }
        let results: Vec<Vec<u64>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // (a) uniqueness per object.
        for obj in 0..n_objects {
            let mut pvs: Vec<u64> = results.iter().map(|r| r[obj]).collect();
            pvs.sort_unstable();
            pvs.dedup();
            assert_eq!(pvs.len(), results.len(), "duplicate pv on object {obj}");
            // (d) consecutive from 1 (everyone declared every object).
            assert_eq!(pvs, (1..=results.len() as u64).collect::<Vec<_>>());
        }
        // (c) cross-object order agreement.
        let mut sorted = results.clone();
        sorted.sort_by_key(|r| r[0]);
        for w in sorted.windows(2) {
            for obj in 0..n_objects {
                assert!(
                    w[0][obj] < w[1][obj],
                    "inconsistent pv order across objects: {sorted:?}"
                );
            }
        }
    }
}

/// Without manual aborts, pessimistic frameworks never force an abort and
/// every transaction completes (deadlock freedom) — over random programs.
#[test]
fn prop_no_forced_aborts_and_bounded_completion() {
    for case in 0..12u64 {
        for kind in [FrameworkKind::Optsva, FrameworkKind::Sva] {
            let mut seed_rng = Prng::seeded(case * 7 + 1);
            let n_objects = 2 + seed_rng.index(4);
            let cluster = Arc::new(Cluster::new(2, NetworkModel::instant()));
            let fw = Arc::new(kind.build(cluster));
            for i in 0..n_objects {
                fw.host(
                    NodeId((i % 2) as u16),
                    &format!("r{i}"),
                    Box::new(RegisterObject::new(0)),
                );
            }
            let mut threads = vec![];
            for t in 0..4u64 {
                let fw = Arc::clone(&fw);
                threads.push(std::thread::spawn(move || {
                    let mut rng = Prng::seeded(case * 1000 + t);
                    for _ in 0..8 {
                        let prog = gen_program(&mut rng, n_objects, 6);
                        let decls = decls_for(&prog, n_objects);
                        let ((), stats) = fw
                            .dtm()
                            .tx(NodeId(0))
                            .with_decls(&decls)
                            .run(|ctx| {
                                for (o, call) in &prog.ops {
                                    ctx.call(ObjHandle(*o), call.clone())?;
                                }
                                Ok(())
                            })
                            .expect("transaction must complete");
                        assert_eq!(stats.attempts, 1, "pessimistic: no retries");
                    }
                }));
            }
            for t in threads {
                t.join().unwrap(); // bounded completion: join() returns
            }
            assert_eq!(fw.dtm().aborts(), 0, "{}: forced abort without manual abort", kind.label());
            fw.shutdown();
        }
    }
}

/// OptSVA-CF and SVA agree with a serial oracle on single-threaded
/// programs (the optimizations must be semantically invisible).
#[test]
fn prop_single_thread_matches_serial_oracle() {
    for case in 0..40u64 {
        let mut rng = Prng::seeded(0xACE ^ case);
        let n_objects = 1 + rng.index(5);
        let progs: Vec<Program> = (0..5).map(|_| gen_program(&mut rng, n_objects, 8)).collect();

        // Serial oracle: plain registers.
        let mut oracle = vec![0i64; n_objects];
        let mut oracle_results: Vec<Vec<i64>> = Vec::new();
        for prog in &progs {
            let mut res = Vec::new();
            for (o, call) in &prog.ops {
                match call.method {
                    "get" => res.push(oracle[*o]),
                    "set" => {
                        oracle[*o] = call.args[0].as_int();
                        res.push(0);
                    }
                    _ => {
                        oracle[*o] += call.args[0].as_int();
                        res.push(oracle[*o]);
                    }
                }
            }
            oracle_results.push(res);
        }

        for kind in [FrameworkKind::Optsva, FrameworkKind::OptsvaNoAsync, FrameworkKind::Sva] {
            let cluster = Arc::new(Cluster::new(1, NetworkModel::instant()));
            let fw = kind.build(cluster);
            for i in 0..n_objects {
                fw.host(NodeId(0), &format!("r{i}"), Box::new(RegisterObject::new(0)));
            }
            for (p, prog) in progs.iter().enumerate() {
                let decls = decls_for(prog, n_objects);
                // The body *returns* the observed values — no out-params.
                let (got, _) = fw
                    .dtm()
                    .tx(NodeId(0))
                    .with_decls(&decls)
                    .run(|ctx| {
                        let mut got: Vec<i64> = Vec::new();
                        for (o, call) in &prog.ops {
                            let v = ctx.call(ObjHandle(*o), call.clone())?;
                            got.push(match v {
                                atomic_rmi2::object::Value::Int(x) => x,
                                _ => 0,
                            });
                        }
                        Ok(got)
                    })
                    .unwrap();
                assert_eq!(
                    got, oracle_results[p],
                    "{} case {case} prog {p}: diverged from serial oracle\nprog: {prog:?}",
                    kind.label()
                );
            }
            // Final states agree too.
            for i in 0..n_objects {
                let oid = match &fw {
                    atomic_rmi2::workload::Framework::Optsva(s) => {
                        s.cluster().registry.locate(&format!("r{i}")).unwrap()
                    }
                    atomic_rmi2::workload::Framework::Sva(s) => {
                        s.cluster().registry.locate(&format!("r{i}")).unwrap()
                    }
                    _ => unreachable!(),
                };
                let v = fw.with_object(oid, |o| {
                    o.as_any().downcast_ref::<RegisterObject>().unwrap().value()
                });
                assert_eq!(v, oracle[i], "{} case {case}: final state", kind.label());
            }
            fw.shutdown();
        }
    }
}

/// `add`-only concurrent programs: the final value must equal the sum of
/// all committed increments for every framework (atomicity of updates).
#[test]
fn prop_concurrent_adds_sum_exactly() {
    for kind in [
        FrameworkKind::Optsva,
        FrameworkKind::Sva,
        FrameworkKind::Tfa,
        FrameworkKind::Rw2pl,
    ] {
        let cluster = Arc::new(Cluster::new(1, NetworkModel::instant()));
        let fw = Arc::new(kind.build(cluster));
        fw.host(NodeId(0), "r0", Box::new(RegisterObject::new(0)));
        let mut threads = vec![];
        for t in 0..6u64 {
            let fw = Arc::clone(&fw);
            threads.push(std::thread::spawn(move || {
                let mut rng = Prng::seeded(t);
                let mut sum = 0i64;
                for _ in 0..20 {
                    let k = 1 + rng.below(9) as i64;
                    let decls = vec![AccessDecl::new("r0", Suprema::updates(1))];
                    fw.dtm()
                        .tx(NodeId(0))
                        .with_decls(&decls)
                        .run(|ctx| {
                            ctx.call(ObjHandle(0), OpCall::unary("add", k))?;
                            Ok(())
                        })
                        .unwrap();
                    sum += k;
                }
                sum
            }));
        }
        let want: i64 = threads.into_iter().map(|t| t.join().unwrap()).sum();
        let oid = match fw.as_ref() {
            atomic_rmi2::workload::Framework::Optsva(s) => {
                s.cluster().registry.locate("r0").unwrap()
            }
            atomic_rmi2::workload::Framework::Sva(s) => s.cluster().registry.locate("r0").unwrap(),
            atomic_rmi2::workload::Framework::Tfa(s) => s.cluster().registry.locate("r0").unwrap(),
            atomic_rmi2::workload::Framework::Locks(s) => {
                s.cluster().registry.locate("r0").unwrap()
            }
        };
        let got = fw.with_object(oid, |o| {
            o.as_any().downcast_ref::<RegisterObject>().unwrap().value()
        });
        assert_eq!(got, want, "{}: lost update", kind.label());
        fw.shutdown();
    }
}

/// Early release must never let two transactions hold direct access at
/// once: a register that checks invariant "single writer" via add/get
/// round trips under randomized concurrent programs.
#[test]
fn prop_manual_abort_then_retry_converges() {
    for case in 0..10u64 {
        let cluster = Arc::new(Cluster::new(1, NetworkModel::instant()));
        let fw = Arc::new(FrameworkKind::Optsva.build(cluster));
        fw.host(NodeId(0), "r0", Box::new(RegisterObject::new(0)));
        let mut threads = vec![];
        for t in 0..4u64 {
            let fw = Arc::clone(&fw);
            threads.push(std::thread::spawn(move || {
                let mut rng = Prng::seeded(case * 31 + t);
                let mut committed = 0i64;
                for _ in 0..10 {
                    let k = 1 + rng.below(5) as i64;
                    let drop_it = rng.chance(0.4);
                    let decls = vec![AccessDecl::new("r0", Suprema::new(0, 0, 1))];
                    let r = fw.dtm().tx(NodeId(0)).with_decls(&decls).run(|ctx| {
                        ctx.call(ObjHandle(0), OpCall::unary("add", k))?;
                        if drop_it {
                            return ctx.abort();
                        }
                        Ok(())
                    });
                    match r {
                        Ok(_) => committed += k,
                        Err(TxError::ManualAbort) => {}
                        Err(e) => panic!("{e}"),
                    }
                }
                committed
            }));
        }
        let want: i64 = threads.into_iter().map(|t| t.join().unwrap()).sum();
        let oid = match fw.as_ref() {
            atomic_rmi2::workload::Framework::Optsva(s) => {
                s.cluster().registry.locate("r0").unwrap()
            }
            _ => unreachable!(),
        };
        let got = fw.with_object(oid, |o| {
            o.as_any().downcast_ref::<RegisterObject>().unwrap().value()
        });
        assert_eq!(got, want, "case {case}: aborted adds leaked into the register");
        fw.shutdown();
    }
}
