//! Sharded-transport acceptance: FIFO per sender–receiver pair through
//! the public inbox surface, end-to-end pooled execution over the
//! batched delivery path, and the megascale engine riding the same
//! transport — the integration face of the `cluster::inbox` and
//! `executor::pool` unit tests.

use atomic_rmi2::object::{Account, AccountRef};
use atomic_rmi2::workload::{run_megascale, MegascaleParams};
use atomic_rmi2::{AtomicRmi2, Cluster, NetworkModel, NodeId, Suprema};
use std::sync::Arc;
use std::time::Duration;

/// FIFO per pair on the public surface: a small message posted after a
/// large one (shorter wire delay, so it would overtake on a bare latency
/// model) is clamped to the large one's arrival and drained after it.
#[test]
fn same_pair_messages_never_overtake() {
    let c = Cluster::new_virtual(2, NetworkModel::lan());
    let now = c.clock().now();
    let big = c.inboxes().post(NodeId(0), NodeId(1), 4096, now, c.network().delay(4096), 7);
    let small = c.inboxes().post(NodeId(0), NodeId(1), 16, now, c.network().delay(16), 8);
    assert!(c.network().delay(16) < c.network().delay(4096), "premise: small is faster");
    assert_eq!(small, big, "small message is clamped to the in-flight big one's arrival");
    assert_eq!(c.inboxes().earliest(NodeId(1)), Some(big));
    assert!(c.inboxes().drain_due(NodeId(1), big - Duration::from_nanos(1)).is_empty());
    let due = c.inboxes().drain_due(NodeId(1), big);
    assert_eq!(due.len(), 2, "both arrive in the same batch");
    assert_eq!((due[0].tag, due[1].tag), (7, 8), "post order preserved");
}

/// End-to-end over the pooled executors and batched delivery: concurrent
/// cyclic cross-node transfers all commit, money is conserved, every
/// accounted message leg is delivered through an inbox drain, and
/// shutdown joins cleanly.
#[test]
fn pooled_cluster_commits_concurrent_cross_node_transfers() {
    let cluster = Arc::new(Cluster::new_virtual(4, NetworkModel::lan()));
    let sys = AtomicRmi2::new(Arc::clone(&cluster));
    for n in 0..4u16 {
        sys.host(NodeId(n), &format!("acct{n}"), Box::new(Account::with_balance(1000)));
    }
    let mut handles = Vec::new();
    for n in 0..4u16 {
        let sys = Arc::clone(&sys);
        handles.push(std::thread::spawn(move || {
            for _ in 0..3 {
                let src = format!("acct{n}");
                let dst = format!("acct{}", (n + 1) % 4);
                let mut tx = sys.tx(NodeId(n));
                let a = AccountRef::new(tx.accesses(&src, Suprema::new(1, 0, 1)));
                let b = AccountRef::new(tx.updates(&dst, 1));
                let r = tx.run(|t| {
                    a.withdraw(t, 10)?;
                    b.deposit(t, 10)?;
                    if a.balance(t)? < 0 {
                        return t.abort();
                    }
                    Ok(())
                });
                assert!(r.is_ok(), "transfer on node {n} failed: {r:?}");
            }
        }));
    }
    for h in handles {
        h.join().expect("client thread panicked");
    }
    // Drain the executor pool before reading transport counters: commit
    // may leave asynchronous release tasks whose message legs are
    // accounted at send time but drained slightly later.
    sys.shutdown();
    let (msgs, bytes, _) = cluster.stats.snapshot();
    assert!(msgs >= 2, "cyclic cross-node transfers must cross the wire");
    assert!(bytes > 0);
    let (delivered, drains) = cluster.inboxes().delivery_stats();
    assert_eq!(delivered, msgs, "at quiescence every accounted leg has been drained");
    assert!((1..=delivered).contains(&drains), "batching never inflates drain count");
    let mut total = 0i64;
    for n in 0..4u16 {
        let oid = cluster.registry.locate(&format!("acct{n}")).unwrap();
        total +=
            sys.with_object(oid, |o| o.as_any().downcast_ref::<Account>().unwrap().balance());
    }
    assert_eq!(total, 4000, "transfers conserve total balance");
}

/// The megascale engine drives the same inboxes: a small run commits
/// every transaction, batches deliveries, and advances virtual time.
#[test]
fn megascale_engine_smoke() {
    let p = MegascaleParams {
        nodes: 8,
        clients_per_node: 50,
        txns_per_client: 1,
        think: Duration::from_millis(20),
        ..Default::default()
    };
    let r = run_megascale(&p);
    assert_eq!(r.clients, 400);
    assert_eq!(r.committed_txns, 400, "pessimistic engine: no aborts, all commit");
    assert!(r.messages > 0, "80% locality still leaves cross-node traffic");
    assert!(r.batch_factor >= 1.0);
    assert!(r.sim >= p.op_delay, "at least one operation body elapsed in virtual time");
    assert!(r.throughput > 0.0);
}
