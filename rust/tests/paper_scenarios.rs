//! Integration tests: the paper's illustrative executions (Figs 1–5) as
//! deterministic interleavings, plus §2.4 irrevocability semantics.
//!
//! Each test reconstructs the history from the paper's figure and asserts
//! the blocking/parallelism structure OptSVA-CF promises.

use atomic_rmi2::object::{account::ops, Account, OpCall, RegisterObject};
use atomic_rmi2::optsva::{AtomicRmi2, OptsvaConfig};
use atomic_rmi2::{Clock, Cluster, NetworkModel, NodeId, Suprema, TxCtx, TxError};
use std::sync::Arc;
use std::time::Duration;

fn sys() -> Arc<AtomicRmi2> {
    let cluster = Arc::new(Cluster::new(2, NetworkModel::instant()));
    AtomicRmi2::with_config(
        cluster,
        OptsvaConfig { wait_timeout: Some(Duration::from_secs(20)), asynchrony: true },
    )
}

fn balance_of(sys: &AtomicRmi2, name: &str) -> i64 {
    let oid = sys.cluster().registry.locate(name).unwrap();
    sys.with_object(oid, |o| o.as_any().downcast_ref::<Account>().unwrap().balance())
}

/// Fig 1: versioning orders conflicting accesses; an unrelated object is
/// accessed fully in parallel.
#[test]
fn fig1_versioning_orders_access() {
    let sys = sys();
    sys.host(NodeId(0), "x", Box::new(Account::with_balance(0)));
    sys.host(NodeId(1), "y", Box::new(Account::with_balance(0)));

    // T_i holds x (pv 1); T_j (pv 2) must wait until T_i commits.
    let mut ti = sys.tx(NodeId(0));
    let hxi = ti.updates("x", 2);
    ti.begin().unwrap();
    ti.call(hxi, ops::deposit(1)).unwrap();

    let sys_j = Arc::clone(&sys);
    let tj = std::thread::spawn(move || {
        let mut tj = sys_j.tx(NodeId(0));
        let hxj = tj.updates("x", 1);
        tj.begin().unwrap();
        // blocks until T_i releases x at commit
        tj.call(hxj, ops::deposit(10)).unwrap();
        tj.commit().unwrap();
    });

    // T_k accesses y completely in parallel, unaffected by x's queue.
    let mut tk = sys.tx(NodeId(1));
    let hy = tk.updates("y", 1);
    let t0 = std::time::Instant::now();
    tk.run(|t| {
        t.call(hy, ops::deposit(5))?;
        Ok(())
    })
    .unwrap();
    assert!(t0.elapsed() < Duration::from_millis(200), "T_k must not block");

    std::thread::sleep(Duration::from_millis(50));
    assert!(!tj.is_finished(), "T_j must wait for T_i");
    ti.call(hxi, ops::deposit(1)).unwrap(); // second (last) access: releases
    ti.commit().unwrap();
    tj.join().unwrap();
    assert_eq!(balance_of(&sys, "x"), 12);
    sys.shutdown();
}

/// Fig 2: early release via upper bounds — T_j proceeds as soon as T_i's
/// supremum is reached, *before* T_i commits.
#[test]
fn fig2_early_release_before_commit() {
    let sys = sys();
    sys.host(NodeId(0), "x", Box::new(Account::with_balance(0)));

    let mut ti = sys.tx(NodeId(0));
    let hxi = ti.updates("x", 1); // ub = 1
    ti.begin().unwrap();
    ti.call(hxi, ops::deposit(1)).unwrap(); // supremum reached ⇒ release

    // T_j can access x while T_i is still running (not yet committed).
    let mut tj = sys.tx(NodeId(0));
    let hxj = tj.updates("x", 1);
    tj.begin().unwrap();
    let t0 = std::time::Instant::now();
    tj.call(hxj, ops::deposit(10)).unwrap();
    assert!(
        t0.elapsed() < Duration::from_millis(200),
        "T_j must not wait for T_i's commit after early release"
    );
    // Commit order is still enforced: T_j's commit waits for T_i.
    let sys_j = Arc::clone(&sys);
    let tj_thread = std::thread::spawn(move || {
        tj.commit().unwrap();
        drop(sys_j);
    });
    std::thread::sleep(Duration::from_millis(50));
    assert!(!tj_thread.is_finished(), "T_j's commit must wait for T_i's");
    ti.commit().unwrap();
    tj_thread.join().unwrap();
    assert_eq!(balance_of(&sys, "x"), 11);
    sys.shutdown();
}

/// Fig 3: cascading abort — T_j read T_i's early-released state; T_i
/// aborts, so T_j must abort too, and the state is reverted.
#[test]
fn fig3_cascading_abort() {
    let sys = sys();
    sys.host(NodeId(0), "x", Box::new(Account::with_balance(100)));

    let mut ti = sys.tx(NodeId(0));
    let hxi = ti.updates("x", 1);
    ti.begin().unwrap();
    ti.call(hxi, ops::deposit(900)).unwrap(); // early release

    let mut tj = sys.tx(NodeId(0));
    let hxj = tj.accesses("x", Suprema::new(1, 0, 0));
    tj.begin().unwrap();
    assert_eq!(tj.call(hxj, ops::balance()).unwrap().as_int(), 1000);

    // T_j's commit cannot complete until T_i terminates…
    let tj_thread = std::thread::spawn(move || tj.commit());
    std::thread::sleep(Duration::from_millis(50));
    assert!(!tj_thread.is_finished());
    // …and when T_i aborts, T_j is forced to abort as well.
    ti.abort().unwrap();
    let r = tj_thread.join().unwrap();
    assert!(matches!(r, Err(TxError::ForcedAbort(_))), "got {r:?}");
    assert_eq!(balance_of(&sys, "x"), 100, "state reverted");
    sys.shutdown();
}

/// Fig 4: asynchronous read-only buffering — the read-only object is
/// released before the transaction's first read executes, letting a
/// writer proceed while the reader still uses its buffer.
#[test]
fn fig4_async_read_only_buffering() {
    let sys = sys();
    sys.host(NodeId(0), "x", Box::new(Account::with_balance(7)));

    let mut tj = sys.tx(NodeId(0));
    let hxj = tj.reads("x", 2);
    tj.begin().unwrap();
    // Wait for the buffering task (scheduled at start) to fire.
    tj.proxy(hxj).join_task().unwrap();
    assert!(tj.proxy(hxj).released(), "buffered and released before any read");

    // T_k modifies x while T_j is still running.
    let mut tk = sys.tx(NodeId(0));
    let hxk = tk.updates("x", 1);
    tk.begin().unwrap();
    tk.call(hxk, ops::deposit(100)).unwrap();

    // T_j's reads see the *buffered* (pre-T_k) state.
    assert_eq!(tj.call(hxj, ops::balance()).unwrap().as_int(), 7);
    assert_eq!(tj.call(hxj, ops::balance()).unwrap().as_int(), 7);
    tj.commit().unwrap();
    tk.commit().unwrap();
    assert_eq!(balance_of(&sys, "x"), 107);
    sys.shutdown();
}

/// Fig 5: asynchronous release on last write — T_j's pure writes execute
/// on the log buffer with no synchronization while T_i holds the object;
/// after T_i finishes, T_j's async task applies the log and releases so
/// T_k can proceed, while T_j keeps working.
#[test]
fn fig5_async_last_write_release() {
    let sys = sys();
    sys.host(NodeId(0), "x", Box::new(RegisterObject::new(0)));
    sys.host(NodeId(0), "y", Box::new(RegisterObject::new(0)));

    // T_i takes direct access to x.
    let mut ti = sys.tx(NodeId(0));
    let hxi = ti.accesses("x", Suprema::new(1, 1, 0));
    ti.begin().unwrap();
    assert_eq!(ti.call(hxi, OpCall::nullary("get")).unwrap().as_int(), 0);

    // T_j: two pure writes on x — no waiting, even though T_i holds x.
    let mut tj = sys.tx(NodeId(0));
    let hxj = tj.accesses("x", Suprema::new(1, 2, 0));
    let hyj = tj.updates("y", 1);
    tj.begin().unwrap();
    let t0 = std::time::Instant::now();
    tj.call(hxj, OpCall::unary("set", 5i64)).unwrap();
    tj.call(hxj, OpCall::unary("set", 9i64)).unwrap(); // last write ⇒ async task
    assert!(t0.elapsed() < Duration::from_millis(200), "writes must not block");

    // T_j continues with y immediately (Fig 5's point).
    let t1 = std::time::Instant::now();
    tj.call(hyj, OpCall::unary("add", 3i64)).unwrap();
    assert!(t1.elapsed() < Duration::from_millis(200));

    // T_i finishes with x; T_j's async task applies the log and releases.
    ti.call(hxi, OpCall::unary("set", 1i64)).unwrap();
    ti.commit().unwrap();
    tj.proxy(hxj).join_task().unwrap();
    assert!(tj.proxy(hxj).released());

    // T_k can now access x (sees T_j's writes) while T_j is still open.
    let mut tk = sys.tx(NodeId(0));
    let hxk = tk.accesses("x", Suprema::new(1, 0, 0));
    tk.begin().unwrap();
    assert_eq!(tk.call(hxk, OpCall::nullary("get")).unwrap().as_int(), 9);

    // T_j's final read is served from its copy buffer.
    assert_eq!(tj.call(hxj, OpCall::nullary("get")).unwrap().as_int(), 9);
    tj.commit().unwrap();
    tk.commit().unwrap();
    sys.shutdown();
}

/// §2.4: irrevocable transactions never accept early-released state, so a
/// preceding abort cannot cascade into them.
#[test]
fn irrevocable_never_joins_a_cascade() {
    let sys = sys();
    sys.host(NodeId(0), "x", Box::new(Account::with_balance(100)));

    let mut ti = sys.tx(NodeId(0));
    let hxi = ti.updates("x", 1);
    ti.begin().unwrap();
    ti.call(hxi, ops::deposit(900)).unwrap(); // dirty, early released

    let sys_j = Arc::clone(&sys);
    let tj = std::thread::spawn(move || {
        let mut tj = sys_j.tx(NodeId(0)).irrevocable();
        let hxj = tj.accesses("x", Suprema::new(1, 0, 0));
        tj.begin().unwrap();
        let v = tj.call(hxj, ops::balance()).unwrap().as_int();
        tj.commit().unwrap();
        v
    });
    std::thread::sleep(Duration::from_millis(50));
    assert!(!tj.is_finished(), "irrevocable read must ignore the early release");
    ti.abort().unwrap(); // T_i aborts: x reverts to 100
    let seen = tj.join().unwrap();
    assert_eq!(seen, 100, "irrevocable transaction saw only committed state");
    sys.shutdown();
}

/// Suprema violations abort the offending transaction (§2.2).
#[test]
fn exceeding_the_supremum_aborts() {
    let sys = sys();
    sys.host(NodeId(0), "x", Box::new(Account::with_balance(0)));
    let mut tx = sys.tx(NodeId(0));
    let h = tx.updates("x", 1);
    let r = tx.run(|t| {
        t.call(h, ops::deposit(1))?;
        t.call(h, ops::deposit(1))?; // supremum exceeded
        Ok(())
    });
    assert!(matches!(r, Err(TxError::SupremaExceeded { .. })), "got {r:?}");
    assert_eq!(balance_of(&sys, "x"), 0, "aborted transaction left no effects");
    sys.shutdown();
}

/// The virtual-clock regression (tentpole of the build-bootstrap PR): the
/// paper's scenario structure, run over the *LAN-model* interconnect on a
/// [`atomic_rmi2::VirtualClock`], must complete with **zero** real sleeps
/// through the substrate while still accounting every injected latency in
/// simulated time. Before the clock refactor this workload slept for real
/// on every cross-node RPC.
#[test]
fn scenarios_complete_under_virtual_time_with_zero_real_sleeps() {
    let cluster = Arc::new(Cluster::new_virtual(2, NetworkModel::lan()));
    let clock = Arc::clone(cluster.clock());
    assert!(clock.is_virtual());
    let sys = AtomicRmi2::with_config(
        cluster,
        OptsvaConfig { wait_timeout: Some(Duration::from_secs(20)), asynchrony: true },
    );
    sys.host(NodeId(0), "x", Box::new(Account::with_balance(1000)));
    sys.host(NodeId(1), "y", Box::new(Account::with_balance(0)));

    let real_sleeps_before = atomic_rmi2::clock::real_sleep_count();
    let wall0 = std::time::Instant::now();
    let sim0 = clock.now();

    // Fig 1/2-shaped cross-node transfers: every access to `y` is remote
    // from the node-0 client, so each transaction pays start-lock, call,
    // and commit-protocol latency on the simulated interconnect.
    for _ in 0..30 {
        let mut tx = sys.tx(NodeId(0));
        let hx = tx.updates("x", 1);
        let hy = tx.updates("y", 1);
        tx.run(|t| {
            t.call(hx, ops::withdraw(1))?;
            t.call(hy, ops::deposit(1))?;
            Ok(())
        })
        .unwrap();
    }
    // An early-release handoff still works under virtual time.
    let mut ti = sys.tx(NodeId(0));
    let hxi = ti.updates("x", 1);
    ti.begin().unwrap();
    ti.call(hxi, ops::deposit(5)).unwrap(); // supremum reached ⇒ release
    let mut tj = sys.tx(NodeId(0));
    let hxj = tj.updates("x", 1);
    tj.begin().unwrap();
    tj.call(hxj, ops::deposit(5)).unwrap(); // proceeds on the early release
    ti.commit().unwrap();
    tj.commit().unwrap();

    let sim_elapsed = clock.now() - sim0;
    assert!(
        sim_elapsed >= Duration::from_millis(10),
        "simulated latency must be accounted (got {sim_elapsed:?})"
    );
    assert!(
        wall0.elapsed() < Duration::from_secs(10),
        "virtual-time run must not block on real sleeps"
    );
    assert_eq!(
        atomic_rmi2::clock::real_sleep_count(),
        real_sleeps_before,
        "the substrate performed a real sleep under the virtual clock"
    );
    assert_eq!(balance_of(&sys, "x"), 1000 - 30 + 10);
    assert_eq!(balance_of(&sys, "y"), 30);
    sys.shutdown();
}

/// Unknown suprema (∞) keep full guarantees — objects are simply held to
/// commit (no early release).
#[test]
fn unknown_suprema_hold_until_commit() {
    let sys = sys();
    sys.host(NodeId(0), "x", Box::new(Account::with_balance(0)));
    let mut t1 = sys.tx(NodeId(0));
    let h1 = t1.accesses("x", Suprema::unknown());
    t1.begin().unwrap();
    t1.call(h1, ops::deposit(1)).unwrap();
    assert!(!t1.proxy(h1).released(), "∞ supremum ⇒ no early release");

    let sys2 = Arc::clone(&sys);
    let t2 = std::thread::spawn(move || {
        let mut t2 = sys2.tx(NodeId(0));
        let h2 = t2.updates("x", 1);
        t2.run(|t| {
            t.call(h2, ops::deposit(10))?;
            Ok(())
        })
        .unwrap();
    });
    std::thread::sleep(Duration::from_millis(50));
    assert!(!t2.is_finished(), "x held until T1 commits");
    t1.commit().unwrap();
    t2.join().unwrap();
    assert_eq!(balance_of(&sys, "x"), 11);
    sys.shutdown();
}
