//! The asynchronous (futures) transaction API, end to end:
//!
//!  * a property test that `submit` + `wait` — in arbitrary wait
//!    interleavings — commits exactly the same per-operation results and
//!    final states as the sequential `call` path (the `asynchrony = false`
//!    ablation), over random programs;
//!  * a regression that an [`OpFuture`](atomic_rmi2::OpFuture) dropped
//!    unresolved still executes, still counts toward the declared suprema,
//!    and surfaces failures at commit;
//!  * a deterministic simulated-time comparison showing submit-then-wait
//!    pipelining beating blocking `call`s (the §2.6/§2.8 asynchrony win);
//!  * an attempts-accounting regression for bodies that abort before
//!    their first operation (shared retry driver).

use atomic_rmi2::api::{AccessDecl, ObjHandle, Suprema, TxCtx, TxError};
use atomic_rmi2::object::{account::ops, Account, OpCall, RegisterObject, Value};
use atomic_rmi2::optsva::{AtomicRmi2, OptsvaConfig};
use atomic_rmi2::util::prng::Prng;
use atomic_rmi2::workload::FrameworkKind;
use atomic_rmi2::{Cluster, NetworkModel, NodeId};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// One random transaction program over register objects.
#[derive(Debug, Clone)]
struct Prog {
    ops: Vec<(usize, OpCall)>,
}

fn gen_prog(rng: &mut Prng, n_objects: usize, max_ops: usize) -> Prog {
    let n_ops = 1 + rng.index(max_ops);
    let mut ops = Vec::with_capacity(n_ops);
    for _ in 0..n_ops {
        let obj = rng.index(n_objects);
        let op = match rng.index(3) {
            0 => OpCall::nullary("get"),
            1 => OpCall::unary("set", rng.below(100) as i64),
            _ => OpCall::unary("add", rng.below(10) as i64),
        };
        ops.push((obj, op));
    }
    Prog { ops }
}

/// Exact per-mode suprema (perfect a-priori knowledge, as the paper's
/// preamble provides).
fn suprema_for(prog: &Prog, n_objects: usize) -> Vec<Suprema> {
    let mut sup = vec![Suprema::new(0, 0, 0); n_objects];
    for (o, call) in &prog.ops {
        match call.method {
            "get" => sup[*o].reads += 1,
            "set" => sup[*o].writes += 1,
            _ => sup[*o].updates += 1,
        }
    }
    sup
}

fn build(asynchrony: bool, n_objects: usize) -> Arc<AtomicRmi2> {
    let cluster = Arc::new(Cluster::new(2, NetworkModel::instant()));
    let sys = AtomicRmi2::with_config(
        cluster,
        OptsvaConfig { wait_timeout: Some(Duration::from_secs(10)), asynchrony },
    );
    for i in 0..n_objects {
        sys.host(
            NodeId((i % 2) as u16),
            &format!("r{i}"),
            Box::new(RegisterObject::new(0)),
        );
    }
    sys
}

fn final_states(sys: &AtomicRmi2, n_objects: usize) -> Vec<i64> {
    (0..n_objects)
        .map(|i| {
            let oid = sys.cluster().registry.locate(&format!("r{i}")).unwrap();
            sys.with_object(oid, |o| {
                o.as_any().downcast_ref::<RegisterObject>().unwrap().value()
            })
        })
        .collect()
}

/// Run `prog` on `sys`; `wait_order` = None uses blocking calls, Some(rng)
/// submits everything first and waits the futures in a random permutation.
fn run_prog(
    sys: &Arc<AtomicRmi2>,
    prog: &Prog,
    n_objects: usize,
    mut wait_order: Option<&mut Prng>,
) -> Vec<Value> {
    let sup = suprema_for(prog, n_objects);
    let mut tx = sys.tx(NodeId(0));
    let mut handle_of: Vec<Option<ObjHandle>> = vec![None; n_objects];
    for (i, s) in sup.iter().enumerate() {
        if s.total() > 0 {
            handle_of[i] = Some(tx.accesses(&format!("r{i}"), *s));
        }
    }
    let (out, _) = tx
        .run(|t| {
            match wait_order.as_deref_mut() {
                None => {
                    let mut out = Vec::with_capacity(prog.ops.len());
                    for (o, call) in &prog.ops {
                        out.push(t.call(handle_of[*o].unwrap(), call.clone())?);
                    }
                    Ok(out)
                }
                Some(rng) => {
                    let mut futures = Vec::with_capacity(prog.ops.len());
                    for (o, call) in &prog.ops {
                        futures.push(Some(t.submit(handle_of[*o].unwrap(), call.clone())?));
                    }
                    // Wait in a random permutation: per-object program
                    // order is the framework's job, not the caller's.
                    let mut order: Vec<usize> = (0..futures.len()).collect();
                    rng.shuffle(&mut order);
                    let mut out: Vec<Option<Value>> = (0..futures.len()).map(|_| None).collect();
                    for i in order {
                        out[i] = Some(futures[i].take().unwrap().wait()?);
                    }
                    Ok(out.into_iter().map(Option::unwrap).collect())
                }
            }
        })
        .expect("single-threaded program must commit");
    out
}

/// Property: submit+wait (any interleaving) ≡ sequential call — per-op
/// results and final states — with the `asynchrony = false` ablation as
/// the sequential oracle.
#[test]
fn prop_submit_wait_matches_sequential_call() {
    for case in 0..15u64 {
        let mut rng = Prng::seeded(0xA51C ^ case);
        let mut wait_rng = Prng::seeded(0xD0_0D ^ case);
        let n_objects = 2 + rng.index(4);
        let progs: Vec<Prog> = (0..5).map(|_| gen_prog(&mut rng, n_objects, 8)).collect();

        let oracle = build(false, n_objects); // sequential ablation
        let subject = build(true, n_objects); // full asynchrony
        for prog in &progs {
            let want = run_prog(&oracle, prog, n_objects, None);
            let got = run_prog(&subject, prog, n_objects, Some(&mut wait_rng));
            assert_eq!(got, want, "case {case}: results diverged\nprog: {prog:?}");
        }
        assert_eq!(
            final_states(&subject, n_objects),
            final_states(&oracle, n_objects),
            "case {case}: final states diverged"
        );
        oracle.shutdown();
        subject.shutdown();
    }
}

fn account_sys() -> Arc<AtomicRmi2> {
    let cluster = Arc::new(Cluster::new(1, NetworkModel::instant()));
    AtomicRmi2::with_config(
        cluster,
        OptsvaConfig { wait_timeout: Some(Duration::from_secs(10)), asynchrony: true },
    )
}

/// Regression: a future dropped unresolved still executes, counts toward
/// the supremum (so the object is released at the declared bound), and
/// its effect commits.
#[test]
fn unresolved_future_at_commit_still_enforces_supremum_accounting() {
    let sys = account_sys();
    let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
    let mut tx = sys.tx(NodeId(0));
    let h = tx.updates("A", 1);
    tx.begin().unwrap();
    let fut = tx.submit(h, ops::deposit(5)).unwrap();
    drop(fut); // never waited
    tx.commit().unwrap();
    // The operation ran exactly once and the per-mode counter reflects it.
    assert_eq!(tx.proxy(h).counts(), (0, 0, 1), "supremum accounting");
    assert!(tx.proxy(h).released(), "released at the declared bound");
    assert_eq!(
        sys.with_object(a, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()),
        5
    );
    sys.shutdown();
}

/// Regression: a *failing* submitted operation whose future was dropped
/// aborts the transaction at commit — the error cannot vanish.
#[test]
fn unobserved_submitted_failure_surfaces_at_commit() {
    let sys = account_sys();
    let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(100)));
    let mut tx = sys.tx(NodeId(0));
    let h = tx.updates("A", 1);
    tx.begin().unwrap();
    let f1 = tx.submit(h, ops::deposit(1)).unwrap();
    let f2 = tx.submit(h, ops::deposit(1)).unwrap(); // exceeds the supremum
    drop(f1);
    drop(f2);
    let r = tx.commit();
    assert!(matches!(r, Err(TxError::SupremaExceeded { .. })), "got {r:?}");
    // The transaction aborted: the first deposit was rolled back.
    assert_eq!(
        sys.with_object(a, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()),
        100
    );
    sys.shutdown();
}

/// The same guarantee holds in the `asynchrony = false` ablation: inline
/// submits are registered with the commit drain too.
#[test]
fn unobserved_inline_failure_surfaces_at_commit_in_ablation_mode() {
    let cluster = Arc::new(Cluster::new(1, NetworkModel::instant()));
    let sys = AtomicRmi2::with_config(
        cluster,
        OptsvaConfig { wait_timeout: Some(Duration::from_secs(10)), asynchrony: false },
    );
    let a = sys.host(NodeId(0), "A", Box::new(Account::with_balance(100)));
    let mut tx = sys.tx(NodeId(0));
    let h = tx.updates("A", 1);
    tx.begin().unwrap();
    drop(tx.submit(h, ops::deposit(1)).unwrap());
    drop(tx.submit(h, ops::deposit(1)).unwrap()); // exceeds the supremum inline
    let r = tx.commit();
    assert!(matches!(r, Err(TxError::SupremaExceeded { .. })), "got {r:?}");
    assert_eq!(
        sys.with_object(a, |o| o.as_any().downcast_ref::<Account>().unwrap().balance()),
        100
    );
    sys.shutdown();
}

/// Run one 8-op transaction over 8 registers spread across 4 nodes on a
/// virtual clock, returning the simulated time it took.
fn timed_transaction(pipeline: bool) -> Duration {
    let cluster = Arc::new(Cluster::with_clock(
        4,
        NetworkModel { one_way: Duration::from_millis(2), per_kib: Duration::ZERO },
        Arc::new(atomic_rmi2::VirtualClock::new()),
    ));
    let clock = Arc::clone(cluster.clock());
    let sys = AtomicRmi2::new(cluster);
    for n in 0..4u16 {
        for i in 0..2u16 {
            sys.host(NodeId(n), &format!("r-{n}-{i}"), Box::new(RegisterObject::new(0)));
        }
    }
    let t0 = clock.now();
    let mut tx = sys.tx(NodeId(0));
    let mut handles = Vec::new();
    for n in 0..4u16 {
        for i in 0..2u16 {
            handles.push(tx.accesses(&format!("r-{n}-{i}"), Suprema::updates(1)));
        }
    }
    tx.run(|t| {
        if pipeline {
            let mut futures = Vec::with_capacity(handles.len());
            for h in &handles {
                futures.push(t.submit(*h, OpCall::unary("add", 1i64))?);
            }
            for f in futures {
                f.wait()?;
            }
        } else {
            for h in &handles {
                t.call(*h, OpCall::unary("add", 1i64))?;
            }
        }
        Ok(())
    })
    .unwrap();
    let elapsed = clock.now().saturating_sub(t0);
    sys.shutdown();
    elapsed
}

/// The asynchrony win, on simulated time: submitting all operations and
/// then waiting must beat one blocking round trip per operation. With a
/// single client every virtual sleep is serial on one thread, so the
/// comparison is deterministic up to executor scheduling — which can only
/// *shrink* the pipelined time, never push it past the blocking bound.
#[test]
fn pipelined_submit_beats_blocking_call_on_simulated_time() {
    let blocking = timed_transaction(false);
    let pipelined = timed_transaction(true);
    // Structure: every remote op pays two one-way trips inline when
    // blocking; pipelined ops pay the send leg inline and overlap their
    // response legs with later sends and executor work, so the pipelined
    // run is strictly cheaper in simulated time.
    assert!(
        pipelined < blocking,
        "submit-then-wait must beat blocking calls: pipelined {pipelined:?} vs blocking {blocking:?}"
    );
}

/// Attempts accounting (shared retry driver): a body that aborts *before
/// its first operation* still counts the attempt, for every retrying
/// framework.
#[test]
fn attempts_counted_when_body_aborts_before_first_op() {
    for kind in [FrameworkKind::Optsva, FrameworkKind::Sva, FrameworkKind::Tfa] {
        let cluster = Arc::new(Cluster::new(1, NetworkModel::instant()));
        let fw = kind.build(cluster);
        fw.host(NodeId(0), "r0", Box::new(RegisterObject::new(0)));
        let tries = AtomicU64::new(0);
        let decls = vec![AccessDecl::new("r0", Suprema::updates(1))];
        let ((), stats) = fw
            .dtm()
            .tx(NodeId(0))
            .with_decls(&decls)
            .run(|t| {
                if tries.fetch_add(1, Ordering::SeqCst) < 2 {
                    return t.retry(); // abort with zero ops executed
                }
                t.call(ObjHandle(0), OpCall::unary("add", 1i64))?;
                Ok(())
            })
            .unwrap();
        assert_eq!(stats.attempts, 3, "{}: zero-op attempts must count", kind.label());
        assert_eq!(stats.ops, 1, "{}", kind.label());
        fw.shutdown();
    }
}
