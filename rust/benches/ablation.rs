//! Ablation bench: which OptSVA-CF optimization buys what (DESIGN.md §5).
//!
//! Compares on the Fig 10 point, three read-write ratios:
//!   * `atomic-rmi2+pipe`  — full OptSVA-CF, operations issued through the
//!     asynchronous `submit` API (submit-then-wait pipelining);
//!   * `atomic-rmi2`       — full OptSVA-CF, blocking `call` per op;
//!   * `atomic-rmi2-sync`  — asynchrony disabled (buffering/last-write
//!     release run inline, `submit` degrades to `call`);
//!   * `atomic-rmi`        — SVA (no buffering, no mode distinction):
//!     isolates the entire OptSVA-CF optimization stack.
//!
//! Speedups in parentheses are relative to blocking `atomic-rmi2`; the
//! pipelined row is where submit-then-wait beats blocking `call` on
//! simulated time.
//!
//! Besides the printed table, the run writes
//! `target/bench-results/BENCH_ablation.json`: one entry per
//! variant × ratio (e.g. `atomic-rmi2+pipe/90r`) whose
//! `throughput_ops_s` is gated by CI against the committed baseline.
//!
//! `cargo bench --bench ablation` (`ARMI2_BENCH_QUICK=1` to smoke).

use atomic_rmi2::bench::{default_output_dir, BenchReport};
use atomic_rmi2::metrics::{fmt_speedup, fmt_throughput, Table};
use atomic_rmi2::workload::{run_eigenbench, EigenbenchParams, FrameworkKind};
use atomic_rmi2::NetworkModel;
use std::time::Duration;

fn main() {
    let quick = std::env::var_os("ARMI2_BENCH_QUICK").is_some();
    let mut report = BenchReport::new("ablation")
        .config("scale", if quick { "Quick" } else { "Full" })
        .config("nodes", 4)
        .config("arrays_per_node", 10)
        .config("net", "lan");
    let mut table = Table::new(
        "Ablation: throughput [ops/s], 4 nodes x 8 clients, 10 arrays/node",
        &["variant", "9÷1", "5÷5", "1÷9"],
    );
    // (kind, pipelined, label) — the blocking baseline runs first so every
    // later row can report its speedup against it.
    let variants = [
        (FrameworkKind::Optsva, false, "atomic-rmi2"),
        (FrameworkKind::Optsva, true, "atomic-rmi2+pipe"),
        (FrameworkKind::OptsvaNoAsync, false, "atomic-rmi2-sync"),
        (FrameworkKind::Sva, false, "atomic-rmi"),
    ];
    let mut base: Vec<f64> = Vec::new();
    for (kind, pipeline_ops, label) in variants {
        let mut row = vec![label.to_string()];
        for read_pct in [90u8, 50, 10] {
            let r = run_eigenbench(&EigenbenchParams {
                kind,
                nodes: 4,
                clients_per_node: if quick { 2 } else { 8 },
                arrays_per_node: 10,
                txns_per_client: if quick { 2 } else { 6 },
                hot_ops: 10,
                read_pct,
                op_delay: Duration::from_micros(if quick { 100 } else { 800 }),
                net: NetworkModel::lan(),
                pipeline_ops,
                ..Default::default()
            });
            if kind == FrameworkKind::Optsva && !pipeline_ops {
                base.push(r.throughput);
            }
            report.push(r.bench_entry(format!("{label}/{read_pct}r")));
            row.push(fmt_throughput(r.throughput));
            if label != "atomic-rmi2" && !base.is_empty() {
                let i = row.len() - 2;
                if let Some(b) = base.get(i) {
                    let s = fmt_speedup(r.throughput, *b);
                    let last = row.last_mut().unwrap();
                    *last = format!("{last} ({s})");
                }
            }
        }
        table.add_row(row);
    }
    println!("{}", table.render());
    match report.write_to(&default_output_dir()) {
        Ok(path) => println!("ablation done — report: {}", path.display()),
        Err(e) => {
            eprintln!("ablation done — failed to write report: {e}");
            std::process::exit(1);
        }
    }
}
