//! Microbenchmarks of the coordinator's hot-path primitives (the §Perf
//! profiling substrate): versioning handoff, start-lock acquisition,
//! executor dispatch, buffer capture, proxy round trip, registry lookup
//! (stringly vs interned), and the XLA kernel call. Criterion is not in
//! the offline mirror; this is a plain median-of-N harness with warmup.
//!
//! Besides the printed table, the run writes
//! `target/bench-results/BENCH_micro.json` (see `docs/BENCHMARKS.md`):
//! one entry per primitive with an `ns_per_op` metric, gated by CI
//! against the committed `BENCH_micro.json` baseline.

use atomic_rmi2::api::Suprema;
use atomic_rmi2::bench::{default_output_dir, BenchEntry, BenchReport};
use atomic_rmi2::buffers::CopyBuffer;
use atomic_rmi2::clock::{Clock, RealClock};
use atomic_rmi2::cluster::registry::{CoarseRegistry, Registry};
use atomic_rmi2::cluster::ShardedInboxes;
use atomic_rmi2::executor::Executor;
use atomic_rmi2::object::{account::ops, Account, ComputeBackend, SharedObject, SpinBackend};
use atomic_rmi2::optsva::AtomicRmi2;
use atomic_rmi2::runtime::{XlaBackend, XlaRuntime};
use atomic_rmi2::versioning::ObjectCc;
use atomic_rmi2::{Cluster, NetworkModel, NodeId, Oid, TxCtx};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Median wall time of `iters` batched runs of `f`, printed and recorded
/// into `report` as an entry named `key` with `ns_per_op` (median) and
/// `ns_per_op_p95` metrics. Returns the median ns/op.
fn bench(
    report: &mut BenchReport,
    key: &str,
    label: &str,
    iters: u64,
    batch: u64,
    mut f: impl FnMut(),
) -> f64 {
    // warmup
    for _ in 0..batch.min(1000) {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as u64 / batch.max(1));
    }
    samples.sort_unstable();
    let med = samples[samples.len() / 2];
    let p95 = samples[(samples.len() as f64 * 0.95) as usize];
    println!("{label:<44} median {med:>9} ns/op   p95 {p95:>9} ns/op");
    report.push(
        BenchEntry::new(key)
            .metric("ns_per_op", med as f64)
            .metric("ns_per_op_p95", p95 as f64),
    );
    med as f64
}

fn main() {
    println!("== micro: coordinator hot-path primitives ==");
    let mut report = BenchReport::new("micro").config("harness", "median-of-N");

    // 1. Versioning handoff: assign pv → wait_access → release → terminate.
    let cc = ObjectCc::new();
    bench(
        &mut report,
        "versioning_handoff",
        "versioning: pv+access+release+terminate",
        30,
        1000,
        || {
            let pv = cc.assign_pv();
            cc.wait_access(pv, None).unwrap();
            cc.release(pv);
            cc.terminate(pv);
        },
    );

    // 2. Start-lock acquisition over an 8-object access set.
    let ccs: Vec<ObjectCc> = (0..8).map(|_| ObjectCc::new()).collect();
    let view: Vec<_> = ccs
        .iter()
        .enumerate()
        .map(|(i, cc)| (Oid::new(NodeId(0), i as u32), cc))
        .collect();
    bench(
        &mut report,
        "startlock_8obj",
        "startlock: 8-object atomic pv acquisition",
        30,
        1000,
        || {
            let _ = atomic_rmi2::versioning::acquire_start_locks(&view, |_| {});
        },
    );

    // 3. Executor: submit + run an immediately-true task.
    let ex = Executor::spawn();
    let clock = RealClock::shared();
    bench(
        &mut report,
        "executor_submit_complete",
        "executor: submit+complete (ready task)",
        20,
        200,
        || {
            let h = ex.submit(|| true, || {});
            h.join(clock.as_ref(), Some(clock.now() + Duration::from_secs(5)))
                .unwrap();
        },
    );
    ex.shutdown();

    // 4. Copy-buffer capture of a small object.
    let acct = Account::with_balance(42);
    bench(
        &mut report,
        "copybuffer_capture_account",
        "buffers: CopyBuffer::capture(Account)",
        30,
        10_000,
        || {
            std::hint::black_box(CopyBuffer::capture(&acct));
        },
    );

    // 5. Registry lookup: the pre-overhaul stringly path (hash the name on
    // every dispatch, one coarse lock) vs the interned path the hot path
    // now takes (NameId → striped entry table, no string hashing). The
    // ratio is the headline win of the interned/striped registry.
    const NAMES: u32 = 1024;
    let coarse = CoarseRegistry::new();
    let interned = Registry::new();
    let names: Vec<String> = (0..NAMES).map(|i| format!("bench-object-{i}")).collect();
    for (i, name) in names.iter().enumerate() {
        let oid = Oid::new(NodeId((i % 4) as u16), i as u32);
        coarse.bind(name.clone(), oid);
        interned.bind(name, oid);
    }
    let ids: Vec<_> = names.iter().map(|n| interned.lookup(n).unwrap()).collect();
    let mut i = 0usize;
    let stringly_ns = bench(
        &mut report,
        "registry_coarse_locate",
        "registry: stringly locate (coarse lock)",
        30,
        50_000,
        || {
            i = (i + 1) % names.len();
            std::hint::black_box(coarse.locate(&names[i]));
        },
    );
    let mut j = 0usize;
    let interned_ns = bench(
        &mut report,
        "registry_interned_resolve",
        "registry: interned resolve (striped)",
        30,
        50_000,
        || {
            j = (j + 1) % ids.len();
            std::hint::black_box(interned.resolve(ids[j]));
        },
    );
    let speedup = stringly_ns / interned_ns.max(1.0);
    println!("registry: interned speedup {speedup:>39.1}x");
    report.push(BenchEntry::new("registry_speedup").metric("speedup_x", speedup));

    // 6. Full transaction round trip, 1 object, instant network.
    let cluster = Arc::new(Cluster::new(1, NetworkModel::instant()));
    let sys = AtomicRmi2::new(cluster);
    sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
    bench(
        &mut report,
        "optsva_txn_1obj_call",
        "optsva: full 1-object update txn",
        20,
        200,
        || {
            let mut tx = sys.tx(NodeId(0));
            let h = tx.accesses("A", Suprema::updates(1));
            let _ = tx
                .run(|t| {
                    t.call(h, ops::deposit(1))?;
                    Ok(())
                })
                .unwrap();
        },
    );

    // 6b. Same transaction through the asynchronous submit path.
    bench(
        &mut report,
        "optsva_txn_1obj_submit",
        "optsva: full 1-object txn (submit+wait)",
        20,
        200,
        || {
            let mut tx = sys.tx(NodeId(0));
            let h = tx.accesses("A", Suprema::updates(1));
            let _ = tx
                .run(|t| {
                    t.submit(h, ops::deposit(1))?.wait()?;
                    Ok(())
                })
                .unwrap();
        },
    );

    // 6c. Tracing-off overhead: the identical transaction with the (now
    // ubiquitous) trace instrumentation compiled in but no session open.
    // Every instrumentation point costs one relaxed atomic gate load, so
    // this must track optsva_txn_1obj_call within noise — the "zero cost
    // when off" guarantee of docs/OBSERVABILITY.md, held by the gate.
    assert!(!atomic_rmi2::trace::enabled(), "no trace session during benches");
    bench(
        &mut report,
        "trace_overhead",
        "trace: 1-object txn, tracing off",
        20,
        200,
        || {
            let mut tx = sys.tx(NodeId(0));
            let h = tx.accesses("A", Suprema::updates(1));
            let _ = tx
                .run(|t| {
                    t.call(h, ops::deposit(1))?;
                    Ok(())
                })
                .unwrap();
        },
    );

    // 7. Kernel call: spin reference vs AOT XLA artifact.
    let spin = SpinBackend::new(64, 4);
    let state = vec![0.1f32; 64];
    let params = vec![0.05f32; 64];
    bench(
        &mut report,
        "kernel_spin_mix",
        "kernel: SpinBackend mix (D=64, R=4)",
        20,
        500,
        || {
            std::hint::black_box(spin.mix(&state, &params).unwrap());
        },
    );
    if XlaRuntime::artifacts_present(&XlaRuntime::default_dir()) {
        let xla = XlaBackend::load_default().expect("artifacts");
        bench(
            &mut report,
            "kernel_xla_mix",
            "kernel: XlaBackend mix (AOT artifact)",
            20,
            500,
            || {
                std::hint::black_box(xla.mix(&state, &params).unwrap());
            },
        );
    } else {
        println!("kernel: XlaBackend skipped (run `make artifacts`)");
    }
    sys.shutdown();

    // 8. Inbox envelope pooling: one post → drain_due → recycle cycle on a
    // sharded inbox. `drain_due` hands back a free-listed batch buffer and
    // `recycle` returns it, so the steady state allocates nothing — the
    // hit ratio below is the pooling effectiveness metric the cluster
    // transport's delivery loop relies on.
    let inboxes = ShardedInboxes::new(2);
    let (src, dst) = (NodeId(0), NodeId(1));
    let mut vt = Duration::ZERO;
    bench(
        &mut report,
        "inbox_pool_cycle",
        "cluster: inbox post+drain_due+recycle",
        30,
        20_000,
        || {
            vt += Duration::from_nanos(20);
            inboxes.post(src, dst, 64, vt, Duration::ZERO, 0);
            let batch = inboxes.drain_due(dst, vt);
            inboxes.recycle(dst, batch);
        },
    );
    let (hits, allocs) = inboxes.pool_stats();
    let hit_ratio = hits as f64 / (hits + allocs).max(1) as f64;
    println!("cluster: inbox pool hit ratio {hit_ratio:>29.3} ({hits} hits / {allocs} allocs)");
    report.push(
        BenchEntry::new("inbox_pool")
            .metric("hit_ratio", hit_ratio)
            .metric("allocs", allocs as f64),
    );

    // 9. deposit_heavy: 8 clients hammering one hot account over a
    // simulated LAN, measured in *virtual* time. Commuting update-only
    // transactions are admitted through a shared group grant — no
    // exclusive chain position, no copy-buffer snapshots — so their
    // per-operation round trips overlap across clients. The chained
    // baseline runs the identical deposits under a declaration that also
    // carries a read supremum, which disqualifies them from grouping:
    // each transaction then holds the account exclusively from its first
    // deposit to its last, serializing every round trip behind the
    // version chain.
    const DH_CLIENTS: u16 = 8;
    const DH_TXNS: u64 = 8;
    const DH_OPS: u64 = 4;
    let deposit_heavy = |commuting: bool| -> f64 {
        let cluster = Arc::new(Cluster::new_virtual(DH_CLIENTS + 1, NetworkModel::lan()));
        let clock = Arc::clone(cluster.clock());
        let sys = AtomicRmi2::new(cluster);
        let hot = sys.host(NodeId(0), "hot", Box::new(Account::with_balance(0)));
        let t0 = clock.now();
        let handles: Vec<_> = (0..DH_CLIENTS)
            .map(|c| {
                let sys = Arc::clone(&sys);
                std::thread::spawn(move || {
                    for _ in 0..DH_TXNS {
                        let mut tx = sys.tx(NodeId(c + 1));
                        let h = if commuting {
                            tx.updates("hot", DH_OPS)
                        } else {
                            tx.accesses("hot", Suprema::new(1, 0, DH_OPS))
                        };
                        tx.run(|t| {
                            for _ in 0..DH_OPS {
                                t.call(h, ops::deposit(1))?;
                            }
                            Ok(())
                        })
                        .expect("deposit_heavy txn");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("deposit_heavy client");
        }
        let virt = clock.now().saturating_sub(t0);
        let total = (DH_CLIENTS as u64 * DH_TXNS * DH_OPS) as i64;
        let bal =
            sys.with_object(hot, |o| o.as_any().downcast_ref::<Account>().unwrap().balance());
        assert_eq!(bal, total, "every deposit must land exactly once");
        sys.shutdown();
        virt.as_secs_f64() * 1e6
    };
    let chained_us = deposit_heavy(false);
    let commute_us = deposit_heavy(true);
    let speedup = chained_us / commute_us.max(1e-9);
    println!(
        "deposit_heavy: chained {chained_us:>7.0} virt-µs  commuting {commute_us:>7.0} virt-µs  \
         speedup {speedup:.1}x"
    );
    assert!(
        speedup >= 2.0,
        "group grants must beat the exclusive chain >=2x on the hot account \
         (chained {chained_us:.0}us / commuting {commute_us:.0}us = {speedup:.2}x)"
    );
    report.push(
        BenchEntry::new("deposit_heavy")
            .metric("chained_virt_us", chained_us)
            .metric("commute_virt_us", commute_us)
            .metric("commute_speedup", speedup),
    );

    match report.write_to(&default_output_dir()) {
        Ok(path) => println!("micro done — report: {}", path.display()),
        Err(e) => {
            eprintln!("micro done — failed to write report: {e}");
            std::process::exit(1);
        }
    }
}
