//! Microbenchmarks of the coordinator's hot-path primitives (the §Perf
//! profiling substrate): versioning handoff, start-lock acquisition,
//! executor dispatch, buffer capture, proxy round trip, and the XLA
//! kernel call. Criterion is not in the offline mirror; this is a plain
//! median-of-N harness with warmup.

use atomic_rmi2::api::Suprema;
use atomic_rmi2::buffers::CopyBuffer;
use atomic_rmi2::clock::{Clock, RealClock};
use atomic_rmi2::executor::Executor;
use atomic_rmi2::object::{account::ops, Account, ComputeBackend, SpinBackend};
use atomic_rmi2::optsva::AtomicRmi2;
use atomic_rmi2::runtime::{XlaBackend, XlaRuntime};
use atomic_rmi2::versioning::ObjectCc;
use atomic_rmi2::{Cluster, NetworkModel, NodeId, TxCtx};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Median wall time of `iters` batched runs of `f` (ns per op).
fn bench(name: &str, iters: u64, batch: u64, mut f: impl FnMut()) {
    // warmup
    for _ in 0..batch.min(1000) {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as u64 / batch.max(1));
    }
    samples.sort_unstable();
    let med = samples[samples.len() / 2];
    let p95 = samples[(samples.len() as f64 * 0.95) as usize];
    println!("{name:<44} median {med:>9} ns/op   p95 {p95:>9} ns/op");
}

fn main() {
    println!("== micro: coordinator hot-path primitives ==");

    // 1. Versioning handoff: assign pv → wait_access → release → terminate.
    let cc = ObjectCc::new();
    bench("versioning: pv+access+release+terminate", 30, 1000, || {
        let pv = cc.assign_pv();
        cc.wait_access(pv, None).unwrap();
        cc.release(pv);
        cc.terminate(pv);
    });

    // 2. Start-lock acquisition over an 8-object access set.
    let ccs: Vec<ObjectCc> = (0..8).map(|_| ObjectCc::new()).collect();
    let view: Vec<_> = ccs
        .iter()
        .enumerate()
        .map(|(i, cc)| (atomic_rmi2::Oid::new(NodeId(0), i as u32), cc))
        .collect();
    bench("startlock: 8-object atomic pv acquisition", 30, 1000, || {
        let _ = atomic_rmi2::versioning::acquire_start_locks(&view, |_| {});
    });

    // 3. Executor: submit + run an immediately-true task.
    let ex = Executor::spawn();
    let clock = RealClock::shared();
    bench("executor: submit+complete (ready task)", 20, 200, || {
        let h = ex.submit(|| true, || {});
        h.join(clock.as_ref(), Some(clock.now() + Duration::from_secs(5)))
            .unwrap();
    });
    ex.shutdown();

    // 4. Copy-buffer capture of a small object.
    let acct = Account::with_balance(42);
    bench("buffers: CopyBuffer::capture(Account)", 30, 10_000, || {
        std::hint::black_box(CopyBuffer::capture(&acct));
    });

    // 5. Full transaction round trip, 1 object, instant network.
    let cluster = Arc::new(Cluster::new(1, NetworkModel::instant()));
    let sys = AtomicRmi2::new(cluster);
    sys.host(NodeId(0), "A", Box::new(Account::with_balance(0)));
    bench("optsva: full 1-object update txn", 20, 200, || {
        let mut tx = sys.tx(NodeId(0));
        let h = tx.accesses("A", Suprema::updates(1));
        let _ = tx
            .run(|t| {
                t.call(h, ops::deposit(1))?;
                Ok(())
            })
            .unwrap();
    });

    // 5b. Same transaction through the asynchronous submit path.
    bench("optsva: full 1-object txn (submit+wait)", 20, 200, || {
        let mut tx = sys.tx(NodeId(0));
        let h = tx.accesses("A", Suprema::updates(1));
        let _ = tx
            .run(|t| {
                t.submit(h, ops::deposit(1))?.wait()?;
                Ok(())
            })
            .unwrap();
    });

    // 6. Kernel call: spin reference vs AOT XLA artifact.
    let spin = SpinBackend::new(64, 4);
    let state = vec![0.1f32; 64];
    let params = vec![0.05f32; 64];
    bench("kernel: SpinBackend mix (D=64, R=4)", 20, 500, || {
        std::hint::black_box(spin.mix(&state, &params).unwrap());
    });
    if XlaRuntime::artifacts_present(&XlaRuntime::default_dir()) {
        let xla = XlaBackend::load_default().expect("artifacts");
        bench("kernel: XlaBackend mix (AOT artifact)", 20, 500, || {
            std::hint::black_box(xla.mix(&state, &params).unwrap());
        });
    } else {
        println!("kernel: XlaBackend skipped (run `make artifacts`)");
    }
    sys.shutdown();
    println!("micro done");
}
