//! Bench: regenerate the paper's Fig 10 — throughput vs client count for
//! all eight frameworks at three read-write ratios.
//!
//! `cargo bench --bench fig10_clients` (set `ARMI2_BENCH_QUICK=1` for a
//! fast smoke run). Raw rows land in `target/bench-results/fig10.csv`,
//! machine-readable results in `target/bench-results/BENCH_fig10.json`.

use atomic_rmi2::workload::sweeps::{fig10, write_results_csv, write_results_json, Scale};

fn main() {
    let scale = if std::env::var_os("ARMI2_BENCH_QUICK").is_some() {
        Scale::Quick
    } else {
        Scale::Full
    };
    let t0 = std::time::Instant::now();
    let (tables, results) = fig10(scale);
    for t in &tables {
        println!("{}", t.render());
    }
    match write_results_csv("fig10", &results) {
        Ok(path) => println!("raw results: {path}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    match write_results_json("fig10", scale, &results) {
        Ok(path) => println!("report: {path}"),
        Err(e) => eprintln!("json write failed: {e}"),
    }
    println!("fig10 done in {:.1}s", t0.elapsed().as_secs_f64());
}
