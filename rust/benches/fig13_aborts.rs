//! Bench: regenerate the paper's Fig 13 — the abort-rate table. HyFlow2
//! (TFA) aborts 60–89 % of transactions at high contention; Atomic RMI
//! and Atomic RMI 2 must report exactly 0 %.
//!
//! `cargo bench --bench fig13_aborts` (`ARMI2_BENCH_QUICK=1` to smoke).

use atomic_rmi2::workload::sweeps::{fig13, write_results_csv, write_results_json, Scale};

fn main() {
    let scale = if std::env::var_os("ARMI2_BENCH_QUICK").is_some() {
        Scale::Quick
    } else {
        Scale::Full
    };
    let t0 = std::time::Instant::now();
    let (table, results) = fig13(scale);
    println!("{}", table.render());
    // The paper's qualitative claim, enforced:
    for r in &results {
        if r.framework.contains("SVA") {
            assert_eq!(r.abort_rate, 0.0, "pessimistic framework aborted");
        }
    }
    match write_results_csv("fig13", &results) {
        Ok(path) => println!("raw results: {path}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    match write_results_json("fig13", scale, &results) {
        Ok(path) => println!("report: {path}"),
        Err(e) => eprintln!("json write failed: {e}"),
    }
    println!("fig13 done in {:.1}s", t0.elapsed().as_secs_f64());
}
