//! Bench: regenerate the paper's Fig 12 — Fig 11's node sweep with 10
//! additional conflict-free mild-array operations per transaction
//! (lower average contention).
//!
//! `cargo bench --bench fig12_mild` (`ARMI2_BENCH_QUICK=1` to smoke).

use atomic_rmi2::workload::sweeps::{fig12, write_results_csv, write_results_json, Scale};

fn main() {
    let scale = if std::env::var_os("ARMI2_BENCH_QUICK").is_some() {
        Scale::Quick
    } else {
        Scale::Full
    };
    let t0 = std::time::Instant::now();
    let (tables, results) = fig12(scale);
    for t in &tables {
        println!("{}", t.render());
    }
    match write_results_csv("fig12", &results) {
        Ok(path) => println!("raw results: {path}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    match write_results_json("fig12", scale, &results) {
        Ok(path) => println!("report: {path}"),
        Err(e) => eprintln!("json write failed: {e}"),
    }
    println!("fig12 done in {:.1}s", t0.elapsed().as_secs_f64());
}
