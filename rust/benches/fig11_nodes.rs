//! Bench: regenerate the paper's Fig 11 — throughput vs node count at
//! constant per-node load, with 5 and 10 arrays/node.
//!
//! `cargo bench --bench fig11_nodes` (`ARMI2_BENCH_QUICK=1` to smoke).

use atomic_rmi2::workload::sweeps::{fig11, write_results_csv, write_results_json, Scale};

fn main() {
    let scale = if std::env::var_os("ARMI2_BENCH_QUICK").is_some() {
        Scale::Quick
    } else {
        Scale::Full
    };
    let t0 = std::time::Instant::now();
    let (tables, results) = fig11(scale);
    for t in &tables {
        println!("{}", t.render());
    }
    match write_results_csv("fig11", &results) {
        Ok(path) => println!("raw results: {path}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    match write_results_json("fig11", scale, &results) {
        Ok(path) => println!("report: {path}"),
        Err(e) => eprintln!("json write failed: {e}"),
    }
    println!("fig11 done in {:.1}s", t0.elapsed().as_secs_f64());
}
